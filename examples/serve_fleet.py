"""Fleet serving demo: concurrent ServingEngine instances behind the
global router — optionally with the online weight tuner in the loop.

The same router policies that drive the Level-1 fleet simulator
(`repro.cluster.router`) place real-model request streams across multiple
`repro.serving.ServingEngine` instances ("nodes" with different virtual
accelerator fleets).  The router only needs the narrow node surface —
``node_id`` + ``telemetry()`` + per-stream cost estimates — so a thin
adapter over each engine's *measured* latency table is enough: the same
score formula runs on measured numbers here and on offline cost tables in
the simulator.

``--policy tuned_score`` closes the telemetry loop over real engines: the
run splits into ``--epochs`` serving epochs, each epoch re-places every
stream with the router's current weights, serves it, and feeds the
realized per-node deadline-violation rates back as a telemetry window
(`TunedScoreRouter.on_window`) — the same hindsight-scored coordinate
probe the fleet simulator drives at tune ticks, walking real measured
outcomes instead of simulated ones.

Execution is concurrent — one thread per node, as in a real fleet where
nodes serve independently (placement stays sequential and deterministic;
engines share read-only JAX handles and JAX releases the GIL during
device execution; see docs/architecture.md "Concurrency model").

    PYTHONPATH=src python examples/serve_fleet.py --duration 4 \
        --policy tuned_score --epochs 3
"""
import argparse
import sys
from concurrent.futures import ThreadPoolExecutor

sys.path.insert(0, "src")

import numpy as np

from repro.cluster.node import NodeTelemetry, StreamCost
from repro.cluster.telemetry import TelemetryWindow
from repro.cluster.router import make_policy
from repro.core.uxcost import WindowStats, uxcost
from repro.launch.serve import build_handle
from repro.obs import Obs
from repro.serving import RequestQueue, ServingEngine, VirtualAccelerator


class EngineNode:
    """Adapter: a ServingEngine viewed through the fleet-router surface."""

    def __init__(self, node_id: int, name: str, engine: ServingEngine):
        self.node_id = node_id
        self.name = name
        self.engine = engine
        self.streams: list["EngineStream"] = []
        self.offered_s = 0.0

    def telemetry(self) -> NodeTelemetry:
        n_accs = len(self.engine.accs)
        return NodeTelemetry(
            node_id=self.node_id, system=self.name, n_accs=n_accs,
            queue_depth=0, active_streams=len(self.streams),
            backlog_s=0.0, offered_util=self.offered_s / n_accs,
            window_uxcost=0.0, window_dlv=0.0, utilization=0.0,
            drops=0, draining=False)

    def assign(self, stream: "EngineStream") -> None:
        self.streams.append(stream)
        self.offered_s += stream.cost_on(self).offered_s


class EngineStream:
    """One FPS stream of a registered model, costed from measured tables."""

    def __init__(self, model: str, fps: float, seq: int = 32):
        self.model = model
        self.fps = fps
        self.seq = seq

    def cost_on(self, node: EngineNode) -> StreamCost:
        iso = min(node.engine.lat_table[(self.model, a.name)]
                  for a in node.engine.accs)
        return StreamCost(iso_s=iso, offered_s=self.fps * iso,
                          urgency=iso * self.fps)


def epoch_window(epoch: int, nodes, prev) -> TelemetryWindow:
    """Fold the epoch's engine stats into the telemetry-window shape the
    tuner consumes.  Windows are pure *deltas* (the TelemetryWindow
    contract): ``prev`` maps node_id -> per-model cumulative snapshots at
    the previous epoch boundary, and everything — frames, per-node DLV,
    the window UXCost — is computed from the difference."""
    node_dlv, node_frames = {}, {}
    delta = WindowStats()
    for node in nodes:
        snap = {name: (st.frames, st.violated, st.energy_j,
                       st.worst_energy_j)
                for name, st in node.engine.stats.per_model.items()}
        last = prev.get(node.node_id, {})
        nf = nv = 0
        for name, (f, v, e, w) in snap.items():
            pf, pv, pe, pw = last.get(name, (0, 0, 0.0, 0.0))
            if f - pf > 0 or w - pw > 0.0:
                # per-node namespacing: two nodes hosting one model name
                # stay separate entries in the epoch's UXCost
                d = delta.model(f"n{node.node_id}.{name}")
                d.frames = f - pf
                d.violated = v - pv
                d.energy_j = e - pe
                d.worst_energy_j = w - pw
            nf += f - pf
            nv += v - pv
        prev[node.node_id] = snap
        node_frames[node.node_id] = nf
        node_dlv[node.node_id] = nv / nf if nf > 0 else 0.0
    frames = sum(st.frames for st in delta.per_model.values())
    violated = sum(st.violated for st in delta.per_model.values())
    return TelemetryWindow(
        t0=float(epoch), t1=float(epoch + 1), frames=frames,
        violated=violated,
        dlv_rate=violated / frames if frames else 0.0,
        uxcost=uxcost(delta), node_dlv=node_dlv, node_frames=node_frames,
        backlog_p50=0.0, backlog_p90=0.0, backlog_max=0.0,
        migrations=0, xfer_j=0.0, stream_uxcost={},
        n_models=sum(1 for st in delta.per_model.values() if st.frames))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=4.0)
    ap.add_argument("--policy", default="score",
                    choices=("round_robin", "least_loaded", "score",
                             "tuned_score"))
    ap.add_argument("--epochs", type=int, default=0, help=(
        "serving epochs (re-place + serve + feed telemetry); defaults to "
        "3 for tuned_score, 1 otherwise"))
    ap.add_argument("--obs", default=None, metavar="DIR", help=(
        "export observability artifacts (placement/epoch spans + a "
        "Prometheus/JSON metrics snapshot) to this directory"))
    args = ap.parse_args()
    if args.epochs <= 0:
        args.epochs = 3 if args.policy == "tuned_score" else 1

    # two nodes with different virtual hardware: a big/fast node and a
    # frugal node of small slices — the capacity heterogeneity the
    # score-driven router exploits
    nodes = [
        EngineNode(0, "big", ServingEngine([
            VirtualAccelerator("big0", speed=1.0, power=1.0),
            VirtualAccelerator("big1", speed=1.0, power=1.0),
        ])),
        EngineNode(1, "small", ServingEngine([
            VirtualAccelerator("small0", speed=0.45, power=0.4),
            VirtualAccelerator("small1", speed=0.45, power=0.4),
        ])),
    ]

    handles = [
        build_handle("gemma-2b", "detector", layers=2),
        build_handle("qwen1.5-4b", "verifier", layers=2),
        build_handle("gemma2-2b", "context", layers=4),
        build_handle("mamba2-130m", "kws", layers=2),
    ]
    calib = np.zeros((1, 32), np.int32)
    import jax
    import jax.numpy as jnp
    for h in handles:       # compile before any engine calibrates, so every
        # node's measured table reflects steady-state latency, not compile
        jax.block_until_ready(h.fn(h.params, jnp.asarray(calib)))
    for node in nodes:
        for h in handles:
            node.engine.register(h, calib)

    streams = [
        EngineStream("detector", fps=8),
        EngineStream("verifier", fps=6),
        EngineStream("context", fps=4),
        EngineStream("kws", fps=12),
        EngineStream("detector", fps=6),
        EngineStream("kws", fps=10),
    ]

    policy = make_policy(args.policy)
    rng = np.random.default_rng(0)           # tuner distant-sample stream
    per_epoch_s = args.duration / args.epochs
    prev: dict[int, tuple] = {}
    # observability: the same Obs bundle the fleet simulator threads —
    # spans for placements/epochs, a metrics registry the serving loop
    # publishes into (real engines are wall-clock-timed, so spans here
    # carry epoch indices as the time axis)
    obs = Obs.make({"profile": False} if args.obs else None)
    if obs is not None and obs.metrics is not None:
        m_frames = obs.metrics.counter(
            "serve_frames_total", "frames served", ("node", "model"))
        m_viol = obs.metrics.counter(
            "serve_violations_total", "deadline violations",
            ("node", "model"))
        m_dlv = obs.metrics.gauge(
            "serve_epoch_dlv", "epoch deadline-violation rate")
    print(f"[serve_fleet] policy={policy.name}, {args.epochs} epoch(s) x "
          f"{per_epoch_s:.2f}s")
    for epoch in range(args.epochs):
        # each epoch re-places every stream with the router's current
        # weights on fresh queues — the placement lever the tuner turns
        for node in nodes:
            node.streams = []
            node.offered_s = 0.0
        queues = {n.node_id: RequestQueue(clock=lambda: 0.0)
                  for n in nodes}
        placements = []
        for i, stream in enumerate(streams):
            nid = policy.place(stream, nodes)
            node = next(n for n in nodes if n.node_id == nid)
            node.assign(stream)
            # one engine hosts at most one queue stream per model name
            if stream.model not in queues[nid].streams:
                queues[nid].add_stream(stream.model, fps=stream.fps,
                                       batch=1, seq=stream.seq, vocab=128)
            else:
                st = queues[nid].streams[stream.model]
                st["fps"] += stream.fps      # fold arrival rates, but keep
                # the tightest *original* per-frame deadline — the summed
                # rate is not a deadline
                st["deadline"] = min(st["deadline"], 1.0 / stream.fps)
            placements.append((i, stream.model, stream.fps, node.name))
            if obs is not None and obs.tracer is not None:
                obs.tracer.event("place", float(epoch), stream=i,
                                 model=stream.model, node=node.name,
                                 policy=policy.name)

        for i, model, fps, where in placements:
            print(f"[serve_fleet]   epoch {epoch} stream {i}: "
                  f"{model:>9s} @{fps:4.1f}fps -> node {where}")

        # drive every node's engine concurrently (one thread per node,
        # like a real fleet): each thread owns exactly one engine + queue,
        # so there is no shared mutable state between them; results are
        # collected per node and merged in node order after the join,
        # keeping output and fleet stats deterministic regardless of
        # thread scheduling
        active = [n for n in nodes if n.streams]
        for node in nodes:
            if node not in active:
                print(f"[serve_fleet] node {node.name}: idle")
        with ThreadPoolExecutor(max_workers=max(len(active), 1)) as pool:
            futures = {
                node.node_id: pool.submit(node.engine.run,
                                          queues[node.node_id],
                                          duration_s=per_epoch_s)
                for node in active
            }
            reports = {nid: fut.result() for nid, fut in futures.items()}
        for node in active:                   # node order: deterministic
            print(f"[serve_fleet] node {node.name}: "
                  f"{reports[node.node_id].summary()}")

        win = epoch_window(epoch, nodes, prev)
        if obs is not None:
            if obs.tracer is not None:
                obs.tracer.span("epoch", float(epoch), float(epoch + 1),
                                dlv=win.dlv_rate, uxcost=win.uxcost,
                                frames=win.frames)
            if obs.metrics is not None:
                m_dlv.set(win.dlv_rate)
        on_window = getattr(policy, "on_window", None)
        if on_window is not None:
            on_window(win, rng)
            print(f"[serve_fleet]   epoch {epoch}: DLV={win.dlv_rate:.3f} "
                  f"-> weights "
                  f"{[round(w, 3) for w in policy.weights]} "
                  f"(commits={policy.probe.commits})")

    fleet_stats = WindowStats()
    for node in nodes:                        # node order: deterministic
        fleet_stats.merge(node.engine.stats)
    print(f"[serve_fleet] fleet UXCost = {uxcost(fleet_stats):.4f} over "
          f"{sum(st.frames for st in fleet_stats.per_model.values())} frames "
          f"({len(nodes)} nodes, {args.epochs} epochs)")
    if obs is not None:
        if obs.metrics is not None:
            for node in nodes:
                for name, st in sorted(node.engine.stats.per_model.items()):
                    m_frames.inc(st.frames, node=node.name, model=name)
                    m_viol.inc(st.violated, node=node.name, model=name)
            obs.metrics.gauge(
                "serve_fleet_uxcost",
                "fleet UXCost at run end").set(uxcost(fleet_stats))
        if obs.tracer is not None:
            obs.tracer.finish(float(args.epochs))
        paths = obs.export(args.obs)
        print(f"[serve_fleet] obs artifacts -> "
              f"{', '.join(sorted(paths.values()))}")


if __name__ == "__main__":
    main()
