"""Train a language model end-to-end on the synthetic pipeline.

Default: a ~1M-param GPT-style model for 300 steps on CPU (~2 min), with
checkpointing and resume. ``--preset 100m`` selects a ~124M-parameter
config (the deliverable-scale run — use on a real machine with time).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --steps 300 --fail-at 150
    PYTHONPATH=src python examples/train_lm.py --resume auto   # continue
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import ArchConfig
from repro.data import SyntheticLMData
from repro.distributed import FaultInjector, SimulatedPreemption
from repro.training import OptimConfig, TrainConfig, Trainer

PRESETS = {
    # ~1M params: CPU-friendly demo
    "tiny": ArchConfig(
        name="lm-tiny", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512,
        dtype="float32", scan_layers=False),
    # ~124M params: GPT-2-small-class (the "train ~100M" deliverable)
    "100m": ArchConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32768,
        dtype="float32", scan_layers=True, remat="dots"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", default="never",
                    choices=["auto", "never", "must"])
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n_params = None
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=0)
    trainer = Trainer(
        cfg=cfg,
        tcfg=TrainConfig(optim=OptimConfig(
            learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
            total_steps=args.steps)),
        data=iter(data),
        ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=25,
        fault_injector=(FaultInjector((args.fail_at,))
                        if args.fail_at is not None else None),
    )
    trainer.init_or_resume(resume=args.resume)
    import jax
    n_params = sum(x.size for x in jax.tree.leaves(
        trainer.state["params"]))
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    try:
        hist = trainer.run(args.steps)
    except SimulatedPreemption as e:
        print(f"[train_lm] {e} — rerun with --resume auto to recover "
              f"from {args.ckpt_dir}")
        return
    print(f"[train_lm] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(acc {hist[-1]['accuracy']:.3f}) over {len(hist)} steps")


if __name__ == "__main__":
    main()
