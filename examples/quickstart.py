"""Quickstart: schedule an RTMM workload scenario with DREAM in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (build_scenario, dream_full, run_planaria, run_sim)
from repro.core.baselines import FCFSScheduler, VeltairLikeScheduler

SCENARIO = "AR_Call"            # keyword spotting -> translation + SkipNet
SYSTEM = "4K_1WS2OS"            # 1 big WS + 2 small OS sub-accelerators


def main() -> None:
    scn = build_scenario(SCENARIO, cascade_prob=0.5)
    print(f"scenario {SCENARIO}: "
          + ", ".join(f"{m.model.name}@{m.fps:.0f}fps" for m in scn.models))

    results = [
        run_sim(scn, SYSTEM, FCFSScheduler, duration_s=4.0),
        run_sim(scn, SYSTEM, VeltairLikeScheduler, duration_s=4.0),
        run_planaria(scn, SYSTEM, duration_s=4.0),
        run_sim(scn, SYSTEM, dream_full, duration_s=4.0),
    ]
    print(f"\n{'scheduler':>12s} {'UXCost':>9s} {'DLV':>7s} "
          f"{'energy':>7s} {'frames':>7s} {'drops':>6s}")
    for r in results:
        print(f"{r.scheduler:>12s} {r.uxcost:9.4f} {r.dlv_rate:7.3f} "
              f"{r.norm_energy:7.3f} {r.frames:7d} {r.drops:6d}")
    best = min(results, key=lambda r: r.uxcost)
    print(f"\nlowest UXCost: {best.scheduler}")


if __name__ == "__main__":
    main()
