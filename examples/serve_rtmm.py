"""End-to-end driver: a real-time multi-model workload served by DREAM.

Real JAX models (reduced LM configs from four assigned architecture
families) run as concurrent FPS streams with a cascade dependency and a
weight-class Supernet variant, dispatched onto heterogeneous virtual
accelerator slices by MapScore, with smart frame drop, online (alpha, beta)
adaptivity and straggler re-dispatch — the production face of the paper.

    PYTHONPATH=src python examples/serve_rtmm.py --duration 8
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.launch.serve import build_handle
from repro.serving import RequestQueue, ServingEngine, VirtualAccelerator


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=8.0)
    ap.add_argument("--overload", action="store_true",
                    help="double every FPS target to show frame drop + "
                         "supernet switching under load")
    args = ap.parse_args()

    accs = [
        VirtualAccelerator("big0", speed=1.0, power=1.0),
        VirtualAccelerator("small0", speed=0.45, power=0.4),
        VirtualAccelerator("small1", speed=0.45, power=0.4),
    ]
    engine = ServingEngine(accs, adaptivity=True, frame_drop=True,
                           supernet_switch=True)

    det = build_handle("gemma-2b", "detector", layers=2)
    verif = build_handle("qwen1.5-4b", "verifier", layers=2)
    ctx = build_handle("gemma2-2b", "context", layers=4)
    ctx_v1 = build_handle("gemma2-2b", "context@v1", layers=2)
    ctx.supernet = ("context@v1",)
    kws = build_handle("mamba2-130m", "kws", layers=2)

    calib32 = np.zeros((1, 32), np.int32)
    calib16 = np.zeros((1, 16), np.int32)
    for h in (det, verif, ctx, ctx_v1):
        engine.register(h, calib32)
    engine.register(kws, calib16)

    mult = 2.0 if args.overload else 1.0
    q = RequestQueue(clock=lambda: 0.0)
    q.add_stream("detector", fps=8 * mult, batch=1, seq=32, vocab=128)
    q.add_stream("verifier", fps=8 * mult, batch=1, seq=32, vocab=128,
                 depends_on="detector", trigger_prob=0.5)
    q.add_stream("context", fps=4 * mult, batch=1, seq=32, vocab=128)
    q.add_stream("kws", fps=12 * mult, batch=1, seq=16, vocab=128)

    report = engine.run(q, duration_s=args.duration)
    print("[serve_rtmm]", report.summary())
    for name, st in sorted(report.per_model.items()):
        print(f"[serve_rtmm]   {name:>12s} frames={st['frames']:4d} "
              f"violated={st['violated']:4d} energy={st['energy']:.3f}")
    print(f"[serve_rtmm] adapted (alpha, beta) = "
          f"({report.alpha:.2f}, {report.beta:.2f}); "
          f"aborted={engine.aborted}")


if __name__ == "__main__":
    main()
