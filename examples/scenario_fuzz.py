"""Scenario-engine tour: fuzz, build, phase-shift, record, replay.

    PYTHONPATH=src python examples/scenario_fuzz.py [seed]

Samples a few random-but-valid RTMM scenarios, prints their composition,
then takes one through the full loop: simulate under DREAM with a mid-run
workload shift while recording the arrival trace, write the trace to JSONL,
and replay it — verifying the replayed UXCost is bit-identical.
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.core import dream_full, run_sim
from repro.core.baselines import FCFSScheduler
from repro.core.simulator import Simulator
from repro.scenarios import (fuzz_phase_script, fuzz_scenario, load_trace,
                             save_trace)


def describe(builder) -> str:
    parts = []
    for e in builder.entries:
        arr = e.arrival.kind if e.arrival is not None else "periodic"
        dep = f" <-{e.depends_on}@p={e.trigger_prob}" if e.depends_on else ""
        parts.append(f"{e.model_name}@{e.fps:.0f}fps[{arr}]{dep}")
    return ", ".join(parts)


def main() -> None:
    seed = int(sys.argv[1]) if len(sys.argv) > 1 else 0
    print("sampled scenarios:")
    for k in range(4):
        b = fuzz_scenario(seed + k)
        print(f"  [{seed + k}] {describe(b)}")

    builder = fuzz_scenario(seed)
    script = fuzz_phase_script(seed, builder, duration_s=4.0)
    t, action = script.events[0]
    print(f"\nphase shift at t={t:.2f}s: {action.to_config()}")

    sim = Simulator(builder.build(), "4K_1WS2OS", dream_full(),
                    duration_s=4.0, seed=seed, phase_script=script,
                    record=True)
    live = sim.run()
    fcfs = run_sim(builder.build(), "4K_1WS2OS", FCFSScheduler,
                   duration_s=4.0, seed=seed, phase_script=script)
    print(f"live  DREAM UXCost={live.uxcost:.4f} frames={live.frames} "
          f"(FCFS UXCost={fcfs.uxcost:.4f})")

    with tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False) as f:
        path = save_trace(sim.trace, f.name)
    print(f"trace: {len(sim.trace.events)} events -> {path}")

    replayed = Simulator(builder.build(), "4K_1WS2OS", dream_full(),
                         duration_s=4.0, seed=seed,
                         replay=load_trace(path)).run()
    print(f"replay      UXCost={replayed.uxcost:.4f} frames={replayed.frames}")
    assert replayed.uxcost == live.uxcost, "replay diverged from live run"
    print("replay is bit-identical to the live run")


if __name__ == "__main__":
    main()
