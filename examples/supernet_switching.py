"""Supernet switching under load (Section 4.5.1 / Figure 14, live demo).

Runs the same scenario twice in the Level-1 simulator — light load (50%
cascade) and heavy load (99% cascade) — and prints which Once-for-All
subnet the DREAM dispatcher selected for the context-understanding model,
plus the UXCost with and without switching.

    PYTHONPATH=src python examples/supernet_switching.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import build_scenario, dream_full, dream_smartdrop, run_sim

SYSTEM = "4K_1WS2OS"


def subnet_breakdown(r):
    counts = {k: v for k, v in r.variant_counts.items()
              if k.startswith("ctx_ofa")}
    total = sum(counts.values())
    return {k: v / total for k, v in sorted(counts.items())} if total else {}


def main() -> None:
    for prob, label in ((0.5, "light load (50% cascade)"),
                        (0.99, "heavy load (99% cascade)")):
        scn = build_scenario("AR_Social", prob)
        with_sw = run_sim(scn, SYSTEM, dream_full, duration_s=6.0)
        without = run_sim(scn, SYSTEM, dream_smartdrop, duration_s=6.0)
        print(f"\n{label}:")
        print(f"  UXCost with switching    = {with_sw.uxcost:8.4f} "
              f"(DLV {with_sw.dlv_rate:.3f})")
        print(f"  UXCost without switching = {without.uxcost:8.4f} "
              f"(DLV {without.dlv_rate:.3f})")
        print("  subnet selection:")
        for name, frac in subnet_breakdown(with_sw).items():
            tag = "original" if "@" not in name else name.split("@")[1]
            print(f"    {tag:>9s}: {frac*100:5.1f}%")


if __name__ == "__main__":
    main()
