#!/usr/bin/env bash
# CI entrypoint: lint + tier-1 tests + docs checks + benchmark smokes with
# regression gating, organized as named stages with per-stage wall times.
#
#   scripts/ci.sh [artifact-dir]
#
# Modes:
#   CI_FAST=1 scripts/ci.sh    fast mode (PRs): lint + coverage-gated
#                              tests + docs checks
#   scripts/ci.sh              full mode (main): + benchmark smokes + the
#                              check_bench.py baseline comparison
#
# Exits nonzero on any failure; suitable for any CI runner.  Needs no
# install step: the repo imports via PYTHONPATH (the `pip install -e .`
# path works too, but CI stays install-free).
set -euo pipefail
cd "$(dirname "$0")/.."

ARTIFACTS="${1:-benchmarks/artifacts}"
mkdir -p "$ARTIFACTS"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

CI_FAST="${CI_FAST:-0}"
STAGE_NAMES=()
STAGE_TIMES=()

# Coverage gate (fast lane): pytest-cov over the scheduling stack the
# tier-1 suite exercises end-to-end (core + cluster + scenarios +
# serving; the jax model/kernel stack has its own tests but is gated by
# them, not by line coverage).  The committed threshold is a ratchet
# floor — raise it when coverage rises, never lower it to make a PR
# pass.  Skipped gracefully when pytest-cov is not installed (local
# runs); CI always installs it, so the gate is real there.
COV_MIN="${COV_MIN:-80}"
COV_PKGS=(--cov=repro.core --cov=repro.cluster --cov=repro.scenarios
          --cov=repro.serving)
COV_TOTAL="not measured (pytest-cov not installed)"

stage() {
    local name="$1"
    shift
    echo
    echo "=== ${name} ==="
    local t0=$SECONDS
    "$@"
    local dt=$(( SECONDS - t0 ))
    STAGE_NAMES+=("$name")
    STAGE_TIMES+=("$dt")
    echo "--- ${name}: ${dt}s"
}

report() {
    echo
    echo "=== stage times ==="
    local i
    for i in "${!STAGE_NAMES[@]}"; do
        printf '  %-18s %4ss\n' "${STAGE_NAMES[$i]}" "${STAGE_TIMES[$i]}"
    done
    printf '  %-18s %s (gate: >= %s%%)\n' coverage "$COV_TOTAL" "$COV_MIN"
}
trap report EXIT

# ---------------------------------------------------------------- stages
lint() {
    # syntax/import rot fails fast, before the test stage
    python -m compileall -q src benchmarks examples scripts tests
    if python -c "import pyflakes" 2>/dev/null; then
        # package __init__.py files re-export their submodule surface on
        # purpose; every other pyflakes finding is a failure
        local out
        out=$(python -m pyflakes src benchmarks examples scripts tests \
              | grep -v "__init__.py:.*imported but unused" || true)
        if [ -n "$out" ]; then
            echo "$out"
            echo "lint: pyflakes findings above" >&2
            return 1
        fi
        echo "lint: compileall + pyflakes ok"
    else
        echo "lint: compileall ok (pyflakes not installed, skipped)"
    fi
}

#: DeprecationWarning promoted to error for warnings attributed to
#: repro.* modules: an internal caller regressing onto a deprecated call
#: form (legacy fuzz_streams kwargs, Simulator(soa_slab=...), ...) fails
#: the lane instead of scrolling by.  Third-party deprecations and test
#: modules exercising the shims on purpose (pytest.warns) are unaffected.
PYTEST_W=(-W 'error::DeprecationWarning:repro')

tests() {
    if python -c "import pytest_cov" 2>/dev/null; then
        python -m pytest -x -q "${PYTEST_W[@]}" "${COV_PKGS[@]}" \
            --cov-report=term --cov-fail-under="$COV_MIN"
        COV_TOTAL="$(python -m coverage report --format=total 2>/dev/null \
                     || echo '?')%"
    else
        echo "tests: pytest-cov not installed — coverage gate skipped"
        python -m pytest -x -q "${PYTEST_W[@]}"
    fi
}

docs_refs() {
    python scripts/check_docs.py docs
}

vector_smoke() {
    # fast-lane vectorization gate: the batched score/dispatch/clock fast
    # paths must stay bit-identical to their scalar oracles (differential
    # suite), replay the golden corpus digest-exact, keep the peek-heap
    # invariant across membership churn, and clear the committed
    # throughput smoke floor.  Redundant with the full `tests` stage by
    # design: vectorization drift fails here with a named stage instead
    # of somewhere inside the suite run.
    python -m pytest -q -p no:cacheprovider "${PYTEST_W[@]}" \
        tests/test_vectorized_equiv.py tests/test_golden_traces.py \
        tests/test_peek_heap.py tests/test_perf_smoke.py \
        tests/test_fuzz_spec.py
}

slo_smoke() {
    # fast-lane SLO gate: a small overloaded tiered fleet must trigger the
    # admission controller (swaps and/or rejections) and replay bit-exactly
    # with the controller bypassed (recorded decisions applied as inputs)
    python - <<'EOF'
import sys
from benchmarks.fleet_sweep import build_overload_fleet, OVERLOAD_SLO
from repro.cluster import FleetSimulator
from repro.cluster import trace as ftrace
scn = build_overload_fleet(3, 4, 24, 1.0, burst=True)
r = FleetSimulator(scn, "score", duration_s=1.0, seed=3, slo=OVERLOAD_SLO,
                   slo_every_s=0.1, record=True).run()
rep = FleetSimulator(replay=ftrace.loads(ftrace.dumps(r.trace))).run()
if r.swaps + r.rejections == 0:
    sys.exit("slo smoke: controller never acted on an overloaded fleet")
if (rep.uxcost, rep.frames, rep.swaps, rep.rejections, rep.tier_dlv) != \
        (r.uxcost, r.frames, r.swaps, r.rejections, r.tier_dlv):
    sys.exit("slo smoke: SLO trace replay mismatch")
print(f"ci: ok — slo smoke: {r.swaps} swaps, {r.rejections} rejections, "
      f"tier_dlv={{{', '.join(f'{k}: {v:.3f}' for k, v in r.tier_dlv.items())}}}, "
      "replay exact")
EOF
}

obs_smoke() {
    # fast-lane observability gate: a tiny traced fleet run must (a) stay
    # bit-identical to an untraced control in UXCost/frames, (b) export a
    # Prometheus snapshot our strict parser accepts, (c) produce a
    # non-empty schema-valid span file whose critical paths reconcile with
    # the reported pipeline latency, and (d) record profiler wall time
    python - <<'EOF'
import sys, tempfile
from benchmarks.fleet_sweep import build_overload_fleet, OVERLOAD_SLO
from repro.cluster import FleetSimulator
from repro.obs import critical_path, load_jsonl, parse_prometheus, \
    pipeline_tails
scn = build_overload_fleet(3, 4, 24, 1.0, burst=True)
kw = dict(duration_s=1.0, seed=3, slo=OVERLOAD_SLO, slo_every_s=0.1)
ctrl = FleetSimulator(scn, "score", **kw).run()
fs = FleetSimulator(scn, "score", obs=True, **kw)
r = fs.run()
if (r.uxcost, r.frames, r.tier_dlv) != \
        (ctrl.uxcost, ctrl.frames, ctrl.tier_dlv):
    sys.exit("obs smoke: traced run diverged from untraced control")
with tempfile.TemporaryDirectory() as d:
    paths = fs.obs.export(d)
    recs = load_jsonl(paths["spans"])           # validates every span
    if not recs:
        sys.exit("obs smoke: span file is empty")
    fams = parse_prometheus(open(paths["metrics_prom"]).read())
    if not fams:
        sys.exit("obs smoke: Prometheus export has no samples")
tails = pipeline_tails(recs)
if not tails:
    sys.exit("obs smoke: no completed pipeline tails traced")
tot = 0.0
for tail in tails:
    cp = critical_path(recs, tail_uid=tail["attrs"]["uid"])
    if abs(sum(s["t1"] - s["t0"] for s in cp["segments"])
           - cp["total_s"]) > 1e-9:
        sys.exit("obs smoke: critical-path segments do not telescope")
    tot += cp["total_s"]
if abs(tot / len(tails) - r.pipeline_latency_s) > 1e-9:
    sys.exit("obs smoke: critical paths do not reconcile with "
             "overall pipeline latency")
if fs.obs.profiler.total_wall_s <= 0.0:
    sys.exit("obs smoke: profiler recorded no wall time")
print(f"ci: ok — obs smoke: {len(recs)} spans, {len(fams)} metric "
      f"samples, {len(tails)} critical paths reconciled, traced run "
      "bit-identical to control")
EOF
}

soa_smoke() {
    # fast-lane SoA gate: the structure-of-arrays slab core must stay
    # bit-identical to the per-event scalar oracle (same UXCost, frames,
    # drops, aborts, and trace bytes) on a live fleet run, and the golden
    # corpus must replay digest-exact with the slab core engaged.  The
    # batch scheduler arm is forced (soa_batch_min=1) so small CI
    # scenarios exercise the matrix path, not just the scalar fallback.
    python - <<'EOF'
import sys
import pytest
from benchmarks.fleet_sweep import build_overload_fleet, OVERLOAD_SLO
from repro.cluster import FleetSimulator
from repro.cluster import trace as ftrace
from repro.core.scheduler import DreamScheduler
from repro.core.simulator import Simulator

def fp():
    scn = build_overload_fleet(3, 4, 24, 1.0, burst=True)
    r = FleetSimulator(scn, "score", duration_s=1.0, seed=3,
                       slo=OVERLOAD_SLO, slo_every_s=0.1, record=True).run()
    return (r.uxcost, r.frames, r.swaps, r.rejections, r.tier_dlv,
            ftrace.dumps(r.trace))

DreamScheduler.soa_batch_min = 1     # small CI fleets hit the matrix arm
slab = fp()
Simulator.soa_slab = False
scalar = fp()
Simulator.soa_slab = True
if slab != scalar:
    sys.exit("soa smoke: slab core diverged from the per-event oracle")
# golden corpus, replayed in-process so the forced flags stay in effect
rc = pytest.main(["-q", "-p", "no:cacheprovider",
                  "tests/test_golden_traces.py"])
if rc != 0:
    sys.exit("soa smoke: golden corpus digest check failed with the "
             "slab core engaged")
print("ci: ok — soa smoke: slab core bit-identical to scalar oracle "
      "(batch arm forced), golden corpus digest-exact")
EOF
}

genai_smoke() {
    # fast-lane genai gate: a mixed chat+vision fleet (autoregressive
    # chat_llm heads with stochastic token counts + fixed-deadline vision
    # pipelines) must (a) produce byte-identical traces on the SoA and
    # scalar engines — token-level preemption takes the same slab/heap
    # machinery as everything else — and (b) replay bit-exactly, with the
    # recorded per-job token counts consumed as inputs instead of RNG
    python - <<'EOF'
import sys
from benchmarks.fleet_sweep import build_genai_fleet
from repro.cluster import FleetSimulator
from repro.cluster import trace as ftrace
scn = build_genai_fleet(3, 3, 18, 1.0)
n_chat = sum(1 for e in scn.events if e.kind == "stream"
             and any(c["model"].get("builder") == "chat_llm"
                     for c in e.payload["entries"]))
if n_chat == 0:
    sys.exit("genai smoke: fuzzed population contains no chat_llm heads")
soa = FleetSimulator(scn, "score", duration_s=1.0, seed=3,
                     record=True).run()
scal = FleetSimulator(scn, "score", duration_s=1.0, seed=3, record=True,
                      engine="scalar").run()
soa_bytes = ftrace.dumps(soa.trace)
if soa_bytes != ftrace.dumps(scal.trace):
    sys.exit("genai smoke: scalar and SoA engine traces diverged on the "
             "mixed chat+vision fleet")
rep = FleetSimulator(replay=ftrace.loads(soa_bytes)).run()
if (rep.uxcost, rep.frames, rep.drops) != \
        (soa.uxcost, soa.frames, soa.drops):
    sys.exit("genai smoke: genai trace replay mismatch")
print(f"ci: ok — genai smoke: {n_chat} chat streams in the mix, "
      f"{soa.frames} frames, engines byte-identical, replay exact")
EOF
}

pydoc_render() {
    python - <<'EOF'
import pydoc
for mod in ("repro.cluster", "repro.cluster.fleet", "repro.cluster.router",
            "repro.cluster.node", "repro.cluster.builder",
            "repro.cluster.telemetry", "repro.cluster.trace",
            "repro.scenarios", "repro.scenarios.builder",
            "repro.scenarios.arrivals", "repro.scenarios.phases",
            "repro.scenarios.trace", "repro.scenarios.registry",
            "repro.scenarios.fuzzer", "repro.core.costmodel",
            "repro.core.adaptivity", "repro.obs", "repro.obs.spans",
            "repro.obs.metrics", "repro.obs.profiler", "repro.obs.report"):
    text = pydoc.plain(pydoc.render_doc(mod))  # raises on import failure
    assert "NAME" in text and "DESCRIPTION" in text, mod
print("pydoc: ok — all public modules render")
EOF
}

scenario_sweep() {
    python -m benchmarks.run --only scenario_sweep \
        --seed 0 --duration 1.5 --json "$ARTIFACTS/ci_scenario_sweep.json"
    python - "$ARTIFACTS/ci_scenario_sweep.json" <<'EOF'
import json, sys
d = json.load(open(sys.argv[1]))
if d["failures"]:
    sys.exit(f"benchmark failures: {d['failures']}")
sweep = d["results"]["scenario_sweep"]
if not sweep["all_replays_exact"]:
    sys.exit("trace replay determinism broken")
print("ci: ok —", len(sweep["rows"]), "fuzzed scenarios, replays exact")
EOF
}

fleet_sweep() {
    # 4 nodes + churn; includes the drift-tuner arm (8 nodes, CI-sized)
    python - "$ARTIFACTS/ci_fleet_sweep.json" <<'EOF'
import json, sys
from benchmarks.fleet_sweep import run
out = run(duration_s=1.5, seed=1, n_nodes=4, n_streams=28)
json.dump(out, open(sys.argv[1], "w"), indent=1)
if not out["replay_exact"]:
    sys.exit("fleet trace replay determinism broken")
if not out["score_beats_round_robin"]:
    sys.exit("score-driven routing did not beat round-robin")
d = out["drift"]
if not d["replay_exact"]:
    sys.exit("tuned fleet trace replay determinism broken")
if not d["tuned_beats_static"]:
    sys.exit("online-tuned routing did worse than static score weights "
             "on the drifting-workload fleet")
lf = out["lifecycle"]
if not lf["replay_exact"]:
    sys.exit("lifecycle fleet trace replay determinism broken")
if not lf["score_beats_ll"]:
    sys.exit("score routing did worse than least-loaded on the "
             "lifecycle-churn fleet")
if not lf["tuned_beats_ll"]:
    sys.exit("tuned routing did worse than least-loaded on the "
             "lifecycle-churn fleet")
ov = out["overload"]
if not ov["replay_exact"]:
    sys.exit("SLO fleet trace replay determinism broken")
if ov["slo_over_unaware_min"] < 1.0:
    sys.exit("SLO-aware admission did worse than the unaware control on "
             "at least one overload seed")
if not ov["tier0_flat"]:
    sys.exit("tier-0 violation rate not flat under the 2x overload burst")
if ov["swaps"] + ov["rejections"] == 0:
    sys.exit("overload arm exercised neither the degradation ladder nor "
             "the reject gate")
bu = out["budget"]
if not bu["replay_exact"]:
    sys.exit("budget-aware fleet trace replay determinism broken")
g = out["genai"]
if not g["predictor_beats_blind"]:
    sys.exit("EWMA length predictor did worse than blind cap pricing on "
             "at least one genai seed")
if not g["engine_equal"]:
    sys.exit("scalar and SoA engines diverged on the genai fleet")
if not g["replay_exact"]:
    sys.exit("genai fleet trace replay determinism broken")
print(f"ci: ok — {out['n_nodes']}-node fleet (+churn), "
      f"{out['n_streams']} streams, "
      f"UXCost(rr)/UXCost(score)={out['rr_over_score']:.3f}, "
      f"UXCost(static)/UXCost(tuned)={d['tuned_over_static']:.3f} "
      f"({d['n_seeds']} drift seeds); lifecycle "
      f"({lf['departures']} departures, {lf['rejoins']} rejoins, "
      f"{lf['link_queued']} link-queued transfers): "
      f"UXCost(ll)/UXCost(score)={lf['ll_over_score']:.3f}, "
      f"UXCost(ll)/UXCost(tuned)={lf['ll_over_tuned']:.3f}, "
      f"contended/uncontended={lf['contended_over_uncontended']:.3f}; "
      f"overload ({ov['swaps']} swaps, {ov['rejections']} rejections): "
      f"UXCost(unaware)/UXCost(aware)={ov['slo_over_unaware']:.3f}, "
      f"tier0_dlv={ov['tier0_dlv_overload']:.3f}, tier0_flat; "
      f"budget routing UXCost(flat)/UXCost(budget)="
      f"{bu['budget_over_flat']:.3f}; genai "
      f"UXCost(blind)/UXCost(predictor)={g['predictor_over_blind']:.3f} "
      f"(min {g['predictor_over_blind_min']:.3f}, engines equal); "
      "replays exact")
EOF
}

cascade_split() {
    python - "$ARTIFACTS/ci_cascade_split.json" <<'EOF'
import json, sys
from benchmarks.fleet_sweep import run_cascade
# 8 nodes: stage-splitting needs node diversity — 4-node fleets leave too
# few placement targets for heavy stages, and the comparison turns on luck
out = run_cascade(duration_s=1.5, seed=0, n_nodes=8, n_streams=10)
json.dump(out, open(sys.argv[1], "w"), indent=1)
if not out["replay_exact"]:
    sys.exit("stage-split fleet trace replay determinism broken")
if out["split_uxcost_total"] > out["whole_uxcost_total"]:
    sys.exit("stage-split routing exceeded whole-pipeline UXCost")
print(f"ci: ok — cascade fleets ({out['n_seeds']} seeds), "
      f"{out['split_streams']} streams split, "
      f"{out['trigger_transfers']} cross-node triggers, "
      f"UXCost(whole)/UXCost(split)={out['whole_over_split']:.3f}, "
      "replays exact")
EOF
}

bench_check() {
    # the nightly lane sets CI_GATE_THROUGHPUT=1 (after running the scale
    # arm) to additionally enforce the baseline's absolute throughput
    # floors; other lanes keep wall-clock throughput trajectory-only
    local extra=()
    if [ "${CI_GATE_THROUGHPUT:-0}" = "1" ]; then
        extra+=(--gate-throughput)
    fi
    python scripts/check_bench.py --artifacts "$ARTIFACTS" "${extra[@]}"
}

# ------------------------------------------------------------------ plan
stage lint           lint
stage vector_smoke   vector_smoke
stage tests          tests
stage docs_refs      docs_refs
stage slo_smoke      slo_smoke
stage obs_smoke      obs_smoke
stage soa_smoke      soa_smoke
stage genai_smoke    genai_smoke

if [ "$CI_FAST" = "1" ]; then
    echo
    echo "ci: fast mode (CI_FAST=1) — benchmark smokes skipped"
    exit 0
fi

stage pydoc_render   pydoc_render
stage scenario_sweep scenario_sweep
stage fleet_sweep    fleet_sweep
stage cascade_split  cascade_split
stage bench_check    bench_check
