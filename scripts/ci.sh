#!/usr/bin/env bash
# CI smoke entrypoint: tier-1 tests + one fast scenario-sweep benchmark.
# Exits nonzero on any failure; suitable for any CI runner.
#
#   scripts/ci.sh [artifact-dir]
set -euo pipefail
cd "$(dirname "$0")/.."

ARTIFACTS="${1:-benchmarks/artifacts}"
mkdir -p "$ARTIFACTS"

# package import works either via `pip install -e .` or the PYTHONPATH hack;
# CI uses the latter so it needs no install step
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 tests ==="
python -m pytest -x -q

echo "=== scenario sweep (fast) ==="
python -m benchmarks.run --only scenario_sweep \
    --seed 0 --duration 1.5 --json "$ARTIFACTS/ci_scenario_sweep.json"

python - "$ARTIFACTS/ci_scenario_sweep.json" <<'EOF'
import json, sys
d = json.load(open(sys.argv[1]))
if d["failures"]:
    sys.exit(f"benchmark failures: {d['failures']}")
sweep = d["results"]["scenario_sweep"]
if not sweep["all_replays_exact"]:
    sys.exit("trace replay determinism broken")
print("ci: ok —", len(sweep["rows"]), "fuzzed scenarios, replays exact")
EOF

echo "=== fleet sweep (fast, 4 nodes + churn) ==="
python - "$ARTIFACTS/ci_fleet_sweep.json" <<'EOF'
import json, sys
from benchmarks.fleet_sweep import run
out = run(duration_s=1.5, seed=1, n_nodes=4, n_streams=28)
json.dump(out, open(sys.argv[1], "w"), indent=1)
if not out["replay_exact"]:
    sys.exit("fleet trace replay determinism broken")
if not out["score_beats_round_robin"]:
    sys.exit("score-driven routing did not beat round-robin")
print(f"ci: ok — {out['n_nodes']}-node fleet (+churn), "
      f"{out['n_streams']} streams, "
      f"UXCost(rr)/UXCost(score)={out['rr_over_score']:.3f}, replay exact")
EOF

echo "=== cascade stage-split smoke (8 nodes + drain) ==="
python - "$ARTIFACTS/ci_cascade_split.json" <<'EOF'
import json, sys
from benchmarks.fleet_sweep import run_cascade
# 8 nodes: stage-splitting needs node diversity — 4-node fleets leave too
# few placement targets for heavy stages, and the comparison turns on luck
out = run_cascade(duration_s=1.5, seed=0, n_nodes=8, n_streams=10)
json.dump(out, open(sys.argv[1], "w"), indent=1)
if not out["replay_exact"]:
    sys.exit("stage-split fleet trace replay determinism broken")
if out["split_uxcost_total"] > out["whole_uxcost_total"]:
    sys.exit("stage-split routing exceeded whole-pipeline UXCost")
print(f"ci: ok — cascade fleets ({out['n_seeds']} seeds), "
      f"{out['split_streams']} streams split, "
      f"{out['trigger_transfers']} cross-node triggers, "
      f"UXCost(whole)/UXCost(split)={out['whole_over_split']:.3f}, "
      "replays exact")
EOF

echo "=== docs cross-references ==="
python scripts/check_docs.py docs

echo "=== pydoc render check (public fleet/scenario APIs) ==="
python - <<'EOF'
import pydoc
for mod in ("repro.cluster", "repro.cluster.fleet", "repro.cluster.router",
            "repro.cluster.node", "repro.cluster.builder",
            "repro.cluster.trace", "repro.scenarios",
            "repro.scenarios.builder", "repro.scenarios.arrivals",
            "repro.scenarios.phases", "repro.scenarios.trace",
            "repro.scenarios.registry", "repro.scenarios.fuzzer",
            "repro.core.costmodel"):
    text = pydoc.plain(pydoc.render_doc(mod))  # raises on import failure
    assert "NAME" in text and "DESCRIPTION" in text, mod
print("pydoc: ok — all public modules render")
EOF
