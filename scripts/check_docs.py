#!/usr/bin/env python
"""Docs cross-reference checker: every ``[[symbol]]`` in docs/*.md must
resolve to a real module path or module attribute, and every relative
markdown link between docs (docs -> docs, README -> docs) must point at
a file that exists.

The docs use ``[[repro.core.costmodel.TransferModel]]``-style references
as symbol-to-code cross links.  This script imports the longest module
prefix of each reference and walks the remaining attributes, so renames
and removals break CI instead of silently rotting the documentation.
Inter-doc ``[text](relative.md)`` links are resolved against the linking
file's directory; a deleted or renamed doc breaks CI the same way.

    PYTHONPATH=src python scripts/check_docs.py [docs-dir]
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

REF_RE = re.compile(r"\[\[([A-Za-z_][\w.]*)\]\]")
# [text](target) markdown links; skips images (![...]) and bare URLs
LINK_RE = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s#]+)(?:#[^)\s]*)?\)")


def resolve(ref: str) -> bool:
    """True when ``ref`` is an importable module or a module attribute."""
    parts = ref.split(".")
    for cut in range(len(parts), 0, -1):
        modname = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_links(path: pathlib.Path) -> list[str]:
    """Relative markdown links in ``path`` that point at missing files."""
    bad = []
    for target in LINK_RE.findall(path.read_text()):
        if "://" in target or target.startswith("mailto:"):
            continue                          # external URL — not checked
        if not (path.parent / target).exists():
            bad.append(target)
    return bad


def main(docs_dir: str = "docs") -> int:
    root = pathlib.Path(docs_dir)
    files = sorted(root.glob("*.md"))
    if not files:
        print(f"check_docs: no markdown files under {root}/", file=sys.stderr)
        return 1
    readme = root.parent / "README.md"
    link_files = files + ([readme] if readme.exists() else [])
    n_refs = n_links = 0
    failures: list[tuple[str, str]] = []
    for path in files:
        for ref in REF_RE.findall(path.read_text()):
            n_refs += 1
            if not resolve(ref):
                failures.append((str(path), f"unresolved reference [[{ref}]]"))
    for path in link_files:
        links = LINK_RE.findall(path.read_text())
        n_links += sum(1 for t in links
                       if "://" not in t and not t.startswith("mailto:"))
        for target in check_links(path):
            failures.append((str(path), f"broken link ({target})"))
    if failures:
        for path, msg in failures:
            print(f"check_docs: {path}: {msg}", file=sys.stderr)
        return 1
    print(f"check_docs: ok — {n_refs} references and {n_links} relative "
          f"links across {len(link_files)} files all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
