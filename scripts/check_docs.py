#!/usr/bin/env python
"""Docs cross-reference checker: every ``[[symbol]]`` in docs/*.md must
resolve to a real module path or module attribute.

The docs use ``[[repro.core.costmodel.TransferModel]]``-style references
as symbol-to-code cross links.  This script imports the longest module
prefix of each reference and walks the remaining attributes, so renames
and removals break CI instead of silently rotting the documentation.

    PYTHONPATH=src python scripts/check_docs.py [docs-dir]
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

REF_RE = re.compile(r"\[\[([A-Za-z_][\w.]*)\]\]")


def resolve(ref: str) -> bool:
    """True when ``ref`` is an importable module or a module attribute."""
    parts = ref.split(".")
    for cut in range(len(parts), 0, -1):
        modname = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def main(docs_dir: str = "docs") -> int:
    root = pathlib.Path(docs_dir)
    files = sorted(root.glob("*.md"))
    if not files:
        print(f"check_docs: no markdown files under {root}/", file=sys.stderr)
        return 1
    n_refs = 0
    failures: list[tuple[str, str]] = []
    for path in files:
        for ref in REF_RE.findall(path.read_text()):
            n_refs += 1
            if not resolve(ref):
                failures.append((str(path), ref))
    if failures:
        for path, ref in failures:
            print(f"check_docs: {path}: unresolved reference [[{ref}]]",
                  file=sys.stderr)
        return 1
    print(f"check_docs: ok — {n_refs} references across "
          f"{len(files)} files all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
