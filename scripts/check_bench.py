#!/usr/bin/env python
"""Benchmark-regression gate: compare CI sweep outputs against a committed
baseline with a tolerance band.

The CI benchmark smokes (``scripts/ci.sh``) write JSON artifacts whose
headline metrics are improvement *ratios* — higher is better:

  * ``rr_over_score``     — round-robin UXCost / score-routing UXCost
                            (ci_fleet_sweep.json)
  * ``whole_over_split``  — whole-pipeline UXCost / stage-split UXCost
                            (ci_cascade_split.json)
  * ``tuned_over_static`` — static-weights UXCost / online-tuned UXCost
                            (ci_fleet_sweep.json, drift section)
  * ``ll_over_score_lifecycle`` / ``ll_over_tuned_lifecycle`` —
                            least-loaded UXCost / score (resp. tuned)
                            UXCost on the lifecycle-churn fleet (streams
                            arrive AND depart; contended links)
  * ``contended_over_uncontended`` — score-routing UXCost under finite
                            shared-link bandwidth / under an uncontended
                            link (same scenarios).  Tracked *two-sided*:
                            this ratio is a determinism-sensitive
                            stability metric, not a higher-is-better one,
                            so drift in either direction past the band
                            fails.
  * ``slo_over_unaware``  — SLO-unaware UXCost / SLO-aware UXCost under
                            the 2x overload burst (ci_fleet_sweep.json,
                            overload section): what tiered admission +
                            variant degradation buy back.
  * ``tier0_dlv_overload`` — aggregate tier-0 (guaranteed) deadline-
                            violation rate of the SLO-aware overload
                            runs.  Two-sided: it must stay *flat* — a
                            drop can mean the burst stopped biting, a
                            rise that the guaranteed tier leaked
                            degradation.

This script loads the artifacts, extracts those metrics, and fails (exit
nonzero) when any falls below ``baseline * (1 - tolerance)`` (or, for
two-sided metrics, outside ``baseline * (1 ± tolerance)``).  The CI
runs are deterministic (fixed seeds, fixed configs), so drift within the
band can only come from intentional code changes; the band exists so
benign scheduler/router improvements that shuffle placements slightly do
not demand a baseline refresh, while real regressions fail loudly.

Improvements beyond the band are reported (not failed) with a reminder to
refresh the baseline:

    PYTHONPATH=src python scripts/check_bench.py [--artifacts DIR]
    PYTHONPATH=src python scripts/check_bench.py --update   # refresh

``--update`` rewrites the baseline from the current artifacts, preserving
the configured tolerances.

Every non-``--update`` run also appends its extracted ratios (stamped
with the git SHA + dirty flag) to ``benchmarks/baselines/trajectory.json``
— the BENCH trend series the nightly CI lane uploads; disable with
``--no-trajectory``.

Wall-clock throughput (``streams_per_wall_s`` from the CI smoke sweep,
``scale_streams_per_wall_s`` from the nightly 256-node/10k-stream arm)
is machine-dependent, so it never enters the ratio baseline.  It is
trajectory-tracked on every run and, on the nightly lane only
(``--gate-throughput``), gated one-sided against the absolute
``throughput_floors`` committed in the baseline — conservative floors
several-fold below the reference machine, catching pathological
slowdowns (a disabled fast path, an accidental O(N^2) rescan) without
flaking on runner noise.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys

_BASELINE_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                             "benchmarks", "baselines")
DEFAULT_BASELINE = os.path.join(_BASELINE_DIR, "ci_baseline.json")
DEFAULT_TRAJECTORY = os.path.join(_BASELINE_DIR, "trajectory.json")

#: metric name -> (artifact file, path inside the artifact json)
METRICS = {
    "rr_over_score": ("ci_fleet_sweep.json", ("rr_over_score",)),
    "whole_over_split": ("ci_cascade_split.json", ("whole_over_split",)),
    "tuned_over_static": ("ci_fleet_sweep.json",
                          ("drift", "tuned_over_static")),
    "ll_over_score_lifecycle": ("ci_fleet_sweep.json",
                                ("lifecycle", "ll_over_score")),
    "ll_over_tuned_lifecycle": ("ci_fleet_sweep.json",
                                ("lifecycle", "ll_over_tuned")),
    "contended_over_uncontended": (
        "ci_fleet_sweep.json", ("lifecycle", "contended_over_uncontended")),
    "slo_over_unaware": ("ci_fleet_sweep.json",
                         ("overload", "slo_over_unaware")),
    "tier0_dlv_overload": ("ci_fleet_sweep.json",
                           ("overload", "tier0_dlv_overload")),
    "budget_over_flat": ("ci_fleet_sweep.json",
                         ("budget", "budget_over_flat")),
    "predictor_over_blind": ("ci_fleet_sweep.json",
                             ("genai", "predictor_over_blind")),
    "streams_per_wall_s": ("ci_fleet_sweep.json", ("streams_per_wall_s",)),
}

#: metrics whose artifact may legitimately be absent (produced only by
#: the nightly lane's extra arms); skipped with a note when missing
OPTIONAL_METRICS = {
    "scale_streams_per_wall_s": ("fleet_scale.json",
                                 ("streams_per_wall_s",)),
}

#: metrics recorded in the trajectory trend series but never part of the
#: ratio baseline: wall-clock throughput depends on the machine running
#: CI, so its *trend on one machine* is what matters.  The nightly lane
#: additionally gates these one-sided against the absolute floors
#: committed in the baseline's ``throughput_floors`` section (pass
#: ``--gate-throughput``); the floors are conservative — several-fold
#: below the reference machine's typical numbers — so they only trip on
#: pathological slowdowns, not runner noise.
TRAJECTORY_ONLY = {"streams_per_wall_s", "scale_streams_per_wall_s"}


def extract(artifacts_dir: str) -> dict[str, float]:
    """Pull every gated metric out of the CI artifacts (all must exist)."""
    out: dict[str, float] = {}
    cache: dict[str, dict] = {}
    for name, (fname, path) in METRICS.items():
        fpath = os.path.join(artifacts_dir, fname)
        if fname not in cache:
            try:
                with open(fpath) as f:
                    cache[fname] = json.load(f)
            except FileNotFoundError:
                sys.exit(f"check_bench: missing artifact {fpath} — run the "
                         "CI benchmark stages first (scripts/ci.sh)")
        node = cache[fname]
        for key in path:
            if key not in node:
                sys.exit(f"check_bench: {fname} has no {'.'.join(path)} — "
                         "artifact predates this metric; re-run the sweep")
            node = node[key]
        out[name] = float(node)
    for name, (fname, path) in OPTIONAL_METRICS.items():
        fpath = os.path.join(artifacts_dir, fname)
        try:
            with open(fpath) as f:
                node = json.load(f)
        except FileNotFoundError:
            print(f"check_bench: note   {name} skipped ({fname} absent — "
                  "produced only by the nightly scale arm)")
            continue
        for key in path:
            if key not in node:
                sys.exit(f"check_bench: {fname} has no {'.'.join(path)} — "
                         "artifact predates this metric; re-run the sweep")
            node = node[key]
        out[name] = float(node)
    return out


def check(values: dict[str, float], baseline: dict,
          gate_throughput: bool = False) -> int:
    """Compare values against the baseline; returns the exit code."""
    base = baseline["metrics"]
    tol = baseline["tolerance"]
    two_sided = set(baseline.get("two_sided", ()))
    floors = baseline.get("throughput_floors", {})
    failures = []
    if gate_throughput:
        for name in sorted(floors):
            if name not in values:
                failures.append((name, float("nan"), floors[name],
                                 floors[name]))
                print(f"check_bench: FAIL   {name} missing — the nightly "
                      "lane gates it; run the scale arm "
                      "(python -m benchmarks.fleet_sweep --scale) first")
    for name, value in sorted(values.items()):
        if name in TRAJECTORY_ONLY:
            if gate_throughput and name in floors:
                floor = float(floors[name])
                if value < floor:
                    failures.append((name, value, floor, floor))
                    print(f"check_bench: FAIL   {name} = {value:.4f} < "
                          f"absolute floor {floor:.4f} (one-sided "
                          "throughput gate; conservative — this is a "
                          "several-fold slowdown, not noise)")
                else:
                    print(f"check_bench: ok     {name} = {value:.4f} "
                          f"(absolute floor {floor:.4f}, one-sided)")
            else:
                print(f"check_bench: trend  {name} = {value:.4f} "
                      "(trajectory-only; machine-dependent, ungated "
                      "outside the nightly --gate-throughput lane)")
            continue
        if name not in base:
            print(f"check_bench: NEW    {name} = {value:.4f} "
                  "(not in baseline — run --update to start gating it)")
            continue
        b = float(base[name])
        t = float(tol.get(name, baseline.get("default_tolerance", 0.1)))
        floor = b * (1.0 - t)
        ceiling = b * (1.0 + t)
        if value < floor:
            failures.append((name, value, b, floor))
            print(f"check_bench: FAIL   {name} = {value:.4f} < floor "
                  f"{floor:.4f} (baseline {b:.4f}, tolerance {t:.0%})")
        elif value > ceiling and name in two_sided:
            # stability metric, not higher-is-better: drift past the
            # band in either direction is a failure, not an improvement
            failures.append((name, value, b, ceiling))
            print(f"check_bench: FAIL   {name} = {value:.4f} > ceiling "
                  f"{ceiling:.4f} (two-sided; baseline {b:.4f}, "
                  f"tolerance {t:.0%})")
        elif value > ceiling:
            print(f"check_bench: BETTER {name} = {value:.4f} > baseline "
                  f"{b:.4f} +{t:.0%} — consider refreshing the baseline "
                  "(scripts/check_bench.py --update)")
        else:
            print(f"check_bench: ok     {name} = {value:.4f} "
                  f"(baseline {b:.4f}, floor {floor:.4f})")
    if failures:
        names = ", ".join(f[0] for f in failures)
        print(f"check_bench: {len(failures)} regression(s): {names}",
              file=sys.stderr)
        return 1
    print(f"check_bench: ok — {len(values)} metrics within tolerance")
    return 0


def _git_stamp() -> dict:
    """{"sha", "dirty"} of the repo producing this run (nulls outside
    git) — makes every trajectory point provenance-traceable.  One
    implementation, shared with ``benchmarks.run --json``."""
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir))
    from benchmarks.run import git_provenance
    return git_provenance()


def append_trajectory(values: dict[str, float], path: str) -> None:
    """Append one {timestamp, git, metrics} point to the BENCH trend
    series (a JSON object with a ``runs`` list).  The nightly CI lane
    uploads this file with the sweep artifacts, so concatenating the
    per-run uploads yields the benchmark trajectory over time."""
    series = {"description": ("BENCH trajectory: one point per "
                              "check_bench.py run (ratios + provenance), "
                              "appended automatically; uploaded by the "
                              "nightly CI lane as a trend series"),
              "runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded.get("runs"), list):
                series = loaded
        except (OSError, ValueError):
            print(f"check_bench: warning — unreadable trajectory at "
                  f"{path}; starting fresh", file=sys.stderr)
    series["runs"].append({
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "git": _git_stamp(),
        "metrics": {k: round(v, 6) for k, v in sorted(values.items())},
    })
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(series, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"check_bench: trajectory point appended -> {path} "
          f"({len(series['runs'])} runs)")


def update(values: dict[str, float], baseline_path: str,
           old: dict | None) -> None:
    baseline = {
        "description": ("CI benchmark baselines: improvement ratios from "
                        "the fixed-seed CI sweeps; refreshed via "
                        "scripts/check_bench.py --update"),
        "metrics": {k: round(v, 6) for k, v in sorted(values.items())
                    if k not in TRAJECTORY_ONLY},
        "tolerance": (old or {}).get("tolerance", {
            name: 0.1 for name in METRICS if name not in TRAJECTORY_ONLY}),
        "two_sided": (old or {}).get("two_sided",
                                     ["contended_over_uncontended",
                                      "tier0_dlv_overload"]),
        # absolute one-sided floors for the nightly --gate-throughput
        # lane; hand-committed (conservative), never refreshed from a run
        "throughput_floors": (old or {}).get("throughput_floors", {}),
    }
    os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
    with open(baseline_path, "w") as f:
        json.dump(baseline, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"check_bench: baseline updated -> {baseline_path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--artifacts", default="benchmarks/artifacts",
                    help="directory holding the ci_*.json artifacts")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline json path")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from current artifacts")
    ap.add_argument("--trajectory", default=DEFAULT_TRAJECTORY,
                    help="BENCH trend-series json to append each run to")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="skip the trajectory append")
    ap.add_argument("--gate-throughput", action="store_true",
                    help="additionally enforce the baseline's absolute "
                         "throughput_floors (nightly lane; requires the "
                         "scale-arm artifact)")
    args = ap.parse_args(argv)
    values = extract(args.artifacts)
    old = None
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            old = json.load(f)
    if args.update:
        update(values, args.baseline, old)
        return 0
    if not args.no_trajectory:
        # append before gating: the trend series wants regressions too
        append_trajectory(values, args.trajectory)
    if old is None:
        sys.exit(f"check_bench: no baseline at {args.baseline} — commit one "
                 "via scripts/check_bench.py --update")
    return check(values, old, gate_throughput=args.gate_throughput)


if __name__ == "__main__":
    sys.exit(main())
