#!/usr/bin/env python
"""Benchmark-regression gate: compare CI sweep outputs against a committed
baseline with a tolerance band.

The CI benchmark smokes (``scripts/ci.sh``) write JSON artifacts whose
headline metrics are improvement *ratios* — higher is better:

  * ``rr_over_score``     — round-robin UXCost / score-routing UXCost
                            (ci_fleet_sweep.json)
  * ``whole_over_split``  — whole-pipeline UXCost / stage-split UXCost
                            (ci_cascade_split.json)
  * ``tuned_over_static`` — static-weights UXCost / online-tuned UXCost
                            (ci_fleet_sweep.json, drift section)

This script loads the artifacts, extracts those metrics, and fails (exit
nonzero) when any falls below ``baseline * (1 - tolerance)``.  The CI
runs are deterministic (fixed seeds, fixed configs), so drift within the
band can only come from intentional code changes; the band exists so
benign scheduler/router improvements that shuffle placements slightly do
not demand a baseline refresh, while real regressions fail loudly.

Improvements beyond the band are reported (not failed) with a reminder to
refresh the baseline:

    PYTHONPATH=src python scripts/check_bench.py [--artifacts DIR]
    PYTHONPATH=src python scripts/check_bench.py --update   # refresh

``--update`` rewrites the baseline from the current artifacts, preserving
the configured tolerances.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks", "baselines",
                                "ci_baseline.json")

#: metric name -> (artifact file, path inside the artifact json)
METRICS = {
    "rr_over_score": ("ci_fleet_sweep.json", ("rr_over_score",)),
    "whole_over_split": ("ci_cascade_split.json", ("whole_over_split",)),
    "tuned_over_static": ("ci_fleet_sweep.json",
                          ("drift", "tuned_over_static")),
}


def extract(artifacts_dir: str) -> dict[str, float]:
    """Pull every gated metric out of the CI artifacts (all must exist)."""
    out: dict[str, float] = {}
    cache: dict[str, dict] = {}
    for name, (fname, path) in METRICS.items():
        fpath = os.path.join(artifacts_dir, fname)
        if fname not in cache:
            try:
                with open(fpath) as f:
                    cache[fname] = json.load(f)
            except FileNotFoundError:
                sys.exit(f"check_bench: missing artifact {fpath} — run the "
                         "CI benchmark stages first (scripts/ci.sh)")
        node = cache[fname]
        for key in path:
            if key not in node:
                sys.exit(f"check_bench: {fname} has no {'.'.join(path)} — "
                         "artifact predates this metric; re-run the sweep")
            node = node[key]
        out[name] = float(node)
    return out


def check(values: dict[str, float], baseline: dict) -> int:
    """Compare values against the baseline; returns the exit code."""
    base = baseline["metrics"]
    tol = baseline["tolerance"]
    failures = []
    for name, value in sorted(values.items()):
        if name not in base:
            print(f"check_bench: NEW    {name} = {value:.4f} "
                  "(not in baseline — run --update to start gating it)")
            continue
        b = float(base[name])
        t = float(tol.get(name, baseline.get("default_tolerance", 0.1)))
        floor = b * (1.0 - t)
        if value < floor:
            failures.append((name, value, b, floor))
            print(f"check_bench: FAIL   {name} = {value:.4f} < floor "
                  f"{floor:.4f} (baseline {b:.4f}, tolerance {t:.0%})")
        elif value > b * (1.0 + t):
            print(f"check_bench: BETTER {name} = {value:.4f} > baseline "
                  f"{b:.4f} +{t:.0%} — consider refreshing the baseline "
                  "(scripts/check_bench.py --update)")
        else:
            print(f"check_bench: ok     {name} = {value:.4f} "
                  f"(baseline {b:.4f}, floor {floor:.4f})")
    if failures:
        names = ", ".join(f[0] for f in failures)
        print(f"check_bench: {len(failures)} regression(s): {names}",
              file=sys.stderr)
        return 1
    print(f"check_bench: ok — {len(values)} metrics within tolerance")
    return 0


def update(values: dict[str, float], baseline_path: str,
           old: dict | None) -> None:
    baseline = {
        "description": ("CI benchmark baselines: improvement ratios from "
                        "the fixed-seed CI sweeps; refreshed via "
                        "scripts/check_bench.py --update"),
        "metrics": {k: round(v, 6) for k, v in sorted(values.items())},
        "tolerance": (old or {}).get("tolerance", {
            name: 0.1 for name in METRICS}),
    }
    os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
    with open(baseline_path, "w") as f:
        json.dump(baseline, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"check_bench: baseline updated -> {baseline_path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--artifacts", default="benchmarks/artifacts",
                    help="directory holding the ci_*.json artifacts")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline json path")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from current artifacts")
    args = ap.parse_args(argv)
    values = extract(args.artifacts)
    old = None
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            old = json.load(f)
    if args.update:
        update(values, args.baseline, old)
        return 0
    if old is None:
        sys.exit(f"check_bench: no baseline at {args.baseline} — commit one "
                 "via scripts/check_bench.py --update")
    return check(values, old)


if __name__ == "__main__":
    sys.exit(main())
