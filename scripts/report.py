#!/usr/bin/env python
"""Offline run-report generator over exported observability artifacts.

Reads the files :meth:`repro.obs.Obs.export` writes into a directory —
``spans.jsonl``, ``metrics.json``, ``profile.json`` (any subset) — and
renders a markdown report: fleet event timeline, per-SLO-tier DLV
breakdown, pressure-law term attribution for every degrade/reject
decision, the N slowest pipelines explained segment-by-segment via
critical-path extraction, and the hot-loop wall-time table.

    PYTHONPATH=src python -m benchmarks.run --only fleet_sweep \
        --json /tmp/b.json --obs /tmp/obs
    python scripts/report.py /tmp/obs
    python scripts/report.py /tmp/obs -o report.md --paths 5
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.abspath(__file__)), os.pardir, "src"))

from repro.obs import load_jsonl  # noqa: E402
from repro.obs.report import render_report  # noqa: E402


def load_artifacts(obs_dir: str) -> tuple:
    """(records, metrics_snapshot, profile_snapshot), each None if its
    artifact is absent — the renderer degrades per section."""
    records = metrics = profile = None
    spans_path = os.path.join(obs_dir, "spans.jsonl")
    if os.path.exists(spans_path):
        records = load_jsonl(spans_path)
    metrics_path = os.path.join(obs_dir, "metrics.json")
    if os.path.exists(metrics_path):
        with open(metrics_path) as f:
            metrics = json.load(f)
    profile_path = os.path.join(obs_dir, "profile.json")
    if os.path.exists(profile_path):
        with open(profile_path) as f:
            profile = json.load(f)
    return records, metrics, profile


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("obs_dir", help="directory holding spans.jsonl / "
                                    "metrics.json / profile.json")
    ap.add_argument("-o", "--out", default=None,
                    help="write the report here instead of stdout")
    ap.add_argument("--title", default=None,
                    help="report title (defaults to the artifact dir)")
    ap.add_argument("--paths", type=int, default=3,
                    help="how many slowest pipelines to explain")
    ap.add_argument("--timeline-rows", type=int, default=60,
                    help="max rows on the event timeline")
    args = ap.parse_args(argv)
    if not os.path.isdir(args.obs_dir):
        sys.exit(f"report: {args.obs_dir} is not a directory")
    records, metrics, profile = load_artifacts(args.obs_dir)
    if records is None and metrics is None and profile is None:
        sys.exit(f"report: no observability artifacts in {args.obs_dir} "
                 "(expected spans.jsonl / metrics.json / profile.json)")
    text = render_report(records, metrics, profile,
                         title=args.title or f"Run report: {args.obs_dir}",
                         n_paths=args.paths,
                         timeline_rows=args.timeline_rows)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"report: wrote {args.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
