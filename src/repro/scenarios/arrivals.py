"""Arrival-process library for RTMM scenarios.

The simulator historically hard-wired strictly periodic frame arrivals.
Real deployments see jittery sensors, event-driven triggers (Poisson),
bursty on/off traffic (voice activity, MMPP-style), and slow diurnal load
swings.  Each process here implements the small protocol the discrete-event
engines consume:

    start(index, period_s, rng) -> float | None
        Reset internal state and return the absolute time of the first
        arrival (None = the stream never fires).  ``index`` is the model's
        position in the scenario, used only for deterministic phase offsets.

    next_after(t, period_s, rng) -> float | None
        The next arrival strictly after an arrival at ``t``.  ``period_s``
        is passed on every call because phase scripts may retarget FPS
        mid-run; processes must honour the new period from the next
        inter-arrival interval onward.

All stochastic draws come from the ``rng`` handed in by the caller (the
simulator keeps a dedicated arrival generator, separate from the path/
cascade generator, so a recorded trace can be replayed without perturbing
the rest of the stochastic stream).  Every process serializes to a plain
dict via ``to_config`` and back via ``arrival_from_config`` so scenario
specs, fuzzer output, and phase scripts stay JSON-able.

One process instance drives exactly one model stream: ``start`` resets any
internal state, but two streams must not share an instance within a run.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional

_PROCESS_KINDS: dict[str, type] = {}


def _register(cls: type) -> type:
    _PROCESS_KINDS[cls.kind] = cls
    return cls


class ArrivalProcess:
    """Base class: deterministic-phase periodic behaviour by default."""

    kind = "abstract"

    def start(self, index: int, period_s: float, rng) -> Optional[float]:
        raise NotImplementedError

    def next_after(self, t: float, period_s: float, rng) -> Optional[float]:
        raise NotImplementedError

    def to_config(self) -> dict:
        cfg = {"kind": self.kind}
        for f in dataclasses.fields(self):  # type: ignore[arg-type]
            if not f.name.startswith("_"):
                cfg[f.name] = getattr(self, f.name)
        return cfg


def legacy_phase(index: int, period_s: float) -> float:
    """The seed simulator's deterministic de-synchronizing phase offset."""
    return period_s * ((index * 7919) % 97) / 97.0


@_register
@dataclass
class Periodic(ArrivalProcess):
    """Strictly periodic frames — byte-compatible with the legacy engine.

    ``phase_frac`` pins the first arrival at ``phase_frac * period``; the
    default None reproduces the legacy index-hashed phase, so scenarios
    without an explicit arrival process keep their historical schedules.
    """

    kind = "periodic"
    phase_frac: Optional[float] = None

    def start(self, index, period_s, rng):
        if self.phase_frac is None:
            return legacy_phase(index, period_s)
        return self.phase_frac * period_s

    def next_after(self, t, period_s, rng):
        return t + period_s


@_register
@dataclass
class PeriodicJitter(ArrivalProcess):
    """Periodic with per-frame uniform jitter of +/- ``jitter`` * period.

    Intervals are floored at 5% of the period so the stream can never
    collapse into a zero-time burst.
    """

    kind = "periodic_jitter"
    jitter: float = 0.1

    def start(self, index, period_s, rng):
        return float(rng.uniform(0.0, period_s))

    def next_after(self, t, period_s, rng):
        dt = period_s * (1.0 + self.jitter * float(rng.uniform(-1.0, 1.0)))
        return t + max(dt, 0.05 * period_s)


@_register
@dataclass
class Poisson(ArrivalProcess):
    """Memoryless arrivals with mean inter-arrival time = the period.

    ``rate_scale`` multiplies the nominal 1/period rate (e.g. 2.0 doubles
    the offered load without touching the deadline-defining FPS target).
    """

    kind = "poisson"
    rate_scale: float = 1.0

    def _gap(self, period_s, rng):
        return float(rng.exponential(period_s / self.rate_scale))

    def start(self, index, period_s, rng):
        return self._gap(period_s, rng)

    def next_after(self, t, period_s, rng):
        return t + self._gap(period_s, rng)


@_register
@dataclass
class BurstyOnOff(ArrivalProcess):
    """Two-state MMPP: Poisson bursts at ``burst_factor``/period while ON,
    silence while OFF.  State holding times are exponential with means
    ``on_s`` / ``off_s``.  Mean rate ~ (on/(on+off)) * burst_factor / period,
    so the defaults roughly preserve the nominal FPS while clustering it.
    """

    kind = "bursty"
    on_s: float = 0.5
    off_s: float = 0.5
    burst_factor: float = 2.0

    def __post_init__(self):
        self._on = True
        self._switch_t = 0.0

    def start(self, index, period_s, rng):
        self._on = bool(rng.random() < self.on_s / (self.on_s + self.off_s))
        hold = self.on_s if self._on else self.off_s
        self._switch_t = float(rng.exponential(hold))
        return self.next_after(0.0, period_s, rng)

    def next_after(self, t, period_s, rng):
        cur = t
        for _ in range(10_000):  # bounded walk; rates are all finite
            if self._on:
                gap = float(rng.exponential(period_s / self.burst_factor))
                if cur + gap <= self._switch_t:
                    return cur + gap
                cur = self._switch_t
                self._on = False
                self._switch_t = cur + float(rng.exponential(self.off_s))
            else:
                cur = self._switch_t
                self._on = True
                self._switch_t = cur + float(rng.exponential(self.on_s))
        return None  # pragma: no cover — degenerate parameters


@_register
@dataclass
class Diurnal(ArrivalProcess):
    """Non-homogeneous Poisson with a sinusoidal rate: thinning against
    rate(t) = (1 + amplitude * sin(2*pi*(t/day_s + phase))) / period.

    ``day_s`` is the full load cycle (compressed to seconds for simulation);
    amplitude in [0, 1).  Models millions-of-users scale diurnal traffic.
    """

    kind = "diurnal"
    amplitude: float = 0.8
    day_s: float = 8.0
    phase: float = 0.0

    def _rate(self, t: float, period_s: float) -> float:
        s = math.sin(2.0 * math.pi * (t / self.day_s + self.phase))
        return (1.0 + self.amplitude * s) / period_s

    def next_after(self, t, period_s, rng):
        rate_max = (1.0 + self.amplitude) / period_s
        cur = t
        for _ in range(100_000):
            cur += float(rng.exponential(1.0 / rate_max))
            if float(rng.random()) * rate_max <= self._rate(cur, period_s):
                return cur
        return None  # pragma: no cover

    def start(self, index, period_s, rng):
        return self.next_after(0.0, period_s, rng)


@_register
@dataclass
class Triggered(ArrivalProcess):
    """No autonomous arrivals: frames come only from an external driver.

    Used by the fleet layer for cascade stages split away from their head —
    the parent stage lives on another node, so frames are injected through
    ``Simulator.inject_arrival`` when cross-node triggers land, never
    self-scheduled.  ``start``/``next_after`` therefore always return None
    and consume no randomness.
    """

    kind = "triggered"

    def start(self, index, period_s, rng):
        return None

    def next_after(self, t, period_s, rng):
        return None


def arrival_from_config(cfg: dict) -> ArrivalProcess:
    """Materialize a process from its ``to_config`` dict."""
    d = dict(cfg)
    kind = d.pop("kind")
    try:
        cls = _PROCESS_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown arrival process kind: {kind!r}") from None
    return cls(**d)


def arrival_kinds() -> tuple[str, ...]:
    return tuple(sorted(_PROCESS_KINDS))
