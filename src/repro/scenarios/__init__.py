"""Scenario engine: declarative builders, arrival processes, phase scripts,
trace record/replay, and a seeded scenario fuzzer.

This package is the single source of RTMM workload definitions: the five
Table-3 scenarios live in :mod:`.registry` (``repro.core.workloads``
delegates here), arbitrary new scenarios compose via
:class:`.builder.ScenarioBuilder`, and the simulator / serving engine
consume the same :class:`.trace.Trace` format for exact replay.
"""
from .arrivals import (ArrivalProcess, BurstyOnOff, Diurnal, Periodic,
                       PeriodicJitter, Poisson, Triggered,
                       arrival_from_config, arrival_kinds)
from .builder import ModelEntry, ModelRef, ScenarioBuilder, ScenarioError
from .phases import (PhaseAction, PhaseScript, join, join_entry, leave,
                     scale_fps, set_fps, set_trigger_prob)
from .trace import (Trace, TraceRecorder, dumps, load_trace, loads,
                    save_trace)
from .fuzzer import (fuzz_many, fuzz_phase_script, fuzz_scenario,
                     signature)
from . import registry

__all__ = [
    "ArrivalProcess", "BurstyOnOff", "Diurnal", "Periodic", "PeriodicJitter",
    "Poisson", "Triggered", "arrival_from_config", "arrival_kinds",
    "ModelEntry", "ModelRef", "ScenarioBuilder", "ScenarioError",
    "PhaseAction", "PhaseScript", "join", "join_entry", "leave", "scale_fps",
    "set_fps", "set_trigger_prob",
    "Trace", "TraceRecorder", "dumps", "load_trace", "loads", "save_trace",
    "fuzz_many", "fuzz_phase_script", "fuzz_scenario", "signature",
    "registry",
]
