"""Declarative scenario builder: compose RTMM pipelines from zoo models.

A scenario is described as data — zoo model references, FPS targets,
cascade dependencies, and optional arrival processes — then materialized
into the immutable :class:`repro.core.types.Scenario` the simulator and
serving engine consume.  Because the description is plain data, scenarios
round-trip through JSON (``to_config`` / ``from_config``), which is what
the registry, the fuzzer, phase-script ``join`` actions, and the fleet's
stream sharding build on.

Invariants enforced by ``validate()``: model names are unique within a
scenario, FPS targets are positive, trigger probabilities lie in [0, 1],
and cascade dependencies only reference *earlier* entries (forward-only —
which is why a pipeline can always be placed head first, and why
cross-pipeline dependencies cannot exist).

    scn = (ScenarioBuilder("kitchen_sink")
           .model("ssd_mnv2", fps=30, name="det", kwargs={"res": 640})
           .model("handpose", fps=30, name="pose", depends_on="det",
                  trigger_prob=0.7)
           .model("kws_res8", fps=15, name="kws",
                  arrival=Poisson())
           .build())
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Union

from repro.core.types import ModelGraph, ModelSpec, Scenario
from repro.core import zoo

from .arrivals import ArrivalProcess, arrival_from_config


class ScenarioError(ValueError):
    """Raised when a scenario description is inconsistent."""


@dataclass(frozen=True)
class ModelRef:
    """A serializable pointer to a zoo model builder.

    ``builder`` is a key of ``zoo.ZOO_BUILDERS``; ``name`` overrides the
    instance name (two pipelines may use the same architecture under
    different names); ``kwargs`` forwards builder parameters (res, patches,
    skip_prob, ...).
    """

    builder: str
    name: Optional[str] = None
    kwargs: dict = field(default_factory=dict)

    def build(self) -> ModelGraph:
        if self.builder not in zoo.ZOO_BUILDERS:
            raise ScenarioError(f"unknown zoo builder: {self.builder!r}")
        # Memoized: one structural build per (builder, kwargs), renamed via
        # dataclasses.replace so the frozen layers tuple keeps one identity
        # fleet-wide (that identity is the costmodel fast-cache key).
        return zoo.build_cached(self.builder, self.name, self.kwargs)

    def to_config(self) -> dict:
        return {"builder": self.builder, "name": self.name,
                "kwargs": dict(self.kwargs)}

    @classmethod
    def from_config(cls, cfg: dict) -> "ModelRef":
        return cls(builder=cfg["builder"], name=cfg.get("name"),
                   kwargs=dict(cfg.get("kwargs", {})))


@dataclass
class ModelEntry:
    """One pipeline stage of a scenario under construction."""

    ref: Union[ModelRef, ModelGraph]
    fps: float
    depends_on: Optional[str] = None
    trigger_prob: float = 0.5
    deadline_factor: Optional[float] = None
    arrival: Union[ArrivalProcess, dict, None] = None

    @property
    def model_name(self) -> str:
        if isinstance(self.ref, ModelGraph):
            return self.ref.name
        if self.ref.name is not None:
            return self.ref.name
        return self.ref.build().name

    def to_spec(self) -> ModelSpec:
        graph = self.ref if isinstance(self.ref, ModelGraph) else self.ref.build()
        arrival = self.arrival
        if isinstance(arrival, dict):
            arrival = arrival_from_config(arrival)
        return ModelSpec(
            model=graph,
            fps=self.fps,
            depends_on=self.depends_on,
            trigger_prob=self.trigger_prob,
            deadline_s=None if self.deadline_factor is None
            else self.deadline_factor / self.fps,
            arrival=arrival,
        )

    def to_config(self) -> dict:
        if isinstance(self.ref, ModelGraph):
            raise ScenarioError(
                f"entry {self.ref.name!r} wraps a raw ModelGraph; only "
                "ModelRef-based entries serialize to config")
        arrival = self.arrival
        if isinstance(arrival, ArrivalProcess):
            arrival = arrival.to_config()
        return {"model": self.ref.to_config(), "fps": self.fps,
                "depends_on": self.depends_on,
                "trigger_prob": self.trigger_prob,
                "deadline_factor": self.deadline_factor,
                "arrival": arrival}

    @classmethod
    def from_config(cls, cfg: dict) -> "ModelEntry":
        return cls(ref=ModelRef.from_config(cfg["model"]), fps=cfg["fps"],
                   depends_on=cfg.get("depends_on"),
                   trigger_prob=cfg.get("trigger_prob", 0.5),
                   deadline_factor=cfg.get("deadline_factor"),
                   arrival=cfg.get("arrival"))


class ScenarioBuilder:
    """Fluent, validating builder for RTMM scenarios."""

    def __init__(self, name: str):
        self.name = name
        self.entries: list[ModelEntry] = []

    def model(self, ref: Union[str, ModelRef, ModelGraph], fps: float, *,
              name: Optional[str] = None, kwargs: Optional[dict] = None,
              depends_on: Optional[str] = None, trigger_prob: float = 0.5,
              deadline_factor: Optional[float] = None,
              arrival: Union[ArrivalProcess, dict, None] = None,
              ) -> "ScenarioBuilder":
        """Append one pipeline stage.  ``ref`` is a zoo builder key, a
        prebuilt :class:`ModelRef`, or (non-serializable) a raw ModelGraph."""
        if isinstance(ref, str):
            ref = ModelRef(builder=ref, name=name, kwargs=dict(kwargs or {}))
        elif name is not None or kwargs is not None:
            raise ScenarioError("name/kwargs only apply to zoo-key refs")
        self.entries.append(ModelEntry(
            ref=ref, fps=fps, depends_on=depends_on, trigger_prob=trigger_prob,
            deadline_factor=deadline_factor, arrival=arrival))
        return self

    def add_genai_stream(self, fps: float, *, name: Optional[str] = None,
                         kwargs: Optional[dict] = None,
                         depends_on: Optional[str] = None,
                         trigger_prob: float = 0.5,
                         deadline_factor: Optional[float] = None,
                         arrival: Union[ArrivalProcess, dict, None] = None,
                         ) -> "ScenarioBuilder":
        """Append an autoregressive chat_llm stage (prefill + stochastic
        per-job decode loop).  Thin sugar over ``model("chat_llm", ...)``;
        ``kwargs`` forwards chat_llm builder parameters (d_model,
        prompt_tokens, max_new_tokens, token_mean, ...)."""
        return self.model("chat_llm", fps, name=name, kwargs=kwargs,
                          depends_on=depends_on, trigger_prob=trigger_prob,
                          deadline_factor=deadline_factor, arrival=arrival)

    # ------------------------------------------------------------ validate
    def validate(self) -> list[str]:
        """All model names for a valid scenario (raises ScenarioError)."""
        if not self.entries:
            raise ScenarioError(f"scenario {self.name!r} has no models")
        names: list[str] = []
        for e in self.entries:
            n = e.model_name
            if n in names:
                raise ScenarioError(f"duplicate model name {n!r}")
            if e.fps <= 0:
                raise ScenarioError(f"{n!r}: fps must be positive, got {e.fps}")
            if not (0.0 <= e.trigger_prob <= 1.0):
                raise ScenarioError(
                    f"{n!r}: trigger_prob {e.trigger_prob} outside [0, 1]")
            if e.depends_on is not None and e.depends_on not in names:
                raise ScenarioError(
                    f"{n!r} depends on {e.depends_on!r}, which is not an "
                    "earlier model of the scenario")
            names.append(n)
        return names

    # --------------------------------------------------------------- build
    def build(self) -> Scenario:
        self.validate()
        return Scenario(name=self.name,
                        models=tuple(e.to_spec() for e in self.entries))

    # ----------------------------------------------------------- serialize
    def to_config(self) -> dict:
        self.validate()
        return {"name": self.name,
                "models": [e.to_config() for e in self.entries]}

    @classmethod
    def from_config(cls, cfg: dict) -> "ScenarioBuilder":
        b = cls(cfg["name"])
        b.entries = [ModelEntry.from_config(m) for m in cfg["models"]]
        return b
