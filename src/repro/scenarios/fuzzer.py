"""Seeded scenario fuzzer: sample valid random RTMM scenarios.

For stress sweeps the registry's hand-built scenarios are not enough — the
scheduler should hold up on *any* plausible combination of pipelines, FPS
targets, cascades, and arrival processes.  ``fuzz_scenario(seed)`` draws a
random-but-valid :class:`ScenarioBuilder`; identical seeds yield identical
scenarios, and every scenario serializes (``to_config``) so interesting
samples can be pinned as regression cases.

``fuzz_phase_script(seed, builder, duration_s)`` optionally layers a random
workload shift (FPS rescale / cascade swing / model departure) on top, to
stress the online adaptivity engine.
"""
from __future__ import annotations

import json
from typing import Optional

import numpy as np

from .arrivals import (ArrivalProcess, BurstyOnOff, Diurnal, Periodic,
                       PeriodicJitter, Poisson)
from .builder import ModelRef, ScenarioBuilder
from . import phases

#: (zoo builder key, builder kwargs) pools.  Heads run standalone streams;
#: children hang off a parent via a cascade dependency.
HEAD_POOL: tuple[tuple[str, dict], ...] = (
    ("fbnet_c", {}),
    ("ssd_mnv2", {"res": 512}),
    ("ssd_mnv2", {"res": 640}),
    ("skipnet", {"res": 448}),
    ("trailnet", {}),
    ("sosnet", {"patches": 144}),
    ("rapid_rl", {}),
    ("googlenet_car", {}),
    ("focal_depth", {}),
    ("ed_tcn", {}),
    ("kws_res8", {}),
    ("ofa", {}),
)
CHILD_POOL: tuple[tuple[str, dict], ...] = (
    ("handpose", {"res": 320}),
    ("handpose", {"res": 288}),
    ("gnmt", {}),
    ("vgg_voxceleb", {}),
    ("sosnet", {"patches": 196}),
    ("googlenet_car", {}),
)
FPS_CHOICES = (5.0, 10.0, 15.0, 30.0, 60.0)


def _sample_arrival(rng: np.random.Generator) -> Optional[ArrivalProcess]:
    kind = rng.integers(0, 6)
    if kind == 0:
        return None                       # legacy strict-periodic default
    if kind == 1:
        return Periodic(phase_frac=round(float(rng.uniform(0.0, 1.0)), 3))
    if kind == 2:
        return PeriodicJitter(jitter=round(float(rng.uniform(0.05, 0.4)), 3))
    if kind == 3:
        return Poisson(rate_scale=round(float(rng.uniform(0.5, 2.0)), 3))
    if kind == 4:
        return BurstyOnOff(
            on_s=round(float(rng.uniform(0.2, 1.0)), 3),
            off_s=round(float(rng.uniform(0.2, 1.0)), 3),
            burst_factor=round(float(rng.uniform(1.5, 4.0)), 3))
    return Diurnal(amplitude=round(float(rng.uniform(0.3, 0.95)), 3),
                   day_s=round(float(rng.uniform(2.0, 12.0)), 3))


def fuzz_scenario(seed: int, max_pipelines: int = 4,
                  cascade_prob: float = 0.5,
                  max_depth: int = 2) -> ScenarioBuilder:
    """Draw one valid random scenario (1..max_pipelines pipelines).

    ``cascade_prob`` is the probability each pipeline grows a cascade
    child (1.0 makes every pipeline a cascade — the population the fleet
    stage-split benchmarks want); ``max_depth`` bounds the cascade chain
    length (2 = head + child, the historical shape).  Defaults consume
    exactly the seed fuzzer's RNG stream, so existing seeds reproduce
    their historical scenarios bit-for-bit.
    """
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, max_pipelines + 1))
    b = ScenarioBuilder(f"fuzz_{seed}")
    for p in range(n):
        hb, hkw = HEAD_POOL[int(rng.integers(0, len(HEAD_POOL)))]
        head = f"{hb}_{p}"
        b.model(ModelRef(hb, name=head, kwargs=dict(hkw)),
                fps=float(FPS_CHOICES[int(rng.integers(0, len(FPS_CHOICES)))]),
                arrival=_sample_arrival(rng))
        parent, depth = head, 1
        while depth < max_depth and rng.random() < cascade_prob:
            cb, ckw = CHILD_POOL[int(rng.integers(0, len(CHILD_POOL)))]
            child = f"{cb}_{p}c" if depth == 1 else f"{cb}_{p}c{depth}"
            b.model(ModelRef(cb, name=child, kwargs=dict(ckw)),
                    fps=float(FPS_CHOICES[int(rng.integers(0, len(FPS_CHOICES)))]),
                    depends_on=parent,
                    trigger_prob=round(float(rng.uniform(0.2, 1.0)), 3))
            parent, depth = child, depth + 1
    b.validate()
    return b


def fuzz_phase_script(seed: int, builder: ScenarioBuilder,
                      duration_s: float) -> phases.PhaseScript:
    """A random mid-run workload shift for the given scenario."""
    rng = np.random.default_rng(seed + 0x5EED)
    t = round(float(rng.uniform(0.3, 0.7)) * duration_s, 3)
    heads = [e.model_name for e in builder.entries if e.depends_on is None]
    children = [e.model_name for e in builder.entries
                if e.depends_on is not None]
    choices = ["scale_fps"]
    if children:
        choices.append("set_trigger_prob")
    if len(heads) > 1:
        choices.append("leave")
    kind = choices[int(rng.integers(0, len(choices)))]
    if kind == "scale_fps":
        action = phases.scale_fps(round(float(rng.uniform(0.5, 2.5)), 3))
    elif kind == "set_trigger_prob":
        action = phases.set_trigger_prob(
            children[int(rng.integers(0, len(children)))],
            round(float(rng.uniform(0.0, 1.0)), 3))
    else:
        action = phases.leave(heads[int(rng.integers(0, len(heads)))])
    return phases.PhaseScript([(t, action)])


def signature(builder: ScenarioBuilder) -> str:
    """Canonical string identity of a scenario (for dedup in sweeps)."""
    cfg = builder.to_config()
    cfg.pop("name", None)       # identity is the structure, not the label
    return json.dumps(cfg, sort_keys=True)


def fuzz_many(n: int, seed0: int = 0, **kw) -> list[ScenarioBuilder]:
    return [fuzz_scenario(seed0 + i, **kw) for i in range(n)]
