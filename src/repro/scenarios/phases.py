"""Phase scripts: timed mutations of a *running* scenario.

DREAM's adaptivity engine exists to survive workload shifts — models
joining and leaving, FPS retargeting, cascade probability swings — but a
static scenario never exercises it.  A :class:`PhaseScript` is an ordered
list of ``(time, PhaseAction)`` pairs the simulator applies as first-class
events, so a single run can sweep through several workload regimes.

Actions are plain data (kind + payload) so scripts serialize into traces
and replay exactly; the simulator re-validates every payload on apply
(traces are hand-editable) and records applied actions in processing
order.  Supported kinds:

    set_fps(model, fps)          retarget one model's FPS (period + deadline)
    scale_fps(factor[, models])  multiply FPS of all (or listed) models
    set_trigger_prob(model, p)   change a cascade's trigger probability
    leave(model)                 stop a model's arrivals / cascade triggers
    join(entry)                  add a new pipeline stage mid-run (a
                                 serializable ModelEntry — zoo ref based)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional, Sequence, Union

from .builder import ModelEntry


@dataclass(frozen=True)
class PhaseAction:
    kind: str
    payload: dict

    def to_config(self) -> dict:
        return {"kind": self.kind, **self.payload}

    @classmethod
    def from_config(cls, cfg: dict) -> "PhaseAction":
        d = dict(cfg)
        return cls(kind=d.pop("kind"), payload=d)


def set_fps(model: str, fps: float) -> PhaseAction:
    if not fps > 0:
        raise ValueError(f"set_fps: fps must be positive, got {fps}")
    return PhaseAction("set_fps", {"model": model, "fps": float(fps)})


def scale_fps(factor: float,
              models: Optional[Sequence[str]] = None) -> PhaseAction:
    if not factor > 0:
        raise ValueError(f"scale_fps: factor must be positive, got {factor}")
    return PhaseAction("scale_fps", {
        "factor": float(factor),
        "models": None if models is None else list(models)})


def set_trigger_prob(model: str, prob: float) -> PhaseAction:
    if not 0.0 <= prob <= 1.0:
        raise ValueError(f"set_trigger_prob: {prob} outside [0, 1]")
    return PhaseAction("set_trigger_prob",
                       {"model": model, "prob": float(prob)})


def leave(model: str) -> PhaseAction:
    return PhaseAction("leave", {"model": model})


def join(entry: ModelEntry) -> PhaseAction:
    """Add a pipeline stage mid-run.  The entry must be ModelRef-based so
    the action (and any trace containing it) stays serializable."""
    return PhaseAction("join", {"entry": entry.to_config()})


def join_entry(action: PhaseAction) -> ModelEntry:
    """Materialize the ModelEntry carried by a ``join`` action."""
    assert action.kind == "join"
    return ModelEntry.from_config(action.payload["entry"])


class PhaseScript:
    """An ordered schedule of scenario mutations."""

    def __init__(self,
                 events: Iterable[tuple[float, PhaseAction]] = ()):
        self.events: list[tuple[float, PhaseAction]] = sorted(
            ((float(t), a) for t, a in events), key=lambda e: e[0])

    def at(self, t: float, action: PhaseAction) -> "PhaseScript":
        self.events.append((float(t), action))
        self.events.sort(key=lambda e: e[0])
        return self

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def to_config(self) -> list[dict]:
        return [{"t": t, "action": a.to_config()} for t, a in self.events]

    @classmethod
    def from_config(cls, cfg: Union[list, dict]) -> "PhaseScript":
        events = cfg["events"] if isinstance(cfg, dict) else cfg
        return cls((e["t"], PhaseAction.from_config(e["action"]))
                   for e in events)
