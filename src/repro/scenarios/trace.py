"""Trace record/replay: seeded generation -> JSONL -> exact replay.

A trace is the complete externally-visible stochastic input of a run: the
head-of-pipeline frame arrivals (dependent models are cascade-triggered
from the engine's own seeded generator and need no recording) plus any
phase-script mutations, in the order the engine processed them.  Replaying
a trace through a simulator constructed with the same seed reproduces the
live run bit-for-bit — same jobs, same dispatches, same UXCost — because
arrival randomness lives on a dedicated generator, separate from the
path-sampling / cascade generator.

JSONL format (one JSON object per line, ``sort_keys`` so identical runs
produce identical bytes):

    {"type": "meta", "version": 1, "scenario": ..., "seed": ..., ...}
    {"type": "arrival", "t": 0.0123, "model": "kws_res8"}
    {"type": "phase", "t": 2.0, "action": {"kind": "scale_fps", ...}}
    {"type": "tokens", "t": 0.0123, "model": "chat_llm", "n": 7}
    {"type": "preempt", "t": 0.5, "model": "chat_llm", "acc": 1}

``tokens`` records an autoregressive job's sampled generation length (a
draw on the simulator's dedicated token stream); replay feeds the draws
back per-model in creation order, so the token stream — like the arrival
stream — is never consumed during replay.  ``preempt`` marks a mid-decode
job yielding its accelerator to another job at a token boundary; it is
informational (replay derives nothing from it).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

TRACE_VERSION = 1


@dataclass
class Trace:
    meta: dict
    events: list[dict] = field(default_factory=list)  # occurrence order

    @property
    def arrivals(self) -> list[tuple[float, str]]:
        return [(e["t"], e["model"]) for e in self.events
                if e["type"] == "arrival"]

    @property
    def phases(self) -> list[tuple[float, dict]]:
        return [(e["t"], e["action"]) for e in self.events
                if e["type"] == "phase"]

    def arrivals_by_model(self) -> dict[str, list[float]]:
        out: dict[str, list[float]] = {}
        for t, m in self.arrivals:
            out.setdefault(m, []).append(t)
        return out

    def tokens_by_model(self) -> dict[str, list[int]]:
        """Recorded generation lengths per model, in creation order."""
        out: dict[str, list[int]] = {}
        for e in self.events:
            if e["type"] == "tokens":
                out.setdefault(e["model"], []).append(int(e["n"]))
        return out


class TraceRecorder:
    """Collects events in engine-processing order during a live run."""

    def __init__(self, meta: dict):
        self.meta = dict(meta)
        self.meta.setdefault("version", TRACE_VERSION)
        self.events: list[dict] = []

    def arrival(self, t: float, model: str) -> None:
        self.events.append({"type": "arrival", "t": float(t), "model": model})

    def phase(self, t: float, action_cfg: dict) -> None:
        self.events.append({"type": "phase", "t": float(t),
                            "action": action_cfg})

    def tokens(self, t: float, model: str, n: int) -> None:
        self.events.append({"type": "tokens", "t": float(t),
                            "model": model, "n": int(n)})

    def preempt(self, t: float, model: str, acc: int) -> None:
        self.events.append({"type": "preempt", "t": float(t),
                            "model": model, "acc": int(acc)})

    def trace(self) -> Trace:
        return Trace(meta=dict(self.meta), events=list(self.events))


def dumps(trace: Trace) -> str:
    lines = [json.dumps({"type": "meta", **trace.meta}, sort_keys=True)]
    lines += [json.dumps(e, sort_keys=True) for e in trace.events]
    return "\n".join(lines) + "\n"


def loads(text: str, *,
          event_kinds: tuple[str, ...] = ("arrival", "phase",
                                          "tokens", "preempt"),
          version: int = TRACE_VERSION) -> Trace:
    """Parse a JSONL trace.  ``event_kinds`` is the set of accepted event
    types — the default is the simulator trace; layered formats (the fleet
    trace of ``repro.cluster``) pass their own kinds and version."""
    meta: dict = {}
    events: list[dict] = []
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        obj = json.loads(line)
        kind = obj.pop("type", None)
        if kind == "meta":
            meta = obj
        elif kind in event_kinds:
            events.append({"type": kind, **obj})
        else:
            raise ValueError(f"trace line {lineno}: unknown type {kind!r}")
    if meta.get("version", version) != version:
        raise ValueError(f"unsupported trace version {meta.get('version')}")
    return Trace(meta=meta, events=events)


def save_trace(trace: Trace, path: str) -> str:
    with open(path, "w") as f:
        f.write(dumps(trace))
    return path


def load_trace(path: str) -> Trace:
    with open(path) as f:
        return loads(f.read())
