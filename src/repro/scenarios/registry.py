"""Scenario registry: named scenario factories, Table 3 included.

The five RTMM scenarios of the paper's Table 3 are registered here as
plain :class:`ScenarioBuilder` instances — no longer special-cased code
paths — next to whatever scenarios users register themselves:

    @register("My_Factory_Floor")
    def _floor(cascade_prob: float = 0.5) -> ScenarioBuilder:
        return (ScenarioBuilder("My_Factory_Floor")
                .model("ssd_mnv2", fps=30, name="det", kwargs={"res": 512})
                .model("sosnet", fps=60, name="track",
                       depends_on="det", trigger_prob=cascade_prob))

``repro.core.workloads`` keeps its historical ``build_scenario`` /
``SCENARIOS`` API by delegating to this module.
"""
from __future__ import annotations

from typing import Callable

from .builder import ScenarioBuilder, ScenarioError
from repro.core.types import Scenario

_FACTORIES: dict[str, Callable[..., ScenarioBuilder]] = {}


def register(name: str):
    """Decorator registering a ``(**kw) -> ScenarioBuilder`` factory."""
    def deco(fn: Callable[..., ScenarioBuilder]):
        _FACTORIES[name] = fn
        return fn
    return deco


def names() -> tuple[str, ...]:
    return tuple(sorted(_FACTORIES))


def get(name: str, **kw) -> ScenarioBuilder:
    try:
        fac = _FACTORIES[name]
    except KeyError:
        raise ScenarioError(
            f"unknown scenario {name!r}; registered: {', '.join(names())}"
        ) from None
    return fac(**kw)


def build(name: str, **kw) -> Scenario:
    return get(name, **kw).build()


# ---------------------------------------------------------------------------
# Table 3 — the paper's five RTMM scenarios as registry instances
# ---------------------------------------------------------------------------


@register("VR_Gaming")
def _vr_gaming(cascade_prob: float = 0.5) -> ScenarioBuilder:
    return (ScenarioBuilder("VR_Gaming")
            .model("fbnet_c", fps=60, name="gaze_fbnet_c")
            .model("ssd_mnv2", fps=30, name="hand_det_ssd",
                   kwargs={"res": 640})
            .model("handpose", fps=30, name="pose_handpose",
                   kwargs={"res": 320}, depends_on="hand_det_ssd",
                   trigger_prob=cascade_prob)
            .model("ofa", fps=30, name="ctx_ofa")
            .model("kws_res8", fps=15, name="kws_res8")
            .model("gnmt", fps=15, name="translate_gnmt",
                   depends_on="kws_res8", trigger_prob=cascade_prob))


@register("AR_Call")
def _ar_call(cascade_prob: float = 0.5) -> ScenarioBuilder:
    return (ScenarioBuilder("AR_Call")
            .model("kws_res8", fps=15, name="kws_res8")
            .model("gnmt", fps=15, name="translate_gnmt",
                   depends_on="kws_res8", trigger_prob=cascade_prob)
            .model("skipnet", fps=30, name="ctx_skipnet",
                   kwargs={"res": 448}))


@register("Drone_Outdoor")
def _drone_outdoor(cascade_prob: float = 0.5) -> ScenarioBuilder:
    del cascade_prob  # no cascaded pipeline in this scenario (Table 3)
    return (ScenarioBuilder("Drone_Outdoor")
            .model("ssd_mnv2", fps=30, name="objdet_ssd", kwargs={"res": 640})
            .model("trailnet", fps=60, name="nav_trailnet")
            .model("sosnet", fps=60, name="vo_sosnet",
                   kwargs={"patches": 144}))


@register("Drone_Indoor")
def _drone_indoor(cascade_prob: float = 0.5) -> ScenarioBuilder:
    del cascade_prob
    return (ScenarioBuilder("Drone_Indoor")
            .model("ssd_mnv2", fps=30, name="objdet_ssd", kwargs={"res": 640})
            .model("rapid_rl", fps=60, name="nav_rapid_rl")
            .model("sosnet", fps=60, name="obst_sosnet",
                   kwargs={"patches": 144})
            .model("googlenet_car", fps=60, name="car_googlenet"))


@register("AR_Social")
def _ar_social(cascade_prob: float = 0.5) -> ScenarioBuilder:
    return (ScenarioBuilder("AR_Social")
            .model("focal_depth", fps=30, name="depth_focal")
            .model("ed_tcn", fps=30, name="action_ed_tcn")
            .model("ssd_mnv2", fps=30, name="face_det_ssd",
                   kwargs={"res": 640})
            .model("vgg_voxceleb", fps=30, name="verif_vggvox",
                   depends_on="face_det_ssd", trigger_prob=cascade_prob)
            .model("ofa", fps=30, name="ctx_ofa"))


TABLE3 = ("VR_Gaming", "AR_Call", "Drone_Outdoor", "Drone_Indoor",
          "AR_Social")


# ---------------------------------------------------------------------------
# Generative-AI scenarios (autoregressive chat_llm job family)
# ---------------------------------------------------------------------------


@register("Chat_Assistant")
def _chat_assistant(cascade_prob: float = 0.5) -> ScenarioBuilder:
    """Mixed interactive assistant: an autoregressive chat head sharing
    the device with a vision pipeline — the paper's dynamic-workload
    stress case for token-level preemption (the fixed-deadline vision
    stream must be able to preempt the chat decode loop mid-generation).
    """
    return (ScenarioBuilder("Chat_Assistant")
            .add_genai_stream(fps=4, name="chat_llm",
                              kwargs={"max_new_tokens": 24,
                                      "token_mean": 10.0})
            .model("ssd_mnv2", fps=30, name="cam_det_ssd",
                   kwargs={"res": 640})
            .model("handpose", fps=30, name="pose_handpose",
                   kwargs={"res": 320}, depends_on="cam_det_ssd",
                   trigger_prob=cascade_prob)
            .model("kws_res8", fps=15, name="kws_res8"))


@register("Voice_Agent")
def _voice_agent(cascade_prob: float = 0.5) -> ScenarioBuilder:
    """Speech-triggered agent: keyword spotting cascades into an
    autoregressive response generator, next to a periodic context model.
    Exercises genai jobs *as cascade tails* (triggered arrivals)."""
    return (ScenarioBuilder("Voice_Agent")
            .model("kws_res8", fps=15, name="kws_res8")
            .add_genai_stream(fps=15, name="reply_llm",
                              kwargs={"max_new_tokens": 16,
                                      "token_mean": 6.0},
                              depends_on="kws_res8",
                              trigger_prob=cascade_prob)
            .model("fbnet_c", fps=30, name="ctx_fbnet_c"))
