import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""HLO profiler for the dry-run: what dominates 'bytes accessed'?

Groups the optimized HLO's buffer traffic by op kind and by shape, so a
§Perf iteration can name the tensor it is about to shrink.

    PYTHONPATH=src python -m repro.launch.inspect_hlo \
        --arch qwen1.5-4b --shape train_4k --top 25
"""
import argparse
import collections
import re

from repro.configs import ARCH_IDS, SHAPES
from repro.launch.dryrun import lower_cell, _shape_bytes
from repro.launch.mesh import make_production_mesh

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+ = (?P<rtype>\([^)]*\)|\S+)\s+"
    r"(?P<op>[\w-]+)\(")


def analyze(hlo: str, top: int = 20):
    by_op = collections.Counter()
    by_shape = collections.Counter()
    count_op = collections.Counter()
    for line in hlo.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        if op in ("parameter", "constant", "tuple", "get-tuple-element"):
            continue
        b = _shape_bytes(m.group("rtype"))
        if b <= 0:
            continue
        by_op[op] += b
        count_op[op] += 1
        if b > (1 << 20):
            by_shape[f"{m.group('rtype')[:60]} {op}"] += b
    print("top ops by result bytes (per-device, summed over instrs):")
    for op, b in by_op.most_common(top):
        print(f"  {op:>28s} {b/1e9:10.2f} GB  x{count_op[op]}")
    print("top individual shapes:")
    for sh, b in by_shape.most_common(top):
        print(f"  {b/1e9:10.2f} GB  {sh}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod"])
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    res = lower_cell(args.arch, args.shape, mesh, remat=args.remat,
                     verbose=True, return_hlo=True)
    print("terms:", {k: round(v, 4) for k, v in res["terms_s"].items()})
    print("collectives:", {k: round(v / 1e9, 3)
                           for k, v in res["collective_bytes_per_dev"].items()})
    analyze(res["hlo_text"], top=args.top)


if __name__ == "__main__":
    main()
