"""Serving entry point: a multi-model RTMM workload on the serving engine.

Registers a set of reduced-config models as concurrent FPS streams (with a
cascade dependency and Supernet variants), builds heterogeneous virtual
accelerator slices, and runs the DREAM-dispatch engine in real time.

    PYTHONPATH=src python -m repro.launch.serve --duration 10
"""
from __future__ import annotations

import argparse
import dataclasses
import functools

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import model as M
from repro.serving import (ModelHandle, RequestQueue, ServingEngine,
                           VirtualAccelerator)


def build_handle(arch: str, name: str, *, layers: int | None = None,
                 d_model: int | None = None, seed: int = 0) -> ModelHandle:
    cfg = smoke_config(arch)
    upd = {"vocab_size": 128, "scan_layers": False}
    if layers:
        upd["num_layers"] = layers
    if d_model:
        upd["d_model"] = d_model
        upd["d_ff"] = 2 * d_model
    cfg = dataclasses.replace(cfg, **upd)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)

    @functools.partial(jax.jit)
    def fn(p, tokens):
        logits, _ = M.forward(p, cfg, tokens)
        return logits

    return ModelHandle(name=name, cfg=cfg, params=params, fn=fn)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=8.0)
    ap.add_argument("--no-drop", action="store_true")
    ap.add_argument("--no-supernet", action="store_true")
    ap.add_argument("--no-adapt", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # heterogeneous 3-slice system (a big fast slice + two small efficient)
    accs = [
        VirtualAccelerator("big0", speed=1.0, power=1.0),
        VirtualAccelerator("small0", speed=0.45, power=0.4),
        VirtualAccelerator("small1", speed=0.45, power=0.4),
    ]
    engine = ServingEngine(
        accs, adaptivity=not args.no_adapt, frame_drop=not args.no_drop,
        supernet_switch=not args.no_supernet, seed=args.seed)

    # model set: detector -> verifier cascade + context supernet + kws
    det = build_handle("gemma-2b", "detector", layers=2)
    verif = build_handle("qwen1.5-4b", "verifier", layers=2)
    ctx = build_handle("gemma2-2b", "context", layers=4)
    ctx_v1 = build_handle("gemma2-2b", "context@v1", layers=2)
    ctx.supernet = ("context@v1",)
    kws = build_handle("mamba2-130m", "kws", layers=2)

    # calibrate every model with its stream shape (avoids recompiles at
    # dispatch time that would poison the wall-clock accounting)
    calib32 = np.zeros((1, 32), np.int32)
    calib16 = np.zeros((1, 16), np.int32)
    for h in (det, verif, ctx, ctx_v1):
        engine.register(h, calib32)
    engine.register(kws, calib16)

    q = RequestQueue(clock=lambda: 0.0)
    q.add_stream("detector", fps=8, batch=1, seq=32, vocab=128,
                 deadline_frac=1.0)
    q.add_stream("verifier", fps=8, batch=1, seq=32, vocab=128,
                 depends_on="detector", trigger_prob=0.5)
    q.add_stream("context", fps=4, batch=1, seq=32, vocab=128)
    q.add_stream("kws", fps=12, batch=1, seq=16, vocab=128)

    report = engine.run(q, duration_s=args.duration)
    print("[serve]", report.summary())
    for name, st in sorted(report.per_model.items()):
        print(f"[serve]   {name:>12s} frames={st['frames']:4d} "
              f"violated={st['violated']:4d} energy={st['energy']:.3f}")
    print(f"[serve] final (alpha, beta) = "
          f"({report.alpha:.2f}, {report.beta:.2f})")


if __name__ == "__main__":
    main()
