"""Production mesh construction + per-(arch, mesh) sharding rule resolution.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before first jax init; tests import this module with 1 device.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4; Auto is that jax's only behaviour
    from jax.sharding import AxisType
except ImportError:
    AxisType = None

from repro.configs import ArchConfig, ShapeCell
from repro.distributed.sharding import DEFAULT_RULES, adapt_rules_for


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: a leading
    'pod' axis of 2 = 512 chips; FSDP state shards over (pod, data)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def rules_for_mesh(mesh: Mesh, base: Optional[dict] = None) -> dict:
    """Specialize the logical-axis rule table to the mesh's axis names
    (single-pod meshes have no 'pod' axis; drop it from composite rules)."""
    base = dict(DEFAULT_RULES if base is None else base)
    names = set(mesh.axis_names)
    out = {}
    for k, v in base.items():
        if v is None:
            out[k] = None
        elif isinstance(v, str):
            out[k] = v if v in names else None
        else:
            kept = tuple(a for a in v if a in names)
            out[k] = kept if len(kept) > 1 else (kept[0] if kept else None)
    return out


def _axis_size(mesh: Mesh, rule) -> int:
    if rule is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(rule, str):
        return sizes.get(rule, 1)
    n = 1
    for a in rule:
        n *= sizes.get(a, 1)
    return n


def rules_for(cfg: ArchConfig, mesh: Mesh, cell: Optional[ShapeCell] = None,
              base: Optional[dict] = None) -> dict:
    """Mesh- and architecture-aware rule table.

    Degrades any rule whose tensor dimension is not divisible by its mesh
    axes (MQA kv heads, odd vocab sizes, batch=1 long-context cells), and
    re-targets the freed capacity where it helps:
      * kv_heads unshardable on 'model'  -> shard the KV cache on kv_seq
        instead (GSPMD partitions the decode softmax over sequence —
        flash-decode style — so long caches still spread over the mesh).
      * batch unshardable (long_500k b=1) -> shard activations on act_seq.
    """
    rules = rules_for_mesh(mesh, base)
    d_inner = cfg.ssm_expand * cfg.d_model if cfg.ssm_state else cfg.d_ff
    dim_of = {
        "heads": cfg.num_heads or 1,
        "kv_heads": cfg.num_kv_heads or 1,
        "act_heads": (cfg.ssm_heads if cfg.is_attention_free
                      else cfg.num_heads) or 1,
        "act_kv_heads": cfg.num_kv_heads or 1,
        "ffn": (min(cfg.d_ff, d_inner) if cfg.d_ff else d_inner),
        "ssm_inproj": (2 * d_inner + 2 * cfg.ssm_state + cfg.ssm_heads
                       if cfg.ssm_state else 1 << 30),
        "experts": cfg.num_experts or 1,
        "vocab": cfg.vocab_size,
        "vocab_out": cfg.vocab_size,
        "fsdp": cfg.d_model,
    }
    if cell is not None:
        dim_of["batch"] = cell.global_batch
    rules = adapt_rules_for(rules, mesh, dim_of)

    model_sz = _axis_size(mesh, "model")
    # attention logits: shard q rows over 'model' when the head count does
    # not divide it (context parallelism — rows of a causal softmax are
    # independent, so this is collective-free for the softmax itself)
    if (cell is not None and rules.get("act_heads") is None
            and model_sz > 1 and cell.seq_len % model_sz == 0):
        rules["act_seq_q"] = "model"
    else:
        rules.setdefault("act_seq_q", None)
    if cell is not None:
        # KV cache: prefer head sharding; fall back to sequence sharding
        if (rules.get("act_kv_heads") is None and model_sz > 1
                and cell.seq_len % model_sz == 0):
            rules["kv_seq"] = "model"
        else:
            rules["kv_seq"] = None
        # batch=1 cells: push the parallelism into the sequence dim
        if rules.get("batch") is None:
            data_rule = rules_for_mesh(mesh, base).get("fsdp")
            if data_rule is not None and cell.seq_len % _axis_size(
                    mesh, data_rule) == 0:
                rules["kv_seq"] = data_rule
            if cell.kind != "decode" and cell.seq_len % model_sz == 0:
                rules["act_seq"] = "model"
    rules.setdefault("act_seq", None)
    rules.setdefault("kv_seq", None)
    return rules
