import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**specs).compile()`` must succeed on the
single-pod (16, 16) mesh and the (2, 16, 16) multi-pod mesh for every
assigned architecture x input-shape cell, and the compiled artifact yields
the roofline terms (memory_analysis / cost_analysis / collective bytes
parsed from the optimized HLO).

The two lines above MUST precede any other import: jax locks the device
count at first init, and the production mesh needs 512 placeholder host
devices. Nothing else in the repo sets this flag (tests and benches see
the real single CPU device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
        --shape train_4k --mesh single                           # one cell
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, ArchConfig, SHAPES, ShapeCell,
                           cell_applicable, get_config)
from repro.data.pipeline import batch_spec
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import model as M
from repro.training import TrainConfig, OptimConfig, build_train_step

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "benchmarks", "artifacts",
                            "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?P<rtype>\([^)]*\)|[a-z0-9\[\],{}\s]+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        bpe = _DTYPE_BYTES.get(m.group("dt"))
        if bpe is None:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * bpe
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes moved by each collective family, from optimized HLO.

    Approximation: one traversal of the result bytes per op (ring algorithms
    move ~2x for all-reduce; -start ops' tuple types double-count the input
    alias, so tuples take the max element instead of the sum).
    """
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        rtype = m.group("rtype").strip()
        if rtype.startswith("("):
            parts = [p for p in rtype.strip("()").split(",")]
            b = max((_shape_bytes(p) for p in parts), default=0)
        else:
            b = _shape_bytes(rtype)
        op = m.group("op")
        out[op] = out.get(op, 0.0) + float(b)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ---------------------------------------------------------------------------
# step functions + input specs per cell kind
# ---------------------------------------------------------------------------


def serve_step(cfg: ArchConfig, rules: dict):
    """One decode step: new token against a seq_len KV cache."""
    constrain = lambda x, lg: shd.constrain(x, lg, rules)

    def fn(params, tokens, cache, pos):
        return M.decode_step(params, cfg, tokens, cache, pos, constrain)

    return fn


def prefill_step(cfg: ArchConfig, rules: dict):
    constrain = lambda x, lg: shd.constrain(x, lg, rules)

    def fn(params, tokens, cache, frontend=None):
        return M.prefill(params, cfg, tokens, cache, frontend, constrain)

    return fn


def input_specs(arch: str, shape: str, cfg: Optional[ArchConfig] = None
                ) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn
    (weak-type-correct, shardable, no device allocation)."""
    cfg = cfg if cfg is not None else get_config(arch)
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    specs: dict[str, Any] = {}
    if cell.kind == "train":
        specs.update(batch_spec(b, s))
        if cfg.frontend:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    elif cell.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["cache"] = M.cache_spec(cfg, b, s)
        if cfg.frontend:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        specs["cache"] = M.cache_spec(cfg, b, s)
        specs["pos"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    return specs


def params_spec(cfg: ArchConfig) -> Any:
    return jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0))


def model_flops(cfg: ArchConfig, cell: ShapeCell, pspec: Any) -> float:
    """6*N*D (train) / 2*N*D (serve) with N = active params, D = tokens.

    N is counted exactly from the parameter spec tree; MoE expert weights
    are scaled by top_k / num_experts (only routed experts are active).
    """
    total = active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(pspec)[0]:
        keys = [getattr(p, "key", str(p)) for p in path]
        size = float(leaf.size)
        total += size
        if cfg.num_experts and "moe" in keys and any(
                k in ("wi", "wg", "wo") for k in keys):
            size *= cfg.num_experts_per_tok / cfg.num_experts
        active += size
    if cell.kind == "train":
        return 6.0 * active * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * active * cell.global_batch * cell.seq_len
    return 2.0 * active * cell.global_batch     # decode: one token per seq


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape: str, mesh, *, remat: str = "dots",
               rules_override: Optional[dict] = None,
               verbose: bool = True, return_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    cfg = dataclasses.replace(
        cfg, remat=remat if cell.kind == "train" else "none",
        scan_layers=True)
    rules = rules_override or rules_for(cfg, mesh, cell)
    t0 = time.time()

    pspec = params_spec(cfg)
    paxes = M.param_axes(cfg)
    p_shard = shd.tree_shardings(mesh, paxes, rules)

    if cell.kind == "train":
        tcfg = TrainConfig(optim=OptimConfig())
        step = build_train_step(cfg, tcfg, rules)
        state_spec = {
            "params": pspec,
            "opt": {
                "m": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    pspec),
                "v": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    pspec),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            },
        }
        state_shard = {
            "params": p_shard,
            "opt": {"m": p_shard, "v": p_shard,
                    "step": shd.sharding_for(mesh, (), rules)},
        }
        specs = input_specs(arch, shape, cfg)
        batch_shard = {
            "tokens": shd.sharding_for(mesh, ("batch", "act_seq"), rules),
            "labels": shd.sharding_for(mesh, ("batch", "act_seq"), rules),
        }
        if "frontend" in specs:
            batch_shard["frontend"] = shd.sharding_for(
                mesh, ("batch", None, None), rules)
        batch_spec_ = {k: specs[k] for k in batch_shard}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(state_shard, batch_shard),
                out_shardings=(state_shard, None),
            ).lower(state_spec, batch_spec_)
    else:
        specs = input_specs(arch, shape, cfg)
        caxes = M.cache_axes(cfg)
        c_shard = shd.tree_shardings(mesh, caxes, rules)
        tok_shard = shd.sharding_for(mesh, ("batch", None), rules)
        if cell.kind == "prefill":
            step = prefill_step(cfg, rules)
            args = [specs["tokens"], specs["cache"]]
            in_sh = [tok_shard, c_shard]
            if "frontend" in specs:
                args.append(specs["frontend"])
                in_sh.append(shd.sharding_for(mesh, ("batch", None, None),
                                              rules))
            with mesh:
                lowered = jax.jit(
                    step, in_shardings=(p_shard, *in_sh),
                    out_shardings=(None, c_shard),
                ).lower(pspec, *args)
        else:
            step = serve_step(cfg, rules)
            pos_shard = shd.sharding_for(mesh, ("batch",), rules)
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(p_shard, tok_shard, c_shard, pos_shard),
                    out_shardings=(None, c_shard),
                ).lower(pspec, specs["tokens"], specs["cache"],
                        specs["pos"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    n_dev = mesh.devices.size

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll["total"] / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, cell, pspec)
    result = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "mesh_axes": list(mesh.axis_names),
        "n_devices": int(n_dev),
        "kind": cell.kind,
        "flops_per_dev": flops_dev,
        "bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "terms_s": terms,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(flops_dev * n_dev, 1.0),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "remat": cfg.remat,
    }
    if return_hlo:
        result["hlo_text"] = hlo_text
    if verbose:
        print(f"[dryrun] {arch:>24s} {shape:<12s} mesh={result['mesh']:<8s} "
              f"compute={terms['compute_s']*1e3:9.3f}ms "
              f"memory={terms['memory_s']*1e3:9.3f}ms "
              f"coll={terms['collective_s']*1e3:9.3f}ms "
              f"dom={dominant.split('_')[0]:<10s} "
              f"lower+compile={t_lower + t_compile:6.1f}s")
    return result


def run_cells(archs, shapes, meshes, out_dir: str = ARTIFACT_DIR,
              remat: str = "dots") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        for arch in archs:
            cfg = get_config(arch)
            for shape in shapes:
                if not cell_applicable(cfg, shape):
                    print(f"[dryrun] {arch:>24s} {shape:<12s} SKIP "
                          f"(full-attention arch, see DESIGN.md)")
                    continue
                tag = f"{mesh_name}__{arch}__{shape}"
                path = os.path.join(out_dir, tag + ".json")
                try:
                    res = lower_cell(arch, shape, mesh, remat=remat)
                    res["status"] = "ok"
                except Exception as e:  # noqa: BLE001 — record, keep going
                    res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    print(f"[dryrun] {arch:>24s} {shape:<12s} ERROR {e!r}")
                results.append(res)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--remat", default="dots",
                    choices=["none", "dots", "dots_nobatch", "full"])
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = (["single", "multipod"] if args.mesh == "both"
              else [args.mesh])
    results = run_cells(archs, shapes, meshes, out_dir=args.out,
                        remat=args.remat)
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"[dryrun] {ok}/{len(results)} cells compiled OK")
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
