"""Launcher: production meshes, dry-run lowering, train/serve entry points."""
