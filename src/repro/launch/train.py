"""Training entry point.

On a pod:   python -m repro.launch.train --arch gemma2-2b --steps 10000 \
                --ckpt-dir /ckpts/run1 --model-parallel 16
On the dev box (CPU, reduced config):
            PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
                --smoke --steps 100

Fault tolerance: --resume auto restores the newest checkpoint (atomic,
reshardable — the elastic-restart path); --fail-at N simulates a preemption
at step N so the restart path can be demonstrated end-to-end.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data import SyntheticLMData
from repro.distributed import CompressionConfig, FaultInjector, remesh
from repro.launch.mesh import rules_for_mesh
from repro.training import OptimConfig, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU dev box)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto",
                    choices=["auto", "never", "must"])
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a preemption at this step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 512),
                                  dtype="float32")
    mesh = remesh(model_parallel=args.model_parallel) \
        if len(jax.devices()) > 1 else None
    rules = rules_for_mesh(mesh) if mesh is not None else None

    tcfg = TrainConfig(
        optim=OptimConfig(learning_rate=args.lr, warmup_steps=args.steps // 10,
                          total_steps=args.steps),
        accum=args.accum,
        compression=CompressionConfig() if args.compress_grads else None,
    )
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed)
    trainer = Trainer(
        cfg=cfg, tcfg=tcfg, data=iter(data), ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, mesh=mesh, rules=rules, seed=args.seed,
        fault_injector=(FaultInjector((args.fail_at,))
                        if args.fail_at is not None else None),
    )
    trainer.init_or_resume(resume=args.resume)
    history = trainer.run(args.steps)
    if history:
        print(f"[train] done: step={history[-1]['step']} "
              f"loss={history[-1]['loss']:.4f} "
              f"acc={history[-1]['accuracy']:.3f}")


if __name__ == "__main__":
    main()
