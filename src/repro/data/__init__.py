"""Data pipeline substrate."""
from .pipeline import SyntheticLMData, batch_spec  # noqa: F401
