"""Deterministic synthetic LM data pipeline.

Produces a reproducible Markov-ish token stream (a fixed random transition
table drives next-token structure, so a model can actually reduce loss on
it — pure-uniform tokens would have irreducible loss log V). Batches are
per-host sharded: each host materializes only its slice of the global batch
(shape [global_batch // num_hosts, seq]), matching multi-host jax where
``jax.make_array_from_process_local_data`` assembles the global array.

Determinism: batch i of run (seed) is identical regardless of host count or
restart point — required for exact checkpoint-resume equivalence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.loss import IGNORE


def batch_spec(global_batch: int, seq_len: int) -> dict:
    """ShapeDtypeStructs of one global batch (for dry-run lowering)."""
    return {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }


@dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8        # next-token candidates per state (entropy knob)
    num_hosts: int = 1
    host_id: int = 0

    def __post_init__(self) -> None:
        assert self.global_batch % self.num_hosts == 0
        rng = np.random.default_rng(self.seed)
        # fixed transition structure: state -> `branching` candidate tokens
        self._table = rng.integers(
            0, self.vocab_size, size=(self.vocab_size, self.branching),
            dtype=np.int64)

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def _gen_sequences(self, step: int) -> np.ndarray:
        """[host_batch, seq_len + 1] tokens for global batch index `step`."""
        n = self.host_batch
        # per-(step, global row) independent streams => host-count invariant
        rows = np.arange(n) + self.host_id * n
        out = np.empty((n, self.seq_len + 1), dtype=np.int64)
        for i, r in enumerate(rows):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 65_537 + r)
            toks = np.empty(self.seq_len + 1, dtype=np.int64)
            toks[0] = rng.integers(0, self.vocab_size)
            picks = rng.integers(0, self.branching, size=self.seq_len)
            for t in range(self.seq_len):
                toks[t + 1] = self._table[toks[t], picks[t]]
            out[i] = toks
        return out

    def batch(self, step: int) -> dict[str, np.ndarray]:
        seqs = self._gen_sequences(step)
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def mask_prefix(labels: np.ndarray, n: int) -> np.ndarray:
    """Exclude the first n positions from the loss (prompt masking)."""
    out = labels.copy()
    out[:, :n] = IGNORE
    return out
