"""Elastic scaling + straggler mitigation for 1000+ node deployments.

Elastic re-mesh: when nodes join/leave, the runner rebuilds the mesh from
the surviving device set (largest (data, model) factorization that keeps
the model axis intact), then restores the latest checkpoint against the new
shardings — CheckpointManager arrays carry global shapes, so restore IS the
reshard. Nothing about the model or train-step code changes.

Straggler mitigation: per-step watermark timing. The trainer records step
wall times in a rolling window; a step slower than ``threshold`` x the
rolling median flags a straggler event. On TPU pods the usual response is
preemptive re-slice (swap the slow host out and elastic-restart), which is
exactly the re-mesh + restore path above; the detector provides the signal
and the hook.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import Mesh

Array = jax.Array


def best_mesh_shape(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) grid for a possibly-degraded device count.

    Keeps the model axis at the requested size (weights are sharded over it;
    changing it mid-run would re-tile every matmul) and gives the rest to
    data parallelism. Falls back to shrinking model parallelism only when
    the device count no longer divides.
    """
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    return max(n_devices // mp, 1), mp


def remesh(devices=None, model_parallel: int = 1,
           axis_names: tuple[str, str] = ("data", "model")) -> Mesh:
    devices = jax.devices() if devices is None else devices
    dp, mp = best_mesh_shape(len(devices), model_parallel)
    import numpy as np
    grid = np.asarray(devices[: dp * mp]).reshape(dp, mp)
    return Mesh(grid, axis_names)


@dataclass
class StragglerDetector:
    """Rolling-median step-time watermark."""

    window: int = 32
    threshold: float = 2.0
    min_samples: int = 8
    times: deque = field(default_factory=lambda: deque(maxlen=64))
    events: list = field(default_factory=list)
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> Optional[float]:
        """Record a step; returns the slowdown factor if it straggled."""
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self._t0 = None
        if len(self.times) >= self.min_samples:
            med = sorted(self.times)[len(self.times) // 2]
            if med > 0 and dt > self.threshold * med:
                factor = dt / med
                self.events.append((step, factor))
                self.times.append(dt)
                return factor
        self.times.append(dt)
        return None


@dataclass
class FaultInjector:
    """Deterministic fault-injection hook for integration tests: raises a
    simulated preemption at configured steps. The trainer's recovery path
    (checkpoint -> restart -> resume) is exercised by tests through this."""

    fail_at_steps: tuple[int, ...] = ()

    def check(self, step: int) -> None:
        if step in self.fail_at_steps:
            raise SimulatedPreemption(step)


class SimulatedPreemption(RuntimeError):
    def __init__(self, step: int):
        super().__init__(f"simulated preemption at step {step}")
        self.step = step
