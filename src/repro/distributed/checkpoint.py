"""Fault-tolerant checkpointing: atomic, shard-per-host, reshard-on-restore.

Layout of one checkpoint:

    <dir>/step_000042/
        MANIFEST.json          # step, tree structure, shapes/dtypes, checksums
        shard_00000.npz        # this host's addressable shard data

Guarantees
----------
* **Atomicity** — written to ``step_X.tmp-<nonce>`` then ``os.rename``d;
  a crash mid-write never corrupts the latest valid checkpoint, and
  ``latest_step`` only ever sees complete directories.
* **Resharding** — arrays are saved with their *global* shape; restore
  device_puts each array against the *target* sharding (any mesh shape /
  axis layout), so a 512-chip checkpoint restores onto 256 chips or onto a
  re-sliced elastic mesh unchanged. This is the elastic-restart path.
* **Integrity** — per-array CRC32 in the manifest, verified on load.
* **Retention** — ``keep`` most recent checkpoints are retained; older ones
  are garbage-collected after a successful save (never before).

Single-host CPU runs exercise the same code path the multi-host launcher
uses (every host writes its addressable shards; host 0 writes the manifest).
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    if isinstance(tree, dict):
        out: dict[str, Any] = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
        return out
    return {prefix.rstrip(SEP): tree}


def _unflatten(flat: dict[str, Any]) -> Any:
    tree: dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- paths
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.dir, name)
                if os.path.exists(os.path.join(path, "MANIFEST.json")):
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        flat = _flatten(tree)
        host = jax.process_index()
        nonce = f"{os.getpid()}-{int(time.time() * 1e6) & 0xFFFFFF:x}"
        final = self._step_dir(step)
        tmp = f"{final}.tmp-{nonce}"
        os.makedirs(tmp, exist_ok=True)

        manifest: dict[str, Any] = {
            "step": step, "format": 1, "extra": extra or {}, "arrays": {}}
        shard: dict[str, np.ndarray] = {}
        for key, val in flat.items():
            arr = np.asarray(jax.device_get(val))
            shard[key] = arr
            manifest["arrays"][key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()),
            }
        np.savez(os.path.join(tmp, f"shard_{host:05d}.npz"), **shard)
        if host == 0:
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
        if os.path.exists(final):            # idempotent re-save of a step
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # clean up orphaned tmp dirs from crashed writers
        for name in os.listdir(self.dir):
            if ".tmp-" in name:
                age = time.time() - os.path.getmtime(
                    os.path.join(self.dir, name))
                if age > 3600:
                    shutil.rmtree(os.path.join(self.dir, name),
                                  ignore_errors=True)

    # ----------------------------------------------------------- restore
    def restore(self, step: Optional[int] = None,
                shardings: Optional[Any] = None,
                ) -> tuple[int, Any, dict]:
        """Load a checkpoint; device_put against ``shardings`` if given
        (a pytree of NamedSharding matching the saved tree) — this is where
        resharding onto a different mesh happens."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._step_dir(step)
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        host = jax.process_index()
        with np.load(os.path.join(path, f"shard_{host:05d}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        for key, meta in manifest["arrays"].items():
            arr = flat[key]
            if zlib.crc32(arr.tobytes()) != meta["crc32"]:
                raise IOError(f"checksum mismatch for {key} in {path}")
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return step, tree, manifest.get("extra", {})
