"""Logical-axis sharding: the single place where parallelism policy lives.

Every parameter and activation in the model stack is annotated with *logical*
axis names ("batch", "embed", "heads", "experts", ...). A rule table maps the
logical names onto physical mesh axes — swapping the table re-shards the whole
model (DP / FSDP / TP / EP / SP) without touching model code.

The production mesh axes (launch/mesh.py):
  pod    — across pods (slow inter-pod links)
  data   — data parallel / FSDP within a pod
  model  — tensor / expert / sequence parallel

Rules are (logical_axis -> mesh axis | tuple | None). ``None`` = replicated.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Optional[Any]  # str | tuple[str, ...] | None

#: Default rule table: FSDP over (pod, data), TP/EP/SP over model.
DEFAULT_RULES: dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "act_seq": None,            # sequence-parallel activations (long ctx)
    "act_seq_q": None,          # attention-logits q rows (context parallel)
    "kv_seq": None,             # KV-cache sequence axis (decode SP fallback)
    "embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_ffn": "model",
    "act_experts": "model",
    "vocab_out": "model",
    # parameters
    "fsdp": ("pod", "data"),    # the FSDP-sharded param axis (usually embed)
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "ssm_inproj": "model",      # fused mamba in_proj output columns
    "ffn_noshard": None,        # per-expert hidden (EP shards experts instead)
    "experts": "model",
    "vocab": "model",
    "layers": None,             # stacked (scanned) layer axis
    "ssm_state": None,
    "conv_kernel": None,
    "head_dim": None,
}


def spec_for(logical: Sequence[Optional[str]],
             rules: Mapping[str, MeshAxes] | None = None) -> P:
    """PartitionSpec for a tuple of logical axis names (None = replicated)."""
    rules = DEFAULT_RULES if rules is None else rules
    return P(*(rules.get(ax) if ax is not None else None for ax in logical))


def sharding_for(mesh: Mesh, logical: Sequence[Optional[str]],
                 rules: Mapping[str, MeshAxes] | None = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical, rules))


def constrain(x, logical: Sequence[Optional[str]],
              rules: Mapping[str, MeshAxes] | None = None):
    """with_sharding_constraint by logical names; no-op outside a mesh.

    jax resolves a bare PartitionSpec against the context mesh (``with
    mesh:``) and raises RuntimeError when there is none — which is exactly
    the single-device test/smoke path, where the constraint is meaningless.
    """
    spec = spec_for(logical, rules)
    if all(s is None for s in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (RuntimeError, ValueError, TypeError):
        return x


def tree_specs(logical_tree: Any, rules: Mapping[str, MeshAxes] | None = None):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda lg: spec_for(lg, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(mesh: Mesh, logical_tree: Any,
                   rules: Mapping[str, MeshAxes] | None = None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(logical_tree, rules))


# -- divisibility-aware rule adaptation --------------------------------------

def adapt_rules_for(rules: Mapping[str, MeshAxes], mesh: Mesh,
                    dim_of: Mapping[str, int]) -> dict[str, MeshAxes]:
    """Drop mesh axes a tensor dimension cannot be divided over.

    ``dim_of`` maps logical axis name -> concrete dimension size for this
    model (e.g. {"kv_heads": 1} for an MQA model). Any rule whose dimension
    is not divisible by the product of its mesh-axis sizes is degraded to
    replication, so the same rule table serves every architecture.
    """
    out = dict(rules)
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    for name, dim in dim_of.items():
        axes = out.get(name)
        if axes is None:
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        prod = 1
        for a in ax_tuple:
            prod *= axis_size.get(a, 1)
        if dim % prod != 0:
            out[name] = None
    return out
