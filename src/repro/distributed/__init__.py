"""Distributed substrate: sharding rules, checkpointing, compression,
elastic re-mesh + straggler detection."""
from .sharding import (DEFAULT_RULES, adapt_rules_for, constrain,  # noqa
                       sharding_for, spec_for, tree_shardings, tree_specs)
from .checkpoint import CheckpointManager  # noqa: F401
from .compression import (CompressionConfig, compress_with_feedback,  # noqa
                          init_error_state)
from .elastic import (FaultInjector, SimulatedPreemption,  # noqa: F401
                      StragglerDetector, best_mesh_shape, remesh)
