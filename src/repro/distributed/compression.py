"""Gradient compression with error feedback (int8 quantized all-reduce).

At 1000+ node scale the gradient reduce-scatter over the slow pod axis is
often the step-time ceiling. Int8 block quantization cuts those bytes 4x
(fp32 grads) while error feedback (residual carried to the next step) keeps
the optimizer trajectory unbiased — the standard 1-bit-Adam/EF-SGD recipe.

The compressor is a pure function over the grad pytree so it composes with
jit/pjit: quantize -> dequantize happens *before* the (sharded) optimizer
update; XLA then all-reduces the int8 representation where the sharding
allows. State (residuals) shards exactly like the gradients.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class CompressionConfig:
    block: int = 256          # quantization group size (per-block scales)
    enabled: bool = True


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_dequant(g: Array, block: int) -> Array:
    """Simulated int8 block quantization (quant->dequant round trip).

    On real hardware the int8 representation is what crosses the wire; the
    round trip here reproduces its exact value loss so error feedback and
    convergence behaviour are faithful.
    """
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.reshape(-1)[:n].reshape(g.shape)


def compress_with_feedback(grads: Any, err: Any, cfg: CompressionConfig
                           ) -> tuple[Any, Any]:
    """Returns (compressed grads, new error state)."""
    if not cfg.enabled:
        return grads, err

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q = _quant_dequant(g32, cfg.block)
        return q, g32 - q

    pairs = jax.tree.map(one, grads, err)
    comp = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_err


def compressed_bytes(params: Any, cfg: CompressionConfig) -> tuple[int, int]:
    """(bytes on the wire with compression, without) — for the §Perf napkin."""
    n = sum(p.size for p in jax.tree.leaves(params))
    scales = sum((p.size + cfg.block - 1) // cfg.block * 4
                 for p in jax.tree.leaves(params))
    return n + scales, n * 4
