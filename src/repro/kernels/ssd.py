"""Mamba2 SSD (state-space duality) chunked-scan kernel (Pallas / TPU).

The SSD insight: the selective-state recurrence

    h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t^T        y_t = C_t h_t + D x_t

decomposes into (i) an intra-chunk part that is a masked, decay-weighted
attention-like matmul (MXU-friendly: [L, L] x [L, P]) and (ii) an
inter-chunk state carry at chunk granularity (a [N, P] state per head).
This trades the sequential length-S scan for S/L sequential steps of dense
[L,·] matmuls — exactly the restructuring TPU wants (long vector scans are
VPU-serial; chunk matmuls hit the MXU).

Kernel layout: grid (batch, head, chunk), chunk innermost/sequential; the
running [N, P] state lives in VMEM scratch across chunk steps. B/C are
shared across heads (G=1), so their tiles are indexed by (batch, chunk)
only; the compiler keeps them resident across the head loop... heads are
the second grid axis, so B/C tiles revisit — acceptable: N is small (64-128)
and the x/y tiles dominate VMEM.

All math in fp32 (the recurrence is exp-weighted; bf16 decays drift).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the TPU params under the old TPUCompilerParams name
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

Array = jax.Array


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
    y_ref, fin_ref,
    state_scr,
    *,
    chunk: int,
):
    cb = pl.program_id(2)
    ncb = pl.num_programs(2)

    @pl.when(cb == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [L]
    a = a_ref[0].astype(jnp.float32)                 # scalar (this head)
    bmat = b_ref[0, :, :].astype(jnp.float32)        # [L, N]
    cmat = c_ref[0, :, :].astype(jnp.float32)        # [L, N]
    dd = d_ref[0].astype(jnp.float32)                # scalar

    la = a * dt                                      # [L] log-decays (<= 0)
    cum = jnp.cumsum(la)                             # inclusive

    # intra-chunk: y_i = sum_{j<=i} exp(cum_i - cum_j) (C_i . B_j) dt_j x_j
    seg = jnp.exp(cum[:, None] - cum[None, :])       # [L, L]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(ii >= jj, seg, 0.0)
    m = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [L, L]
    m = m * seg * dt[None, :]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [L, P]

    # inter-chunk: y_i += exp(cum_i) * C_i @ state_in
    state = state_scr[...]                           # [N, P]
    y_in = jax.lax.dot_general(cmat, state, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    y = y + jnp.exp(cum)[:, None] * y_in + dd * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: state' = exp(cum_L) state + sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
    w = jnp.exp(cum[-1] - cum) * dt                  # [L]
    upd = jax.lax.dot_general(bmat * w[:, None], x,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [N, P]
    state_scr[...] = jnp.exp(cum[-1]) * state + upd

    @pl.when(cb == ncb - 1)
    def _emit_final():
        fin_ref[0, 0, :, :] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: Array,                    # [B, S, H, P]
    dt: Array,                   # [B, S, H]  (softplus'd)
    A: Array,                    # [H]        (negative)
    B: Array,                    # [B, S, N]
    C: Array,                    # [B, S, N]
    D: Array,                    # [H]
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Chunked SSD scan. Returns (y [B,S,H,P], final_state [B,H,N,P] fp32)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (b, h, s // chunk)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)

    y, fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, cc: (bb, cc, hh)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C, D)
    return y, fin


def flops(b: int, s: int, h: int, p: int, n: int, chunk: int) -> int:
    """Analytic MACs: CB^T [L,N,L] + M@x [L,L,P] + state in/out [L,N,P] each."""
    nc = s // chunk
    per_chunk = chunk * chunk * n + chunk * chunk * p + 2 * chunk * n * p
    return b * h * nc * per_chunk
