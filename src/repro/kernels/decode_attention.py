"""Single-token decode attention kernel (Pallas / TPU).

The decode hot loop is memory-bound: one query token attends over a long KV
cache, so the roofline term is KV bytes / HBM bandwidth. The kernel streams
the cache through VMEM in (block_k x head_dim) tiles along the innermost
sequential grid axis, carrying flash-style running (m, l, acc) statistics in
VMEM scratch, and masks by the per-sequence cache length ``pos`` (tiles past
the newest token are skipped entirely — crucial when the cache is allocated
at max_seq but only partially filled).

All query heads of one KV head are processed together ([group, H] q tile):
with GQA this turns the per-tile work into a [group, H] x [H, BK] MXU matmul
instead of a bandwidth-starved GEMV, and each KV byte fetched from HBM is
reused ``group`` times — the classic GQA decode win.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the TPU params under the old TPUCompilerParams name
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

Array = jax.Array

NEG_INF = -2.3819763e38
DEFAULT_BLOCK_K = 256


def _decode_kernel(
    pos_ref,                     # SMEM scalar-prefetch: [B] int32
    q_ref, k_ref, v_ref,         # VMEM tiles
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    window: Optional[int],
    softcap: Optional[float],
    block_k: int,
    group: int,
):
    b = pl.program_id(0)
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)
    p = pos_ref[b]

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = kb * block_k
    run = k_start <= p
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 > p - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32)           # [G, H]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [BK, H]
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # [BK, H]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [G, BK]
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, (group, block_k), 1)
        mask = ki <= p
        if window is not None:
            mask = mask & (ki > p - window)
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        m_safe = jnp.where(m_new == -jnp.inf, 0.0, m_new)
        pexp = jnp.where(mask, jnp.exp(logits - m_safe[:, None]), 0.0)
        alpha = jnp.where(m_prev == -jnp.inf, 0.0, jnp.exp(m_prev - m_safe))
        l_scr[...] = alpha * l_scr[...] + jnp.sum(pexp, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        l = l_scr[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "softcap", "block_k", "interpret"))
def decode_attention(
    q: Array,                    # [B, N, H]
    k_cache: Array,              # [B, S, K, H]
    v_cache: Array,              # [B, S, K, H]
    pos: Array,                  # [B] int32
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> Array:
    b, n, h = q.shape
    _, s, kv, _ = k_cache.shape
    assert n % kv == 0
    group = n // kv
    scale = scale if scale is not None else h ** -0.5
    block_k = min(block_k, s)
    assert s % block_k == 0
    grid = (b, kv, s // block_k)

    # regroup q so each kv head's query group is contiguous: [B, KV, G, H]
    qg = q.reshape(b, kv, group, h)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, softcap=softcap,
        block_k=block_k, group=group)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, group, h),
                             lambda bb, kk, kb, pos_ref: (bb, kk, 0, 0)),
                pl.BlockSpec((1, block_k, 1, h),
                             lambda bb, kk, kb, pos_ref: (bb, kb, kk, 0)),
                pl.BlockSpec((1, block_k, 1, h),
                             lambda bb, kk, kb, pos_ref: (bb, kb, kk, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, h),
                                   lambda bb, kk, kb, pos_ref: (bb, kk, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, group, h), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, n, h)


def hbm_bytes(b: int, s: int, kv: int, h: int, dtype_bytes: int = 2) -> int:
    """Dominant HBM traffic of one decode step (the KV cache read)."""
    return 2 * b * s * kv * h * dtype_bytes
