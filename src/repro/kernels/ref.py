"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function here. They are also the XLA
fallback paths used on CPU (the dry-run compiles these; the Pallas kernels
target TPU and are validated in interpret mode).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -2.3819763e38


def _softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _repeat_kv(k: Array, num_heads: int) -> Array:
    kv = k.shape[2]
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=2)


# ---------------------------------------------------------------------------
# flash attention oracle
# ---------------------------------------------------------------------------


def attention(
    q: Array,                  # [B, Sq, N, H]
    k: Array,                  # [B, Sk, K, H]
    v: Array,                  # [B, Sk, K, H]
    *,
    scale: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
) -> Array:
    """Reference multi-head attention with GQA, causal/local masking, softcap."""
    n = q.shape[2]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    kh = _repeat_kv(k, n)
    vh = _repeat_kv(v, n)
    logits = jnp.einsum("bqnh,bknh->bnqk", q, kh).astype(jnp.float32) * scale
    logits = _softcap(logits, softcap)
    qi = jnp.arange(q.shape[1])[:, None] + q_offset
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (ki > qi - window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bnqk,bknh->bqnh", probs, vh)


# ---------------------------------------------------------------------------
# decode attention oracle
# ---------------------------------------------------------------------------


def decode_attention(
    q: Array,                  # [B, N, H] — one query token per sequence
    k_cache: Array,            # [B, S, K, H]
    v_cache: Array,            # [B, S, K, H]
    pos: Array,                # [B] int32 — index of the newest token
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> Array:
    """Reference single-token decode attention over a KV cache."""
    n = q.shape[1]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    kh = _repeat_kv(k_cache, n)
    vh = _repeat_kv(v_cache, n)
    logits = jnp.einsum("bnh,bknh->bnk", q, kh).astype(jnp.float32) * scale
    logits = _softcap(logits, softcap)
    ki = jnp.arange(k_cache.shape[1])[None, None, :]
    p = pos[:, None, None]
    mask = ki <= p
    if window is not None:
        mask = mask & (ki > p - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bnk,bknh->bnh", probs, vh)


# ---------------------------------------------------------------------------
# Mamba2 SSD oracle (sequential scan — the definition)
# ---------------------------------------------------------------------------


def ssd(
    x: Array,                  # [B, S, H, P]
    dt: Array,                 # [B, S, H]  (already softplus'd, > 0)
    A: Array,                  # [H]        (negative decay rates)
    B: Array,                  # [B, S, N]  (shared across heads, G=1)
    C: Array,                  # [B, S, N]
    D: Array,                  # [H]
    init_state: Optional[Array] = None,   # [B, H, N, P]
) -> tuple[Array, Array]:
    """Reference SSD: h_t = exp(A*dt_t) h_{t-1} + dt_t B_t x_t^T,
    y_t = C_t^T h_t + D x_t. Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    B32 = B.astype(jnp.float32)
    C32 = C.astype(jnp.float32)
    A32 = A.astype(jnp.float32)
    state0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, Bt, Ct = inp            # [B,H,P], [B,H], [B,N], [B,N]
        a = jnp.exp(A32[None, :] * dtt)  # [B,H]
        upd = jnp.einsum("bn,bh,bhp->bhnp", Bt, dtt, xt)
        state = a[:, :, None, None] * state + upd
        y = jnp.einsum("bn,bhnp->bhp", Ct, state)
        return state, y

    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt32, 1, 0),
          jnp.moveaxis(B32, 1, 0), jnp.moveaxis(C32, 1, 0))
    final, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)           # [B,S,H,P]
    y = y + D.astype(jnp.float32)[None, None, :, None] * x32
    return y.astype(dtype), final


def ssd_chunked(
    x: Array, dt: Array, A: Array, B: Array, C: Array, D: Array,
    chunk: int = 64, init_state: Optional[Array] = None,
) -> tuple[Array, Array]:
    """Chunked (state-space dual) formulation in pure jnp — the algorithm the
    Pallas kernel implements. Mathematically identical to ``ssd``."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    dtype = x.dtype
    x32 = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dt32 = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    B32 = B.astype(jnp.float32).reshape(b, nc, chunk, n)
    C32 = C.astype(jnp.float32).reshape(b, nc, chunk, n)
    A32 = A.astype(jnp.float32)

    la = A32[None, None, None, :] * dt32            # [b,nc,L,h] log-decay
    cum = jnp.cumsum(la, axis=2)                    # inclusive
    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) dt_j x_j
    seg = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [b,nc,i,j,h]
    idx = jnp.arange(chunk)
    mask = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    seg = jnp.where(mask, seg, 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", C32, B32)    # [b,nc,i,j]
    m = seg * cb[..., None] * dt32[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, x32)

    # inter-chunk: sequential state carry at chunk granularity
    chunk_decay = jnp.exp(cum[:, :, -1, :])         # [b,nc,h]
    # state update contribution of chunk c: sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dt32      # [b,nc,L,h]
    upd = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", B32, w, x32)

    state0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def carry(state, inp):
        dec, u = inp                                 # [b,h], [b,h,n,p]
        new = dec[:, :, None, None] * state + u
        return new, state                            # emit state *entering* chunk

    final, states_in = jax.lax.scan(
        carry, state0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(upd, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)        # [b,nc,h,n,p]
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         C32, jnp.exp(cum), states_in)
    y = y_intra + y_inter + D.astype(jnp.float32)[None, None, None, :, None] * x32
    return y.reshape(b, s, h, p).astype(dtype), final


# ---------------------------------------------------------------------------
# grouped matmul oracle (MoE expert GEMM)
# ---------------------------------------------------------------------------


def gmm(x: Array, w: Array, group_sizes: Array) -> Array:
    """x: [T, D] rows sorted by group; w: [E, D, F]; group_sizes: [E] int32.
    Row t belongs to group g(t) = searchsorted(cumsum(sizes), t, 'right').
    Returns [T, F] with out[t] = x[t] @ w[g(t)]."""
    t = x.shape[0]
    bounds = jnp.cumsum(group_sizes)
    gid = jnp.searchsorted(bounds, jnp.arange(t), side="right")
    wt = jnp.take(w, gid, axis=0)                    # [T, D, F]
    return jnp.einsum("td,tdf->tf", x, wt.astype(x.dtype))
