"""Flash attention forward kernel (Pallas / TPU).

TPU-native blocked attention: the grid walks (batch, q_head, q_block,
k_block) with the k_block axis innermost — TPU grids execute sequentially,
so VMEM scratch carries the running softmax statistics (m, l) and the
output accumulator across k-blocks of one q-block. BlockSpecs tile Q/K/V
into (block_q x head_dim) / (block_k x head_dim) VMEM-resident tiles; the
MXU sees [block_q, head_dim] x [head_dim, block_k] matmuls with both dims
padded to the 128-lane layout by construction.

GQA is folded into the index maps (query head n reads kv head n * K // N),
so no jnp.repeat materializes the expanded KV. Causal and sliding-window
masks are applied per-tile; fully-masked tiles are skipped via pl.when
(this is what makes the local-attention layers of gemma2 O(S*window)).

Softcap (gemma2's tanh logit cap) happens pre-max in fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the TPU params under the old TPUCompilerParams name
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

Array = jax.Array

NEG_INF = -2.3819763e38
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(
    q_ref, k_ref, v_ref,          # VMEM tiles
    o_ref,                        # output tile
    m_scr, l_scr, acc_scr,        # VMEM scratch (carried across k-blocks)
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    block_q: int,
    block_k: int,
):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    nkb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * block_q
    k_start = kb * block_k

    # tile-level mask pruning: skip tiles that are entirely masked
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None and causal:
        # the whole tile is below every query's window iff its newest key
        # (k_start + block_k - 1) is <= oldest query (q_start) - window
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # [BQ, H]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [BK, H]
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # [BK, H]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # [BQ, BK]
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = mask & (ki <= qi)
        if window is not None:
            mask = mask & (ki > qi - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]                                 # [BQ]
        l_prev = l_scr[...]
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(m_new == -jnp.inf, 0.0, m_new)   # all-masked rows
        p = jnp.exp(logits - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev == -jnp.inf, 0.0,
                          jnp.exp(m_prev - m_safe))
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(kb == nkb - 1)
    def _finalize():
        l = l_scr[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap",
                     "block_q", "block_k", "interpret"))
def flash_attention(
    q: Array,                    # [B, Sq, N, H]
    k: Array,                    # [B, Sk, K, H]
    v: Array,                    # [B, Sk, K, H]
    *,
    scale: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> Array:
    """Blocked flash attention. Sq/Sk must be divisible by the block sizes
    (the ops wrapper pads); GQA handled via index maps (N % K == 0)."""
    b, sq, n, h = q.shape
    _, sk, kv, _ = k.shape
    assert n % kv == 0, (n, kv)
    scale = scale if scale is not None else h ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    grid = (b, n, sq // block_q, sk // block_k)
    q_heads_per_kv = n // kv

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, h),
                         lambda bb, nn, qb, kb: (bb, qb, nn, 0)),
            pl.BlockSpec((1, block_k, 1, h),
                         lambda bb, nn, qb, kb: (bb, kb, nn // q_heads_per_kv, 0)),
            pl.BlockSpec((1, block_k, 1, h),
                         lambda bb, nn, qb, kb: (bb, kb, nn // q_heads_per_kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, h),
                               lambda bb, nn, qb, kb: (bb, qb, nn, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, n, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, h), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(block_q: int, block_k: int, head_dim: int,
               dtype_bytes: int = 2) -> int:
    """VMEM working set of one grid step (tiles + scratch), for block tuning."""
    tiles = (block_q + 2 * block_k) * head_dim * dtype_bytes
    scratch = (2 * block_q + block_q * head_dim) * 4
    out = block_q * head_dim * dtype_bytes
    return tiles + scratch + out


def flops(b: int, sq: int, sk: int, n: int, h: int, causal: bool) -> int:
    """Analytic MACs (QK^T + PV)."""
    full = 2 * b * n * sq * sk * h
    return full // 2 if causal and sq == sk else full
