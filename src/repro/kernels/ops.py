"""Jit'd public wrappers around the Pallas kernels.

Each wrapper handles shape hygiene (padding to tile boundaries), chooses
interpret mode per backend (TPU executes the compiled kernel; CPU runs the
kernel body in interpret mode for validation), and exposes the same
signature as the ``ref`` oracle it must match.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import gmm as _gmm
from . import ssd as _ssd

Array = jax.Array


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_seq(x: Array, axis: int, mult: int) -> Array:
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(
    q: Array, k: Array, v: Array, *,
    scale: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = _fa.DEFAULT_BLOCK_Q,
    block_k: int = _fa.DEFAULT_BLOCK_K,
    interpret: Optional[bool] = None,
) -> Array:
    """[B, Sq, N, H] x [B, Sk, K, H]^2 -> [B, Sq, N, H]."""
    interpret = _interpret_default() if interpret is None else interpret
    sq, sk = q.shape[1], k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    qp = _pad_seq(q, 1, bq)
    kp = _pad_seq(k, 1, bk)
    vp = _pad_seq(v, 1, bk)
    # padded keys are masked for real queries by causality (ki >= sk > qi)
    assert causal or (qp.shape[1] == sq and kp.shape[1] == sk), \
        "non-causal attention requires block-aligned sequence lengths"
    out = _fa.flash_attention(
        qp, kp, vp, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :sq]


def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, pos: Array, *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_k: int = _dec.DEFAULT_BLOCK_K,
    interpret: Optional[bool] = None,
) -> Array:
    """[B, N, H] x cache [B, S, K, H]^2 -> [B, N, H]."""
    interpret = _interpret_default() if interpret is None else interpret
    s = k_cache.shape[1]
    bk = min(block_k, s)
    kp = _pad_seq(k_cache, 1, bk)
    vp = _pad_seq(v_cache, 1, bk)
    return _dec.decode_attention(
        q, kp, vp, pos, scale=scale, window=window, softcap=softcap,
        block_k=bk, interpret=interpret)


def ssd(
    x: Array, dt: Array, A: Array, B: Array, C: Array, D: Array, *,
    chunk: int = 64,
    interpret: Optional[bool] = None,
) -> tuple[Array, Array]:
    """Chunked SSD scan: ([B,S,H,P], ...) -> (y, final_state).

    Padding tokens get dt=0: decay exp(A*0)=1 and zero input weight, so they
    are exact no-ops for both outputs and the carried state.
    """
    interpret = _interpret_default() if interpret is None else interpret
    s = x.shape[1]
    ch = min(chunk, s)
    pad = (-s) % ch
    if pad:
        x = _pad_seq(x, 1, ch)
        dt = _pad_seq(dt, 1, ch)
        B = _pad_seq(B, 1, ch)
        C = _pad_seq(C, 1, ch)
    y, fin = _ssd.ssd(x, dt, A, B, C, D, chunk=ch, interpret=interpret)
    return y[:, :s], fin


def gmm(x_sorted: Array, w: Array, group_sizes: Array, *,
        block_t: int = _gmm.DEFAULT_BLOCK_T,
        block_f: int = _gmm.DEFAULT_BLOCK_F,
        interpret: Optional[bool] = None) -> Array:
    """Ragged grouped matmul [T, D] x [E, D, F] -> [T, F]."""
    interpret = _interpret_default() if interpret is None else interpret
    return _gmm.gmm(x_sorted, w, group_sizes, block_t=block_t,
                    block_f=block_f, interpret=interpret)
