"""Grouped matmul kernel for MoE expert GEMMs (Pallas / TPU).

The MoE hot loop after sort-based dispatch is a ragged batched GEMM:
rows of x are sorted by expert, each contiguous row-group multiplies a
different expert's weight matrix. The dense alternatives either waste
FLOPs (one-hot dispatch einsum over capacity slots) or HBM (gathering
w[g(t)] per token). The kernel instead walks row tiles; a scalar-prefetch
array maps each row tile to its expert, so the weight tile index_map picks
the right expert's [D, BF] tile — each expert's weights stream through VMEM
exactly once per F-tile pass, and every row tile is a dense MXU matmul.

The ops wrapper pads each group to the row-tile boundary so a tile never
spans two experts (padding rows multiply real weights but are dropped on
gather-back; the FLOP overhead is <= E * (BT-1) rows, negligible for
tokens >> experts * BT).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the TPU params under the old TPUCompilerParams name
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

Array = jax.Array

DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_F = 512


def _fit_block(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= ``pref``."""
    b = min(dim, pref)
    while dim % b:
        b -= 1
    return b


def _gmm_kernel(tile_eid_ref, x_ref, w_ref, o_ref):
    del tile_eid_ref  # consumed by the index maps
    x = x_ref[...]                                    # [BT, D]
    w = w_ref[0]                                      # [D, BF]
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def gmm_padded(
    x: Array,                    # [Tp, D] — group-aligned (padded) rows
    w: Array,                    # [E, D, F]
    tile_eid: Array,             # [Tp // block_t] int32 expert of each row tile
    *,
    block_t: int = DEFAULT_BLOCK_T,
    block_f: int = DEFAULT_BLOCK_F,
    interpret: bool = True,
) -> Array:
    tp, d = x.shape
    e, _, f = w.shape
    block_t = min(block_t, tp)
    block_f = _fit_block(f, block_f)
    assert tp % block_t == 0 and f % block_f == 0
    grid = (tp // block_t, f // block_f)

    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_t, d), lambda tb, fb, eid: (tb, 0)),
                pl.BlockSpec((1, d, block_f),
                             lambda tb, fb, eid: (eid[tb], 0, fb)),
            ],
            out_specs=pl.BlockSpec((block_t, block_f),
                                   lambda tb, fb, eid: (tb, fb)),
        ),
        out_shape=jax.ShapeDtypeStruct((tp, f), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_eid.astype(jnp.int32), x, w)


def pad_groups(x_sorted: Array, group_sizes: Array, block_t: int
               ) -> tuple[Array, Array, Array]:
    """Scatter group-sorted rows into a group-aligned padded buffer.

    Returns (x_padded [Tp, D], tile_eid [Tp // block_t], row_map [T] int32)
    where row_map gives each original row's position in the padded buffer.
    Tp = T rounded up so each group starts on a block_t boundary (static:
    T + E * block_t, the worst case).
    """
    t, _ = x_sorted.shape
    e = group_sizes.shape[0]
    tp = (t + e * block_t + block_t - 1) // block_t * block_t

    offs = jnp.concatenate([jnp.zeros((1,), group_sizes.dtype),
                            jnp.cumsum(group_sizes)[:-1]])
    pad_sizes = (group_sizes + block_t - 1) // block_t * block_t
    pad_offs = jnp.concatenate([jnp.zeros((1,), group_sizes.dtype),
                                jnp.cumsum(pad_sizes)[:-1]])
    # row i of group g sits at pad_offs[g] + (i - offs[g])
    gid = jnp.searchsorted(jnp.cumsum(group_sizes), jnp.arange(t), side="right")
    row_map = (jnp.take(pad_offs, gid) + jnp.arange(t)
               - jnp.take(offs, gid)).astype(jnp.int32)
    x_padded = jnp.zeros((tp, x_sorted.shape[1]), x_sorted.dtype)
    x_padded = x_padded.at[row_map].set(x_sorted)
    # expert of each row tile: tile k covers rows [k*bt, (k+1)*bt)
    tile_starts = jnp.arange(tp // block_t) * block_t
    tile_eid = jnp.searchsorted(jnp.cumsum(pad_sizes), tile_starts,
                                side="right").astype(jnp.int32)
    tile_eid = jnp.minimum(tile_eid, e - 1)
    return x_padded, tile_eid, row_map


def gmm(x_sorted: Array, w: Array, group_sizes: Array, *,
        block_t: int = DEFAULT_BLOCK_T, block_f: int = DEFAULT_BLOCK_F,
        interpret: bool = True) -> Array:
    """Ragged grouped matmul: pad to tiles, run the kernel, gather back."""
    x_pad, tile_eid, row_map = pad_groups(x_sorted, group_sizes, block_t)
    out_pad = gmm_padded(x_pad, w, tile_eid, block_t=block_t,
                         block_f=block_f, interpret=interpret)
    return jnp.take(out_pad, row_map, axis=0)
