"""Pallas TPU kernels for the compute hot-spots of the serving/training path.

Layout (per kernel): <name>.py holds the pl.pallas_call + BlockSpec tiling,
ops.py the jit'd public wrapper, ref.py the pure-jnp oracle the tests sweep
against. All kernels validate on CPU via interpret=True; TPU is the target.

Import the wrappers via ``from repro.kernels import ops`` — the wrapper
functions are deliberately NOT re-exported here because their names would
shadow the kernel submodules of the same name.
"""
from . import ops, ref  # noqa: F401
