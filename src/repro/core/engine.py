"""Engine selection: one config object for the oracle/fast-path toggles.

The engine grew five independent switches, each a class attribute flipped
ad hoc by tests and benchmarks: the simulator's SoA slab mirror
(``Simulator.soa_slab``), the scheduler's scalar fast path and its batch
threshold (``DreamScheduler.fast_path`` / ``soa_batch_min``), the fleet
clock's lazy peek heap (``FleetSimulator.lazy_peek``), and the router's
vectorized scoring arm (``ScoreDrivenRouter.vectorized``).  Every pair of
settings is bit-identical by construction (tests/test_vectorized_equiv.py
is the proof), so the only *meaningful* choice is a preset:

    ``engine="soa"``     all vectorized arms on (the default, fast)
    ``engine="scalar"``  every scalar oracle path (slow, for differential
                         testing and debugging)

:class:`EngineConfig` names that choice once and threads it through
``Simulator(engine=...)`` / ``FleetSimulator(engine=...)`` — which apply
it as *instance* attributes, leaving the class-attribute defaults (and
any test that monkeypatches them) untouched.  Per-feature overrides stay
possible for bisection::

    EngineConfig("soa", lazy_peek=False)   # SoA core, scan fleet clock

Flag-by-flag class-attribute flipping keeps working; the config is the
front door, not a new mechanism.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

#: preset name -> fully-resolved flag values
ENGINE_PRESETS: dict[str, dict] = {
    "soa": {"soa_slab": True, "fast_path": True, "soa_batch_min": 8,
            "lazy_peek": True, "vectorized_router": True},
    "scalar": {"soa_slab": False, "fast_path": False, "soa_batch_min": 8,
               "lazy_peek": False, "vectorized_router": False},
}


@dataclass(frozen=True)
class EngineConfig:
    """Engine preset plus optional per-feature overrides (None = preset).

    ``soa_slab``        SoA job slab + slab-stepping in the per-node core
    ``fast_path``       scheduler's memoized scalar fast path
    ``soa_batch_min``   ready-set size above which the scheduler batches
    ``lazy_peek``       fleet clock driven by the persistent peek heap
    ``vectorized_router`` router scores all nodes in one NumPy pass
    """

    engine: str = "soa"
    soa_slab: Optional[bool] = None
    fast_path: Optional[bool] = None
    soa_batch_min: Optional[int] = None
    lazy_peek: Optional[bool] = None
    vectorized_router: Optional[bool] = None

    def __post_init__(self):
        if self.engine not in ENGINE_PRESETS:
            raise ValueError(
                f"unknown engine preset {self.engine!r}; expected one of "
                f"{', '.join(sorted(ENGINE_PRESETS))}")

    @classmethod
    def make(cls, value: "EngineConfig | str | None"
             ) -> "Optional[EngineConfig]":
        """Coerce a constructor argument: None passes through (class-
        attribute behavior), a preset name becomes a bare config."""
        if value is None or isinstance(value, cls):
            return value
        return cls(engine=value)

    def resolve(self) -> dict:
        """Preset values with any explicit overrides applied."""
        out = dict(ENGINE_PRESETS[self.engine])
        for k in out:
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        return out

    # ------------------------------------------------------------- apply
    # Appliers set instance attributes only — class defaults stay intact.

    def apply_simulator(self, sim) -> None:
        """Pin the per-node engine arms.  Must run before the simulator
        builds its JobTable (``soa_slab`` gates that allocation)."""
        r = self.resolve()
        sim.soa_slab = r["soa_slab"]
        sched = sim.scheduler
        if hasattr(type(sched), "fast_path"):
            sched.fast_path = r["fast_path"]
        if hasattr(type(sched), "soa_batch_min"):
            sched.soa_batch_min = r["soa_batch_min"]

    def apply_fleet(self, fleet) -> None:
        """Pin the fleet-level arms (node simulators are configured per
        node via :meth:`apply_simulator` when the fleet creates them)."""
        r = self.resolve()
        fleet.lazy_peek = r["lazy_peek"]
        if hasattr(type(fleet.policy), "vectorized"):
            fleet.policy.vectorized = r["vectorized_router"]
