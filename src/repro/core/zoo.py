"""Layer-graph reconstructions of the models in the paper's Table 3.

The paper schedules at layer granularity using offline latency/energy tables;
it never needs weights — only layer *shapes*. We reconstruct each cited
architecture as an ordered layer list with realistic dimensions (channel
widths, feature-map sizes, filter sizes follow the cited papers; minor details
approximated). Dynamic behaviours (SkipNet skipping, RAPID-RL early exits,
Once-for-All Supernet variants) are attached per Section 2.2.
"""
from __future__ import annotations

from dataclasses import replace as _dc_replace

from .types import GenAIMeta, Layer, ModelGraph, OpType


def conv(name: str, K: int, C: int, R: int, Y: int, X: int, S: int | None = None) -> Layer:
    return Layer(name=name, op=OpType.CONV2D, K=K, C=C, R=R, S=S or R, Y=Y, X=X)


def dwconv(name: str, C: int, R: int, Y: int, X: int) -> Layer:
    return Layer(name=name, op=OpType.DWCONV, C=C, R=R, S=R, Y=Y, X=X)


def fc(name: str, K: int, C: int, M: int = 1) -> Layer:
    return Layer(name=name, op=OpType.FC, K=K, C=C, Y=M)


def pool(name: str, C: int, Y: int, X: int) -> Layer:
    return Layer(name=name, op=OpType.POOL, C=C, Y=Y, X=X)


def mbconv(prefix: str, c_in: int, c_out: int, expand: int, y: int, x: int,
           stride: int = 1) -> list[Layer]:
    """MobileNetV2/V3-style inverted-residual block at *output* resolution y,x."""
    hidden = c_in * expand
    layers = []
    if expand != 1:
        layers.append(conv(f"{prefix}.pw", hidden, c_in, 1, y * stride, x * stride))
    layers.append(dwconv(f"{prefix}.dw", hidden, 3, y, x))
    layers.append(conv(f"{prefix}.pwl", c_out, hidden, 1, y, x))
    return layers


def resblock(prefix: str, c_in: int, c_out: int, y: int, x: int) -> list[Layer]:
    return [
        conv(f"{prefix}.c1", c_out, c_in, 3, y, x),
        conv(f"{prefix}.c2", c_out, c_out, 3, y, x),
    ]


# ---------------------------------------------------------------------------
# Vision models
# ---------------------------------------------------------------------------

def fbnet_c(name: str = "fbnet_c_gaze", res: int = 320) -> ModelGraph:
    """FBNet-C backbone (CVPR'19) on a `res` x `res` eye region — gaze."""
    r = res // 2
    L: list[Layer] = [conv("stem", 16, 3, 3, r, r)]
    spec = [  # (c_out, expand, n, out_res)
        (24, 6, 3, r // 2), (32, 6, 3, r // 4), (64, 6, 3, r // 8),
        (112, 6, 3, r // 8), (184, 6, 3, r // 16), (352, 6, 1, r // 16),
    ]
    c = 16
    for si, (co, e, n, r) in enumerate(spec):
        for bi in range(n):
            L += mbconv(f"s{si}.b{bi}", c, co, e, r, r, stride=1 if bi else 2)
            c = co
    L += [conv("head", 1504, c, 1, r // 16, r // 16), pool("gap", 1504, 1, 1), fc("fc", 64, 1504)]
    return ModelGraph(name=name, layers=tuple(L))


def ssd_mobilenet_v2(name: str = "ssd_mnv2", res: int = 512) -> ModelGraph:
    """SSD-MobileNetV2 (ECCV'16 + CVPR'18) detector at `res` input."""
    r = res // 2
    L: list[Layer] = [conv("stem", 32, 3, 3, r, r)]
    c = 32
    spec = [(16, 1, 1, r), (24, 6, 2, r // 2), (32, 6, 3, r // 4),
            (64, 6, 4, r // 8), (96, 6, 3, r // 8), (160, 6, 3, r // 16),
            (320, 6, 1, r // 16)]
    for si, (co, e, n, rr) in enumerate(spec):
        for bi in range(n):
            L += mbconv(f"s{si}.b{bi}", c, co, e, rr, rr)
            c = co
    L.append(conv("feat", 1280, c, 1, r // 16, r // 16))
    # SSD extra feature layers + class/box heads over 6 scales
    fr, fc_ = r // 16, 1280
    for i in range(4):
        L.append(conv(f"extra{i}.a", 256, fc_, 1, fr, fr))
        fr = max(1, fr // 2)
        L.append(conv(f"extra{i}.b", 512, 256, 3, fr, fr))
        fc_ = 512
    for i, (hr, hc) in enumerate([(r // 16, 1280)] + [(max(1, r // 32 >> k), 512) for k in range(4)]):
        L.append(conv(f"head{i}.cls", 6 * 21, hc, 3, hr, hr))
        L.append(conv(f"head{i}.box", 6 * 4, hc, 3, hr, hr))
    return ModelGraph(name=name, layers=tuple(L))


def handpose_net(name: str = "handpose", res: int = 288) -> ModelGraph:
    """Global-to-local hand pose CNN (Madadi et al.) on depth crops."""
    L: list[Layer] = []
    c, r = 3, res // 2
    for i, co in enumerate([64, 128, 256, 256, 512]):
        L += resblock(f"rb{i}", c, co, r, r)
        c, r = co, max(r // 2, 9)
        L.append(pool(f"p{i}", c, r, r))
    L += [fc("fc1", 1024, c * 81), fc("fc2", 63, 1024)]
    return ModelGraph(name=name, layers=tuple(L))


def skipnet(name: str = "skipnet_ctx", skip_prob: float = 0.5,
            res: int = 288) -> ModelGraph:
    """SkipNet-101 (ECCV'18) with per-residual-block gating: each block is
    skipped with `skip_prob` (paper assumes 50%, 72% top-1). The deep
    ResNet-101 layout gives the large worst-vs-typical path gap that defeats
    conservative static scheduling (paper Section 2.2)."""
    q = res // 2
    L: list[Layer] = [conv("stem", 64, 3, 7, q, q), pool("mp", 64, q // 2, q // 2)]
    blocks: list[tuple[int, int]] = []
    c = 64
    for si, (co, n, r) in enumerate([(64, 3, q // 2), (128, 4, q // 4),
                                     (256, 23, q // 8), (512, 3, q // 16)]):
        for bi in range(n):
            start = len(L)
            L += resblock(f"s{si}.b{bi}", c, co, r, r)
            c = co
            if bi > 0:  # first block of a stage (downsample) is not skippable
                blocks.append((start, len(L)))
    L += [pool("gap", 512, 1, 1), fc("fc", 1000, 512)]
    return ModelGraph(name=name, layers=tuple(L), skip_blocks=tuple(blocks),
                      skip_prob=skip_prob)


def trailnet(name: str = "trailnet_nav") -> ModelGraph:
    """TrailNet (IROS'17): ResNet-18-style trail-following DNN on 448x256."""
    L: list[Layer] = [conv("stem", 64, 3, 7, 224, 128), pool("mp", 64, 112, 64)]
    c = 64
    for si, (co, n, y, x) in enumerate([(64, 2, 112, 64), (128, 2, 56, 32),
                                        (256, 2, 28, 16), (512, 2, 14, 8)]):
        for bi in range(n):
            L += resblock(f"s{si}.b{bi}", c, co, y, x)
            c = co
    L += [pool("gap", 512, 1, 1), fc("fc", 9, 512)]
    return ModelGraph(name=name, layers=tuple(L))


def sosnet(name: str = "sosnet_vo", patches: int = 196) -> ModelGraph:
    """SOSNet (CVPR'19) local descriptors: 7 convs on 32x32 patches; the
    per-frame patch batch is folded into the spatial dims."""
    s = int(patches ** 0.5)  # tile the patch batch into a sqrt grid
    L: list[Layer] = []
    dims = [(32, 1, 32), (32, 32, 32), (64, 32, 16), (64, 64, 16),
            (128, 64, 8), (128, 128, 8)]
    for i, (k, c, r) in enumerate(dims):
        L.append(conv(f"c{i}", k, c, 3, r * s, r * s))
    L.append(conv("c6", 128, 128, 8, s, s))  # final 8x8 valid conv -> descriptor
    return ModelGraph(name=name, layers=tuple(L))


def rapid_rl(name: str = "rapid_rl_nav") -> ModelGraph:
    """RAPID-RL (ICRA'22): conv trunk with preemptive exits on 168x168 frames."""
    L: list[Layer] = [
        conv("c0", 32, 4, 8, 40, 40),
        conv("c1", 64, 32, 4, 18, 18),
        fc("exit0", 6, 64 * 324),
        conv("c2", 64, 64, 3, 14, 14),
        fc("exit1", 6, 64 * 196),
        conv("c3", 128, 64, 3, 14, 14),
        fc("fc1", 512, 128 * 196),
        fc("fc2", 6, 512),
    ]
    # Preemptive exits after the early heads (exit prob. from the paper's spec)
    return ModelGraph(name=name, layers=tuple(L),
                      exit_points=((2, 0.4), (4, 0.4)))


def googlenet_car(name: str = "googlenet_car") -> ModelGraph:
    """GoogLeNet (CompCars fine-grained classifier) on 288x288."""
    L: list[Layer] = [
        conv("stem", 64, 3, 7, 144, 144), pool("p0", 64, 72, 72),
        conv("c1", 64, 64, 1, 72, 72), conv("c2", 192, 64, 3, 72, 72),
        pool("p1", 192, 36, 36),
    ]

    def inception(pfx, c_in, b1, b3r, b3, b5r, b5, pp, r):
        return [
            conv(f"{pfx}.1x1", b1, c_in, 1, r, r),
            conv(f"{pfx}.3r", b3r, c_in, 1, r, r),
            conv(f"{pfx}.3x3", b3, b3r, 3, r, r),
            conv(f"{pfx}.5r", b5r, c_in, 1, r, r),
            conv(f"{pfx}.5x5", b5, b5r, 5, r, r),
            conv(f"{pfx}.pp", pp, c_in, 1, r, r),
        ]

    cfg = [  # (c_in, b1, b3r, b3, b5r, b5, pp, res)
        (192, 64, 96, 128, 16, 32, 32, 36), (256, 128, 128, 192, 32, 96, 64, 36),
        (480, 192, 96, 208, 16, 48, 64, 18), (512, 160, 112, 224, 24, 64, 64, 18),
        (512, 128, 128, 256, 24, 64, 64, 18), (512, 112, 144, 288, 32, 64, 64, 18),
        (528, 256, 160, 320, 32, 128, 128, 18), (832, 256, 160, 320, 32, 128, 128, 9),
        (832, 384, 192, 384, 48, 128, 128, 9),
    ]
    for i, args in enumerate(cfg):
        L += inception(f"inc{i}", *args)
    L += [pool("gap", 1024, 1, 1), fc("fc", 431, 1024)]
    return ModelGraph(name=name, layers=tuple(L))


def focal_depth(name: str = "focal_depth") -> ModelGraph:
    """Focal-length-aware monocular depth (TIP'18): VGG-ish encoder +
    upsampling decoder at 384x384."""
    L: list[Layer] = []
    c, r = 3, 384
    for si, (co, n) in enumerate([(32, 2), (64, 2), (128, 3), (256, 3), (256, 3)]):
        for bi in range(n):
            L.append(conv(f"e{si}.c{bi}", co, c, 3, r, r))
            c = co
        r //= 2
        L.append(pool(f"e{si}.p", c, r, r))
    for di, co in enumerate([128, 64, 32, 16]):
        r *= 2
        L.append(conv(f"d{di}.up", co, c, 3, r, r))
        L.append(conv(f"d{di}.c", co, co, 3, r, r))
        c = co
    L.append(conv("pred", 1, c, 3, r, r))
    return ModelGraph(name=name, layers=tuple(L))


def ed_tcn(name: str = "ed_tcn_action") -> ModelGraph:
    """ED-TCN (CVPR'17) encoder-decoder temporal convnet over T=128 steps of
    2048-d frame features (1-D convs encoded with X=1)."""
    L: list[Layer] = []
    t, c = 256, 2048
    for i, co in enumerate([96, 96]):
        L.append(Layer(f"enc{i}", OpType.CONV2D, K=co, C=c, R=25, S=1, Y=t, X=1))
        c, t = co, t // 2
    for i, co in enumerate([96, 96]):
        t *= 2
        L.append(Layer(f"dec{i}", OpType.CONV2D, K=co, C=c, R=25, S=1, Y=t, X=1))
        c = co
    L.append(fc("cls", 48, c, M=t))
    return ModelGraph(name=name, layers=tuple(L))


def vgg_voxceleb(name: str = "vgg_vox_verif") -> ModelGraph:
    """VGG-M speaker/face verification (VoxCeleb, Interspeech'17) on a
    512x300 spectrogram."""
    L: list[Layer] = [
        conv("c1", 96, 1, 7, 254, 148), pool("p1", 96, 126, 73),
        conv("c2", 256, 96, 5, 62, 36), pool("p2", 256, 30, 17),
        conv("c3", 384, 256, 3, 30, 17),
        conv("c4", 256, 384, 3, 30, 17),
        conv("c5", 256, 256, 3, 30, 17), pool("p5", 256, 9, 8),
        fc("fc6", 4096, 256 * 9 * 8),
        fc("fc7", 1024, 4096),
        fc("fc8", 1251, 1024),
    ]
    return ModelGraph(name=name, layers=tuple(L))


# ---------------------------------------------------------------------------
# Audio / language models
# ---------------------------------------------------------------------------

def kws_res8(name: str = "kws_res8") -> ModelGraph:
    """res8 keyword spotting (ICASSP'18): 6 convs, 45 ch, 40x101 MFCC map."""
    L: list[Layer] = [conv("c0", 45, 1, 3, 20, 50)]
    for i in range(6):
        L.append(conv(f"c{i+1}", 45, 45, 3, 20, 50))
    L += [pool("gap", 45, 1, 1), fc("fc", 12, 45)]
    return ModelGraph(name=name, layers=tuple(L))


def gnmt(name: str = "gnmt_translate", chunk: int = 12, hidden: int = 1024,
         enc_layers: int = 4, dec_layers: int = 4, vocab: int = 8000) -> ModelGraph:
    """GNMT-style LSTM seq2seq (arXiv:1609.08144) in *streaming* form: each
    15-FPS frame consumes the newly arrived audio chunk (`chunk` encoder
    timesteps) and emits two decoder steps. Each LSTM step is two GEMV layers
    (input + recurrent, 4 gates); decoder steps add attention + logits."""
    L: list[Layer] = [fc("embed", hidden, vocab // 32)]  # embedding lookup slice
    for t in range(chunk):
        for l in range(enc_layers):
            L.append(fc(f"enc.t{t}.l{l}.ih", 4 * hidden, hidden))
            L.append(fc(f"enc.t{t}.l{l}.hh", 4 * hidden, hidden))
    for t in range(2):
        for l in range(dec_layers):
            L.append(fc(f"dec.t{t}.l{l}.ih", 4 * hidden, hidden))
            L.append(fc(f"dec.t{t}.l{l}.hh", 4 * hidden, hidden))
        L.append(fc(f"dec.t{t}.attn", hidden, 2 * hidden))
        L.append(fc(f"dec.t{t}.logits", vocab, hidden))
    return ModelGraph(name=name, layers=tuple(L))


def chat_llm(name: str = "chat_llm", d_model: int = 512,
             prompt_tokens: int = 96, n_blocks: int = 4,
             max_new_tokens: int = 24, token_mean: float = 10.0,
             vocab: int = 8000) -> ModelGraph:
    """Compact on-device chat LLM in autoregressive (prefill/decode) form.

    The prefill phase runs the transformer blocks as GEMMs over the whole
    ``prompt_tokens``-long prompt (compute-bound under the roofline); each
    decode step re-runs the same blocks as single-token GEMVs plus a
    logits projection (weight streaming dominates — memory-bound), and
    repeats once per generated token.  Per-job token counts are geometric
    with mean ``token_mean`` capped at ``max_new_tokens``; the two capped
    variants give the SLO degradation ladder its ``max_new_tokens`` rungs.
    """
    L: list[Layer] = []
    for i in range(n_blocks):
        # attention in/out + MLP up/down, folded to two fat GEMMs per block
        L.append(fc(f"prefill.b{i}.attn", 2 * d_model, d_model,
                    M=prompt_tokens))
        L.append(fc(f"prefill.b{i}.mlp", d_model, 2 * d_model,
                    M=prompt_tokens))
    prefill_len = len(L)
    for i in range(n_blocks):
        L.append(fc(f"decode.b{i}.attn", 2 * d_model, d_model))
        L.append(fc(f"decode.b{i}.mlp", d_model, 2 * d_model))
    L.append(fc("decode.logits", vocab // 8, d_model))
    meta = GenAIMeta(prefill_len=prefill_len, max_new_tokens=max_new_tokens,
                     token_mean=token_mean)
    base = ModelGraph(name=name, layers=tuple(L), genai=meta)
    variants = tuple(
        _dc_replace(base, name=f"{name}@v{k}",
                    genai=_dc_replace(meta, max_new_tokens=cap))
        for k, cap in enumerate(
            (max(max_new_tokens // 2, 1), max(max_new_tokens // 4, 1)),
            start=1))
    return _dc_replace(base, variants=variants)


# ---------------------------------------------------------------------------
# Once-for-All Supernet (4 weight-sharing variants, §4.5)
# ---------------------------------------------------------------------------

def _ofa_instance(name: str, depths: list[int], expand: int, width_mult: float,
                  res: int) -> ModelGraph:
    r = res // 2
    L: list[Layer] = [conv("stem", int(24 * width_mult), 3, 3, r, r)]
    c = int(24 * width_mult)
    stage_cfg = [(32, r // 2), (56, r // 4), (104, r // 8), (128, r // 8),
                 (248, r // 16)]
    for si, (co_base, rr) in enumerate(stage_cfg):
        co = int(co_base * width_mult)
        for bi in range(depths[si % len(depths)]):
            L += mbconv(f"s{si}.b{bi}", c, co, expand, rr, rr)
            c = co
    L += [conv("head", 1024, c, 1, r // 16, r // 16), pool("gap", 1024, 1, 1),
          fc("fc", 1000, 1024)]
    return ModelGraph(name=name, layers=tuple(L))


def ofa_supernet(name: str = "ofa_ctx") -> ModelGraph:
    """Once-for-All (ICLR'20) context-understanding Supernet with the original
    plus three lighter weight-sharing variants (ofa-s7edge-style)."""
    base = _ofa_instance(name, depths=[4, 4, 4, 4, 4], expand=6, width_mult=1.0, res=288)
    v1 = _ofa_instance(f"{name}@v1", depths=[3, 3, 3, 3, 3], expand=4, width_mult=1.0, res=256)
    v2 = _ofa_instance(f"{name}@v2", depths=[2, 2, 2, 2, 2], expand=4, width_mult=0.8, res=224)
    v3 = _ofa_instance(f"{name}@v3", depths=[2, 2, 2, 2, 2], expand=3, width_mult=0.65, res=192)
    return ModelGraph(name=base.name, layers=base.layers, variants=(v1, v2, v3))


ZOO_BUILDERS = {
    "fbnet_c": fbnet_c,
    "ssd_mnv2": ssd_mobilenet_v2,
    "handpose": handpose_net,
    "skipnet": skipnet,
    "trailnet": trailnet,
    "sosnet": sosnet,
    "rapid_rl": rapid_rl,
    "googlenet_car": googlenet_car,
    "focal_depth": focal_depth,
    "ed_tcn": ed_tcn,
    "vgg_voxceleb": vgg_voxceleb,
    "kws_res8": kws_res8,
    "gnmt": gnmt,
    "ofa": ofa_supernet,
    "chat_llm": chat_llm,
}


# ---------------------------------------------------------------------------
# Memoized builds
# ---------------------------------------------------------------------------
# Placement-time cost estimation rebuilds the same architecture thousands of
# times under per-stream instance names.  The cost-table fast cache
# (costmodel._FAST_TABLE_CACHE) is keyed by the *identity* of the frozen
# ``layers`` tuple, so every fresh build used to fall through to a structural
# hash over hundreds of Layer dataclasses.  Cache one graph per
# (builder, kwargs) and rename via ``dataclasses.replace`` — the layers
# tuple keeps a single identity fleet-wide, and only the top-level (and
# ``{name}@vK`` variant) name strings differ between instances.

_BUILD_CACHE: dict = {}
_RELABEL_CACHE: dict = {}
_RELABEL_MAX = 65536


def _relabel(g: ModelGraph, name: str) -> ModelGraph:
    """Rename ``g`` (and its ``{old}@vK`` variant prefixes) without touching
    structure; layer tuples are shared with the donor graph."""
    old = g.name
    variants = tuple(
        _dc_replace(v, name=name + v.name[len(old):])
        if v.name.startswith(old) else v
        for v in g.variants)
    return _dc_replace(g, name=name, variants=variants)


def build_cached(builder: str, name: str | None = None,
                 kwargs: dict | None = None) -> ModelGraph:
    """``ZOO_BUILDERS[builder](**kwargs, name=name)`` with structure sharing.

    Graphs are immutable, and no builder lets ``name`` influence layer
    shapes, so two builds differing only in ``name`` may share every layer.
    Unhashable kwarg values fall back to a direct (uncached) build.
    """
    fn = ZOO_BUILDERS[builder]
    kw = dict(kwargs or {})
    kw.pop("name", None)
    try:
        key = (builder, tuple(sorted(kw.items())))
    except TypeError:                        # unhashable kwarg value
        if name is not None:
            kw["name"] = name
        return fn(**kw)
    g = _BUILD_CACHE.get(key)
    if g is None:
        g = _BUILD_CACHE[key] = fn(**kw)
    if name is None or name == g.name:
        return g
    rk = (id(g), name)
    rg = _RELABEL_CACHE.get(rk)
    if rg is None:
        if len(_RELABEL_CACHE) >= _RELABEL_MAX:
            _RELABEL_CACHE.clear()
        rg = _RELABEL_CACHE[rk] = _relabel(g, name)
    return rg
