"""DREAM core: the paper's scheduler, metrics, workloads and simulator."""
from .types import (Accelerator, Dataflow, Layer, ModelGraph, ModelSpec, OpType,
                    Scenario, SYSTEMS, HETERO_SYSTEMS, HOMO_SYSTEMS)
from .costmodel import (ContendedLinks, CostTable, TransferModel,
                        activation_bytes, build_cost_table, build_tables,
                        layer_energy_j, layer_latency_s, model_state_bytes)
from .engine import ENGINE_PRESETS, EngineConfig
from .mapscore import MapScoreParams, mapscore, togo_seconds, min_togo_seconds
from .uxcost import (WindowStats, uxcost, rate_dlv, norm_energy,
                     overall_pipeline_latency)
from .simulator import Dispatch, Job, SchedulerBase, SimResult, Simulator, run_sim
from .scheduler import (DreamScheduler, dream_mapscore, dream_smartdrop,
                        dream_full, AdaptivityState)
from .baselines import (FCFSScheduler, StaticFCFSScheduler, VeltairLikeScheduler,
                        PlanariaSimulator, run_planaria)
from .adaptivity import optimize_params, grid_search, SearchTrace
from .workloads import SCENARIOS, build_scenario

__all__ = [
    "Accelerator", "Dataflow", "Layer", "ModelGraph", "ModelSpec", "OpType",
    "Scenario", "SYSTEMS", "HETERO_SYSTEMS", "HOMO_SYSTEMS",
    "ContendedLinks", "CostTable", "TransferModel", "activation_bytes",
    "build_cost_table",
    "build_tables", "layer_energy_j", "layer_latency_s", "model_state_bytes",
    "ENGINE_PRESETS", "EngineConfig",
    "MapScoreParams", "mapscore", "togo_seconds",
    "min_togo_seconds", "WindowStats", "uxcost", "rate_dlv", "norm_energy",
    "overall_pipeline_latency",
    "Dispatch", "Job", "SchedulerBase", "SimResult", "Simulator", "run_sim",
    "DreamScheduler", "dream_mapscore", "dream_smartdrop", "dream_full",
    "AdaptivityState", "FCFSScheduler", "StaticFCFSScheduler",
    "VeltairLikeScheduler", "PlanariaSimulator", "run_planaria",
    "optimize_params", "grid_search", "SearchTrace", "SCENARIOS",
    "build_scenario",
]
