"""Core datatypes for the DREAM scheduler and its discrete-event simulator.

These types describe the paper's Level-1 world: layer-granularity model
graphs, RTMM pipelines (models with FPS targets, deadlines and control
dependencies), and multi-accelerator systems built from weight-stationary
(WS, NVDLA-like) and output-stationary (OS, ShiDianNao-like) sub-accelerators
(Table 2 of the paper).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, replace
from typing import Optional

MiB = 1 << 20


class OpType(enum.Enum):
    """Operator families the analytical cost model distinguishes."""

    CONV2D = "conv2d"      # dense convolution: K,C,R,S,Y,X
    DWCONV = "dwconv"      # depthwise convolution: C,R,S,Y,X (K==C, groups==C)
    FC = "fc"              # fully connected / GEMV: K (out), C (in), M tokens in Y
    GEMM = "gemm"          # batched matmul: M=Y, N=K, K-dim=C
    POOL = "pool"          # pooling / elementwise: C,Y,X (bandwidth bound)
    RNN = "rnn"            # recurrent cell step (treated as FC with state)


@dataclass(frozen=True)
class Layer:
    """A single schedulable layer (the paper's scheduling granularity).

    Dimensions follow the MAESTRO convention:
      K out channels, C in channels, R x S filter, Y x X *output* spatial.
    FC/GEMM layers use Y as the token/batch (M) dimension with R=S=X=1.
    """

    name: str
    op: OpType
    K: int = 1
    C: int = 1
    R: int = 1
    S: int = 1
    Y: int = 1
    X: int = 1
    bytes_per_elem: int = 2  # fp16 activations/weights (MAESTRO-style tables)

    @property
    def macs(self) -> int:
        if self.op is OpType.DWCONV:
            return self.C * self.R * self.S * self.Y * self.X
        if self.op is OpType.POOL:
            return self.C * self.Y * self.X  # elementwise-ish work
        return self.K * self.C * self.R * self.S * self.Y * self.X

    @property
    def weight_bytes(self) -> int:
        if self.op is OpType.DWCONV:
            return self.C * self.R * self.S * self.bytes_per_elem
        if self.op is OpType.POOL:
            return 0
        return self.K * self.C * self.R * self.S * self.bytes_per_elem

    @property
    def in_bytes(self) -> int:
        # input activation footprint (approximate: stride-1 equivalence)
        c_in = self.C
        return c_in * self.Y * self.X * self.bytes_per_elem

    @property
    def out_bytes(self) -> int:
        k_out = self.C if self.op in (OpType.DWCONV, OpType.POOL) else self.K
        return k_out * self.Y * self.X * self.bytes_per_elem


@dataclass(frozen=True)
class GenAIMeta:
    """Autoregressive-generation spec attached to a :class:`ModelGraph`.

    Layers ``[0, prefill_len)`` run once per job (the prompt / prefill
    phase); layers ``[prefill_len, n_layers)`` form ONE decode step and
    repeat once per generated token.  Per-job token counts are stochastic
    (geometric with mean ``token_mean``, capped at ``max_new_tokens``),
    drawn by the simulator on a dedicated RNG stream.  ``max_new_tokens``
    doubles as the degradation-ladder knob: lighter variants carry a
    smaller cap.
    """

    prefill_len: int
    max_new_tokens: int
    token_mean: float


@dataclass(frozen=True)
class ModelGraph:
    """A model as an ordered layer list plus its dynamic-behaviour spec.

    Dynamicity hooks (Section 2.2 of the paper):
      * ``skip_blocks``: [start, end) layer ranges that are skipped with
        probability ``skip_prob`` (SkipNet-style layer skipping).
      * ``exit_points``: (layer_idx, exit_prob) early exits (RAPID-RL /
        BranchyNet-style); inference stops after ``layer_idx`` w.p. prob.
      * ``variants``: lighter weight-sharing Supernet variants (Once-for-All);
        variant 0 is the original (heaviest). Used by Supernet switching.
      * ``genai``: autoregressive prefill/decode spec — the execution path
        repeats the decode segment once per generated token.
    """

    name: str
    layers: tuple[Layer, ...]
    skip_blocks: tuple[tuple[int, int], ...] = ()
    skip_prob: float = 0.0
    exit_points: tuple[tuple[int, float], ...] = ()
    variants: tuple["ModelGraph", ...] = ()
    genai: Optional[GenAIMeta] = None

    @property
    def macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def weight_bytes(self) -> int:
        return sum(l.weight_bytes for l in self.layers)

    def sample_path(self, rng) -> list[int]:
        """Sample a concrete execution path (list of layer indices)."""
        n = len(self.layers)
        skipped: set[int] = set()
        for (s, e) in self.skip_blocks:
            if rng.random() < self.skip_prob:
                skipped.update(range(s, e))
        path: list[int] = []
        for i in range(n):
            if i in skipped:
                continue
            path.append(i)
            for (exit_idx, p) in self.exit_points:
                if i == exit_idx and rng.random() < p:
                    return path
        return path

    def genai_path(self, n_tokens: int) -> list[int]:
        """Concrete execution path for an autoregressive job emitting
        ``n_tokens``: the prefill segment once, then the decode segment
        repeated per token (layer indices repeat on purpose — every
        consumer gathers per-index, so repetition is well-defined)."""
        g = self.genai
        pl = g.prefill_len
        decode = list(range(pl, len(self.layers)))
        return list(range(pl)) + decode * max(int(n_tokens), 1)

    def worst_path(self) -> list[int]:
        """Longest path (no skips, no early exit) — static-scheduler view.
        For autoregressive graphs: prefill + ``max_new_tokens`` decode
        repetitions, the longest generation the cap admits."""
        if self.genai is not None:
            return self.genai_path(self.genai.max_new_tokens)
        return list(range(len(self.layers)))


@dataclass(frozen=True)
class ModelSpec:
    """One entry of an RTMM scenario (a row of the paper's Table 3)."""

    model: ModelGraph
    fps: float
    depends_on: Optional[str] = None   # name of the upstream model
    trigger_prob: float = 0.5          # P(parent result triggers this model)
    deadline_s: Optional[float] = None  # default: 1/fps
    #: arrival process driving this stream (None = strict legacy periodic).
    #: Either an object implementing the ArrivalProcess protocol of
    #: repro.scenarios.arrivals, or its ``to_config`` dict; the engines
    #: materialize it at setup.  Core stays import-independent of the
    #: scenarios package by treating this as an opaque duck-typed value.
    arrival: Optional[object] = None

    @property
    def period_s(self) -> float:
        return 1.0 / self.fps

    @property
    def deadline(self) -> float:
        return self.deadline_s if self.deadline_s is not None else self.period_s


@dataclass(frozen=True)
class Scenario:
    """A full RTMM workload scenario (Table 3)."""

    name: str
    models: tuple[ModelSpec, ...]

    def model_index(self, name: str) -> int:
        for i, spec in enumerate(self.models):
            if spec.model.name == name:
                return i
        raise KeyError(name)

    def dependents_of(self, name: str) -> list[int]:
        return [i for i, s in enumerate(self.models) if s.depends_on == name]

    def is_chain_tail(self, idx: int) -> bool:
        """True if no other model depends on this one (frame-drop cond. 3)."""
        return not self.dependents_of(self.models[idx].model.name)


class Dataflow(enum.Enum):
    WS = "ws"  # weight stationary  (NVDLA-inspired)
    OS = "os"  # output stationary  (ShiDianNao-inspired)


@dataclass(frozen=True)
class Accelerator:
    """One sub-accelerator of the multi-accelerator system (Table 2)."""

    name: str
    pes: int
    dataflow: Dataflow
    sram_bytes: int = 8 * MiB
    dram_bw: float = 90e9       # bytes/s shared off-chip bandwidth
    clock_hz: float = 700e6

    def split(self, parts: int) -> list["Accelerator"]:
        """Planaria-style fission into equal sub-arrays."""
        assert self.pes % parts == 0
        return [
            replace(self, name=f"{self.name}.{i}", pes=self.pes // parts)
            for i in range(parts)
        ]


def _acc(name: str, pes: int, df: Dataflow) -> Accelerator:
    return Accelerator(name=name, pes=pes, dataflow=df)


#: The eight hardware systems of Table 2 (4K / 8K PEs, homo / hetero).
SYSTEMS: dict[str, tuple[Accelerator, ...]] = {
    "4K_2WS": (_acc("ws0", 2048, Dataflow.WS), _acc("ws1", 2048, Dataflow.WS)),
    "4K_2OS": (_acc("os0", 2048, Dataflow.OS), _acc("os1", 2048, Dataflow.OS)),
    "4K_1WS2OS": (
        _acc("ws0", 2048, Dataflow.WS),
        _acc("os0", 1024, Dataflow.OS),
        _acc("os1", 1024, Dataflow.OS),
    ),
    "4K_1OS2WS": (
        _acc("os0", 2048, Dataflow.OS),
        _acc("ws0", 1024, Dataflow.WS),
        _acc("ws1", 1024, Dataflow.WS),
    ),
    "8K_2WS": (_acc("ws0", 4096, Dataflow.WS), _acc("ws1", 4096, Dataflow.WS)),
    "8K_2OS": (_acc("os0", 4096, Dataflow.OS), _acc("os1", 4096, Dataflow.OS)),
    "8K_1WS2OS": (
        _acc("ws0", 4096, Dataflow.WS),
        _acc("os0", 2048, Dataflow.OS),
        _acc("os1", 2048, Dataflow.OS),
    ),
    "8K_1OS2WS": (
        _acc("os0", 4096, Dataflow.OS),
        _acc("ws0", 2048, Dataflow.WS),
        _acc("ws1", 2048, Dataflow.WS),
    ),
}

HETERO_SYSTEMS = ("4K_1WS2OS", "4K_1OS2WS", "8K_1WS2OS", "8K_1OS2WS")
HOMO_SYSTEMS = ("4K_2WS", "4K_2OS", "8K_2WS", "8K_2OS")
