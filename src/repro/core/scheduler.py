"""The DREAM scheduler (Section 4): MapScore-driven job assignment with the
smart frame drop engine, Supernet switching, and the online (alpha, beta)
adaptivity engine.

Configurations mirror the paper's Table 4:
  DREAM-MapScore  : score-driven dispatch + online parameter optimization
  DREAM-SmartDrop : + smart frame drop
  DREAM-Full      : + Supernet switching
(and `adaptivity=False` gives the fixed alpha=beta=1 ablation of Figure 9).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .adaptivity import PARAM_HI, PARAM_LO, ProbeSearch
from .costmodel import CostTable, E_DRAM
from .mapscore import (CSWITCH_MAX, MapScoreParams, STARV_MAX, URGENCY_MAX,
                       _EPS_SLACK, mapscore, togo_seconds)
from .simulator import Dispatch, Job, SchedulerBase, Simulator
from .uxcost import WindowStats, overall_dlv_rate

# the paper's constrained search range (§5.2) lives with the probe core in
# repro.core.adaptivity; imported here so `scheduler.PARAM_LO/HI` keep
# resolving for existing callers
_ = (PARAM_LO, PARAM_HI)


@dataclass
class AdaptivityState(ProbeSearch):
    """Radius-shrinking online search over (alpha, beta) — Section 3.6.

    The probe state machine itself is the host-agnostic
    :class:`repro.core.adaptivity.ProbeSearch` (also reused, in coordinate
    form, by the fleet weight tuner); this subclass adds the per-node
    workload-change *detector*: when the probe is parked, a DLV-rate shift
    against an EMA re-arms it.  Non-blocking: scheduling always proceeds
    with whatever candidate is under test.
    """

    dlv_ema: Optional[float] = None

    def retrigger(self, radius: float = 0.4) -> None:
        """Restart the (alpha, beta) probe from the current center — the
        response to an externally-signalled workload change (stream
        migration, node membership churn) rather than a detected DLV drift.
        Fresh candidates are drawn on the next window step."""
        super().retrigger(radius)
        self.dlv_ema = None

    def _on_stop(self) -> None:
        self.dlv_ema = None

    def step(self, window_uxcost: float, window_dlv: float,  # type: ignore[override]
             rng: np.random.Generator) -> np.ndarray:
        """Advance one UXCost window; returns the params for the next window."""
        if not self.probing:
            # workload-change detection: DLV-rate shift re-triggers the search
            if self.dlv_ema is None:
                self.dlv_ema = window_dlv
            drift = abs(window_dlv - self.dlv_ema)
            self.dlv_ema = 0.8 * self.dlv_ema + 0.2 * window_dlv
            if drift > 0.2:
                self.radius = 0.4
                self.probing = True
                self._make_candidates(rng)
            return self.center
        return ProbeSearch.step(self, window_uxcost, rng)


#: Dispatch-block cap (seconds): consecutive layers that keep preferring
#: the chosen accelerator are dispatched together up to this much latency.
#: Bounded so urgent arrivals still preempt at block boundaries; on
#: homogeneous systems (every accelerator "preferred") this makes jobs run
#: to completion in period-scale chunks instead of thrashing layer-by-layer
#: across frames — without it, urgency ordering (ToGo/Slack favors jobs
#: with MORE remaining work) starves almost-finished frames under load.
BLOCK_LATENCY_S = 1.5e-3
#: A layer "prefers" the chosen accelerator if its latency there is within
#: this factor of the best accelerator's (ties on homogeneous systems).
PREF_TOL = 1.10


class _FastTable:
    """Python-native view of one CostTable's arrays for the scalar dispatch
    fast path.  ``tolist()`` preserves the exact float64 values, and every
    per-element arithmetic step below mirrors the numpy expression in
    :func:`repro.core.mapscore.mapscore` operation-for-operation, so the
    fast path is bit-identical to the vectorized reference — it only avoids
    numpy's per-call array-construction overhead for the tiny (A,) shapes
    the inner loop actually evaluates."""

    __slots__ = ("lat", "en", "lat_sum", "lat_mean", "en_sum", "in_bytes",
                 "lat_min")

    def __init__(self, table: CostTable):
        self.lat = table.lat.tolist()            # per-acc rows, floats
        self.en = table.en.tolist()
        self.lat_sum = table.lat_sum.tolist()
        self.lat_mean = table.lat_mean.tolist()
        self.en_sum = table.en_sum.tolist()
        self.in_bytes = table.in_bytes.tolist()
        self.lat_min = table.lat_min.tolist()


#: id(table.lat) -> (pinning ref, fast view).  Relabeled tables (namespaced
#: fleet copies) share the underlying arrays, so this stays at one entry per
#: structurally-distinct (model, system) pair; the pin keeps ids stable.
_FAST_TABLES: dict[int, tuple] = {}
_FAST_TABLES_MAX = 4096


def _fast_table(table: CostTable) -> _FastTable:
    key = id(table.lat)
    hit = _FAST_TABLES.get(key)
    if hit is not None and hit[0] is table.lat:
        return hit[1]
    if len(_FAST_TABLES) >= _FAST_TABLES_MAX:
        _FAST_TABLES.clear()
    ft = _FastTable(table)
    _FAST_TABLES[key] = (table.lat, ft)
    return ft


class DreamScheduler(SchedulerBase):
    def __init__(
        self,
        alpha: float = 1.0,
        beta: float = 1.0,
        adaptivity: bool = True,
        frame_drop: bool = False,
        supernet: bool = False,
        seed: int = 0,
        name: Optional[str] = None,
    ):
        self.params = MapScoreParams(alpha=alpha, beta=beta)
        self.adaptivity = adaptivity
        self.frame_drop = frame_drop
        self.supernet = supernet
        self.rng = np.random.default_rng(seed + 101)
        self.adapt = AdaptivityState(center=np.array([alpha, beta])) if adaptivity else None
        if name is not None:
            self.name = name
        elif supernet:
            self.name = "DREAM-Full"
        elif frame_drop:
            self.name = "DREAM-SmartDrop"
        elif adaptivity:
            self.name = "DREAM-MapScore"
        else:
            self.name = "MapScore-fixed"

    # ----------------------------------------------------------- adaptivity
    def retrigger_probe(self) -> None:
        """Re-arm the (alpha, beta) search after an external workload shift
        (fleet routers call this on the nodes a migration touched)."""
        if self.adapt is not None:
            self.adapt.retrigger()

    def on_window(self, sim: Simulator, stats: WindowStats, uxc: float) -> None:
        if self.adapt is None:
            return
        frames = sum(st.frames for st in stats.per_model.values())
        if frames == 0:
            return
        nxt = self.adapt.step(uxc, overall_dlv_rate(stats), self.rng)
        self.params = MapScoreParams(alpha=float(nxt[0]), beta=float(nxt[1]))

    # ------------------------------------------------------ smart frame drop
    def _smart_frame_drop(self, sim: Simulator, t: float) -> None:
        """Section 4.2.1: drop the worst (min_to_go/slack) frame meeting all
        four conditions. Triggered at every scheduling decision."""
        soa = sim.soa
        if soa is not None and len(sim.jobs) >= self.soa_batch_min:
            return self._smart_frame_drop_batch(sim, soa, t)
        # condition 2: more than one active job expected to violate
        # (counting stops at two — only the <2 threshold matters)
        nv = 0
        for j in sim.jobs.values():
            if j.done:
                continue
            mtg = j.cum_min[j.pos] if j.pos < len(j.path) else 0.0
            if mtg > max(j.deadline - t, 0.0):
                nv += 1
                if nv >= 2:
                    break
        if nv < 2:
            return
        best: tuple[float, Job] | None = None
        for j in sim.ready.values():
            slack = j.deadline - t
            mtg = j.cum_min[j.pos] if j.pos < len(j.path) else 0.0
            if mtg <= max(slack, 0.0):          # condition 1
                continue
            if not j.is_tail:                    # condition 3
                continue
            if not sim.can_drop(j.base_name):    # condition 4
                continue
            ratio = mtg / max(slack, 1e-6)
            if best is None or ratio > best[0]:
                best = (ratio, j)
        if best is not None:
            sim.drop_job(best[1], t)

    def _smart_frame_drop_batch(self, sim: Simulator, soa, t: float) -> None:
        """SoA arm of the frame-drop engine: conditions 1-3 evaluate as
        elementwise column predicates (identical float64 comparisons to the
        scalar loop), condition 4 and the strict-> ratio pick run over the
        surviving candidates in ready order — the same iteration order the
        scalar arm uses, so the chosen frame matches bit-for-bit."""
        live = soa.live_rows()              # == sim.jobs iteration order
        nviol = np.count_nonzero(
            soa.togo_min[live] > np.maximum(soa.deadline[live] - t, 0.0))
        if nviol < 2:                        # condition 2
            return
        jids = list(sim.ready)
        if not jids:
            return
        rows = np.array([soa.row_of[j] for j in jids], dtype=np.intp)
        slack = soa.deadline[rows] - t
        mtg = soa.togo_min[rows]
        cand = np.flatnonzero((mtg > np.maximum(slack, 0.0))   # condition 1
                              & soa.is_tail[rows])             # condition 3
        if not len(cand):
            return
        ratio = mtg[cand] / np.maximum(slack[cand], 1e-6)
        best: tuple[float, Job] | None = None
        for i, ci in enumerate(cand):
            j = sim.ready[jids[ci]]
            if not sim.can_drop(j.base_name):                  # condition 4
                continue
            r = float(ratio[i])
            if best is None or r > best[0]:
                best = (r, j)
        if best is not None:
            sim.drop_job(best[1], t)

    # ------------------------------------------------------ Supernet switch
    def _maybe_switch_variant(self, sim: Simulator, job: Job, t: float) -> None:
        """Section 4.5.1: at the switch point — when the job's first layer is
        actually dispatched — deploy the heaviest weight-sharing variant whose
        estimated completion meets the deadline."""
        if job.variant_locked or job.pos != 0:
            return
        job.variant_locked = True
        graph = sim.graphs[job.graph_name]
        sim.variant_counts.setdefault(job.graph_name, 0)
        if not graph.variants or job.decode_len:
            # autoregressive jobs never auto-degrade here: a chat variant
            # rung caps max_new_tokens, i.e. silently truncates the
            # response — a quality cut only the SLO ladder (which charges
            # degradation into UXCost) is entitled to take
            sim.variant_counts[job.graph_name] += 1
            return
        slack = job.slack(t)
        # autoregressive jobs are judged on the predicted profile (the
        # sampled token count is the engine's secret), classic jobs on the
        # true-path ToGo — exactly what the dispatch scorer sees
        togo0 = (job.sched_list[0] if job.sched_list is not None
                 else job.togo())
        if togo0 <= slack:                      # original meets the deadline
            sim.variant_counts[job.graph_name] += 1
            return
        chosen = None
        for v in graph.variants:                # ordered heavy -> light
            vt = sim.tables[v.name]
            if v.genai is not None:
                # ladder rungs differ by max_new_tokens, not layer cost:
                # estimate a full generation at the variant's cap
                est = float(vt.lat_mean[
                    np.asarray(v.worst_path(), dtype=np.int64)].sum())
            else:
                est = float(vt.lat_mean.sum())
            if est <= slack:
                chosen = v
                break
        if chosen is None:
            chosen = graph.variants[-1]          # lightest as a last resort
        sim.switch_variant(job, chosen)
        sim.variant_counts[chosen.name] = sim.variant_counts.get(chosen.name, 0) + 1

    # -------------------------------------------------------------- dispatch
    #: Scalar fast-path toggle.  The reference numpy implementation below
    #: (``schedule_reference``) stays alive as the differential-test oracle;
    #: the fast path replicates its arithmetic operation-for-operation and
    #: must stay bit-identical (see tests/test_vectorized_equiv.py).
    fast_path = True
    #: Ready-set size at which the fast path switches from the per-job
    #: scalar loop to the SoA batch arm (one (jobs, idle-accs) score matrix
    #: off the simulator's JobTable columns).  Both arms are bit-identical,
    #: so this is a pure performance knob — tests pin it to 1 to force
    #: batch coverage on small scenarios.
    soa_batch_min = 8

    def schedule(self, sim: Simulator, t: float) -> Optional[Dispatch]:
        if not self.fast_path:
            return self.schedule_reference(sim, t)
        if self.frame_drop:
            self._smart_frame_drop(sim, t)
        ready = sim.ready
        if not ready:
            return None
        idle_idx = [a.idx for a in sim.accs if not a.busy]
        if not idle_idx:
            return None
        if len(ready) == 1 and len(idle_idx) == 1:
            # forced assignment: every score is finite, so the single
            # (job, acc) pair always wins the argmax — skip the arithmetic
            job = next(iter(ready.values()))
            if self.supernet and not job.variant_locked:
                self._maybe_switch_variant(sim, job, t)
            return Dispatch(job=job, acc_idx=idle_idx[0],
                            n_layers=self._block_len(job, idle_idx[0]))
        if sim.soa is not None and len(ready) >= self.soa_batch_min:
            job, acc_idx = self._schedule_batch(sim, ready, idle_idx, t)
            if self.supernet and not job.variant_locked:
                self._maybe_switch_variant(sim, job, t)
            return Dispatch(job=job, acc_idx=acc_idx,
                            n_layers=self._block_len(job, acc_idx))
        accs = sim.accs
        prev_out = [a.prev_out_bytes for a in accs]
        prev_base = [a.prev_base for a in accs]
        alpha = self.params.alpha
        beta = self.params.beta
        best_score = -np.inf
        best: Optional[tuple[Job, int]] = None
        for job in ready.values():
            pos = job.pos
            nxt = job.path_list[pos]
            ft = _fast_table(job.table)
            # ToGo memo: pos only moves at dispatch boundaries, while the
            # reference recomputes the same pairwise numpy suffix sum on
            # every scheduling decision the job sits through
            ck = (pos, id(job.table))
            if getattr(job, "_togo_at", None) == ck:
                togo = job._togo_v                 # type: ignore[attr-defined]
            else:
                # autoregressive jobs score against the length predictor's
                # precomputed profile, never the sampled token count
                togo = (job.sched_list[pos] if job.sched_list is not None
                        else togo_seconds(job.table, job.path[pos:]))
                job._togo_at = ck                  # type: ignore[attr-defined]
                job._togo_v = togo                 # type: ignore[attr-defined]
            slack = job.deadline - t
            urgency = 0.0 if slack <= _EPS_SLACK else min(togo / slack,
                                                          URGENCY_MAX)
            lat_sum_n = ft.lat_sum[nxt]
            en_sum_n = ft.en_sum[nxt]
            in_b_n = ft.in_bytes[nxt]
            t_queue = max(t - job.t_cmpl, 0.0)
            starv = min(t_queue / ft.lat_mean[nxt], STARV_MAX)
            a_starv = alpha * starv
            base = job.base_name
            jb_score = -np.inf
            jb_acc = -1
            for ai in idle_idx:
                lat_a = ft.lat[ai][nxt]
                en_a = ft.en[ai][nxt]
                if prev_base[ai] == base:
                    cost_switch = 0.0
                else:
                    cost_switch = min(
                        (in_b_n + prev_out[ai]) * E_DRAM / en_a, CSWITCH_MAX)
                s = (urgency * (lat_sum_n / lat_a) + a_starv
                     + beta * (en_sum_n / en_a - cost_switch))
                if s > jb_score:
                    jb_score = s
                    jb_acc = ai
            if jb_score > best_score:
                best_score = jb_score
                best = (job, jb_acc)
        if best is None:
            return None
        if self.supernet and not best[0].variant_locked:
            self._maybe_switch_variant(sim, best[0], t)
        job, acc_idx = best
        return Dispatch(job=job, acc_idx=acc_idx,
                        n_layers=self._block_len(job, acc_idx))

    def _schedule_batch(self, sim: Simulator, ready: dict, idle_idx: list,
                        t: float) -> tuple[Job, int]:
        """SoA batch arm: score every (ready job, idle accelerator) pair in
        one elementwise matrix pass over the simulator's JobTable columns.

        Bit-identity with the scalar loop holds term by term: each numpy
        op is the same IEEE float64 op the scalar expression applies to the
        same value, grouped identically; and the flattened row-major
        argmax (first occurrence of the max) equals the scalar two-level
        strict-> selection — first job reaching the global max, first
        accelerator reaching that job's max."""
        soa = sim.soa
        jids = list(ready)
        rows = np.array([soa.row_of[j] for j in jids], dtype=np.intp)
        for i in np.flatnonzero(soa.cost_stale[rows]):
            sim._soa_cost_refresh(ready[jids[i]], int(rows[i]))
        k = np.array(idle_idx, dtype=np.intp)
        slack = soa.deadline[rows] - t
        tight = slack <= _EPS_SLACK
        urgency = np.where(
            tight, 0.0,
            np.minimum(soa.togo_sched[rows] / np.where(tight, 1.0, slack),
                       URGENCY_MAX))
        a_starv = self.params.alpha * np.minimum(
            np.maximum(t - soa.t_cmpl[rows], 0.0) / soa.lat_mean_n[rows],
            STARV_MAX)
        lat_g = soa.lat_n[rows[:, None], k[None, :]]
        en_g = soa.en_n[rows[:, None], k[None, :]]
        accs = sim.accs
        prev_out = np.array([accs[ai].prev_out_bytes for ai in idle_idx])
        prev_ids = np.array([accs[ai].prev_base_id for ai in idle_idx],
                            dtype=np.int64)
        cost_switch = np.where(
            soa.base_id[rows][:, None] == prev_ids[None, :],
            0.0,
            np.minimum((soa.in_b_n[rows][:, None] + prev_out[None, :])
                       * E_DRAM / en_g, CSWITCH_MAX))
        s = (urgency[:, None] * (soa.lat_sum_n[rows][:, None] / lat_g)
             + a_starv[:, None]
             + self.params.beta * (soa.en_sum_n[rows][:, None] / en_g
                                   - cost_switch))
        flat = int(np.argmax(s))
        nk = len(idle_idx)
        return ready[jids[flat // nk]], idle_idx[flat % nk]

    def schedule_reference(self, sim: Simulator, t: float) -> Optional[Dispatch]:
        """Original vector-per-job dispatch via :func:`mapscore` — retained
        as the bit-identity oracle for the scalar fast path above."""
        if self.frame_drop:
            self._smart_frame_drop(sim, t)
        ready = sim.ready_jobs()
        if not ready:
            return None
        idle = sim.idle_accs()
        if not idle:
            return None
        idle_idx = np.array([a.idx for a in idle])
        prev_out = np.array([a.prev_out_bytes for a in sim.accs])
        prev_base = [a.prev_base for a in sim.accs]
        best_score = -np.inf
        best: Optional[tuple[Job, int]] = None
        for job in ready:
            nxt = int(job.path[job.pos])
            same = np.array([pb == job.base_name for pb in prev_base])
            scores = mapscore(
                job.table, nxt, job.path[job.pos:], t, job.t_cmpl,
                job.deadline, prev_out, same, self.params,
                togo_override=(job.sched_list[job.pos]
                               if job.sched_list is not None else None),
            )[idle_idx]
            k = int(np.argmax(scores))
            if scores[k] > best_score:
                best_score = float(scores[k])
                best = (job, int(idle_idx[k]))
        if best is None:
            return None
        # Supernet switch point: decide the variant for the job that is about
        # to start, with the system load it actually faces at dispatch time.
        if self.supernet and not best[0].variant_locked:
            self._maybe_switch_variant(sim, best[0], t)
        job, acc_idx = best
        return Dispatch(job=job, acc_idx=acc_idx,
                        n_layers=self._block_len_reference(job, acc_idx))

    @staticmethod
    def _block_len(job: Job, acc_idx: int) -> int:
        """Affinity-run blocking via the fast-table row (``lat.min(axis=0)``
        over gathered columns equals a ``lat_min`` gather element-wise, so
        this matches :meth:`_block_len_reference` bit-for-bit)."""
        path = job.path_list
        pos = job.pos
        ft = _fast_table(job.table)
        row = ft.lat[acc_idx]
        lat_min = ft.lat_min
        limit = len(path) - pos
        if job.decode_len:
            # token-level preemption: a dispatch block never crosses a
            # token boundary, so between generated tokens the scheduler
            # can reassess — preempt, smart-drop, or SLO-truncate
            pl = job.prefill_len
            limit = min(limit, (pl - pos) if pos < pl
                        else job.decode_len - (pos - pl) % job.decode_len)
        n = 1
        cum = row[path[pos]]
        for i in range(1, limit):
            li = path[pos + i]
            if row[li] > PREF_TOL * lat_min[li] or cum >= BLOCK_LATENCY_S:
                break
            cum += row[li]
            n = i + 1
        return n

    @staticmethod
    def _block_len_reference(job: Job, acc_idx: int) -> int:
        """Affinity-run blocking: dispatch the run of consecutive layers
        that keep preferring this accelerator, capped at BLOCK_LATENCY_S."""
        path = job.path[job.pos:]
        lat = job.table.lat[:, path]              # (A, remaining)
        pref = lat[acc_idx] <= PREF_TOL * lat.min(axis=0)
        limit = len(path)
        if job.decode_len:
            # token-boundary cap — mirrors :meth:`_block_len` exactly
            pl, pos = job.prefill_len, job.pos
            limit = min(limit, (pl - pos) if pos < pl
                        else job.decode_len - (pos - pl) % job.decode_len)
        n, cum = 1, float(lat[acc_idx, 0])
        for i in range(1, limit):
            if not pref[i] or cum >= BLOCK_LATENCY_S:
                break
            cum += float(lat[acc_idx, i])
            n = i + 1
        return n


def dream_mapscore(seed: int = 0, **kw) -> DreamScheduler:
    return DreamScheduler(adaptivity=True, frame_drop=False, supernet=False,
                          seed=seed, **kw)


def dream_smartdrop(seed: int = 0, **kw) -> DreamScheduler:
    return DreamScheduler(adaptivity=True, frame_drop=True, supernet=False,
                          seed=seed, **kw)


def dream_full(seed: int = 0, **kw) -> DreamScheduler:
    return DreamScheduler(adaptivity=True, frame_drop=True, supernet=True,
                          seed=seed, **kw)
