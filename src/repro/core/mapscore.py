"""MapScore (Algorithm 1 of the paper), vectorized over accelerators.

MapScore(tsk, acc) = Score_Urgency(tsk) * Score_LatPref(tsk, acc)
                     + alpha * Score_Starv(tsk)
                     + beta  * Score_Energy(tsk, acc)

with  Score_Urgency = ToGo / Slack
      Score_LatPref = sum_i EstLat(next, i) / EstLat(next, acc)
      Score_Starv   = T_queue / mean_i EstLat(next, i)
      Score_Energy  = Pref_Energy - Cost_switch
      Pref_Energy   = sum_i EstEn(next, i) / EstEn(next, acc)
      Cost_switch   = CswitchEnergy(tsk, acc.prevTask, acc) / EstEn(next, acc)

All Est* terms come from the offline cost tables (costmodel.CostTable).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .costmodel import CostTable, E_DRAM

_EPS_SLACK = 1e-6
#: Numerical-stability clamps. Alg. 1's raw terms are unbounded ratios:
#: Urgency = ToGo/Slack explodes as Slack -> 0+, Starv = T_queue/lat blows up
#: for microsecond layers that waited milliseconds, and Cost_switch can be
#: orders of magnitude above Pref_Energy when the incoming layer is tiny.
#: The paper constrains alpha, beta to [0, 2] (Section 5.2), which implies
#: comparably-scaled score terms; clamping each term to the same O(10) range
#: realizes that — and makes the (alpha, beta) UXCost landscape the smooth,
#: well-conditioned surface of the paper's Figure 3 rather than a cliff
#: where one runaway term dictates every decision.
URGENCY_MAX = 20.0
STARV_MAX = 20.0
CSWITCH_MAX = 10.0


@dataclass
class MapScoreParams:
    alpha: float = 1.0  # starvation factor  (range [0, 2], Section 5.2)
    beta: float = 1.0   # energy factor      (range [0, 2])


def togo_seconds(table: CostTable, remaining: np.ndarray) -> float:
    """ToGo(tsk): predicted remaining time, averaged across accelerators
    (Alg. 1 line 2). `remaining` = layer indices still in the task's queue."""
    if remaining.size == 0:
        return 0.0
    return float(table.lat_mean[remaining].sum())


def min_togo_seconds(table: CostTable, remaining: np.ndarray) -> float:
    """minimum_to_go for the smart frame drop (best accelerator per layer,
    no context switches) — Section 4.2.1, condition 1."""
    if remaining.size == 0:
        return 0.0
    return float(table.lat_min[remaining].sum())


def mapscore(
    table: CostTable,
    next_layer: int,
    remaining: np.ndarray,
    t_curr: float,
    t_cmpl: float,
    deadline: float,
    prev_out_bytes: np.ndarray,
    same_model: np.ndarray,
    params: MapScoreParams,
    togo_override: float | None = None,
) -> np.ndarray:
    """MapScore of one task on *all* accelerators (vector of length n_accs).

    prev_out_bytes[a] — activation bytes of the job last run on accelerator a
                        (0 if none); drives the context-switch energy.
    same_model[a]     — True if accelerator a last ran this very model (no
                        context switch needed).
    togo_override     — predicted remaining seconds replacing the true-path
                        ToGo (autoregressive jobs: the scheduler sees the
                        length *predictor*, not the sampled token count).
    """
    lat_next = table.lat[:, next_layer]          # (A,)
    en_next = table.en[:, next_layer]            # (A,)

    togo = (togo_seconds(table, remaining) if togo_override is None
            else togo_override)
    slack = deadline - t_curr
    if slack <= _EPS_SLACK:
        urgency = 0.0                            # hopeless frame: deprioritize
    else:
        urgency = min(togo / slack, URGENCY_MAX)  # line 7 (clamped)

    latpref = table.lat_sum[next_layer] / lat_next   # line 8

    t_queue = max(t_curr - t_cmpl, 0.0)
    starv = min(t_queue / table.lat_mean[next_layer], STARV_MAX)  # line 9

    # context-switch energy: fetch new activation + flush old one (line 10)
    cswitch_j = (table.in_bytes[next_layer] + prev_out_bytes) * E_DRAM
    cswitch_j = np.where(same_model, 0.0, cswitch_j)
    cost_switch = np.minimum(cswitch_j / en_next, CSWITCH_MAX)

    pref_energy = table.en_sum[next_layer] / en_next  # line 11
    score_energy = pref_energy - cost_switch          # lines 12-13

    return urgency * latpref + params.alpha * starv + params.beta * score_energy
