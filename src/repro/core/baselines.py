"""Baseline schedulers evaluated against DREAM (Section 5.1).

* FCFS           — dynamic first-come-first-served at *model* granularity:
                   the oldest request goes to the first idle accelerator.
* StaticFCFS     — static scheduling (Figure 2): accelerator assignment is
                   fixed round-robin at arrival; the slot is reserved for the
                   *worst-case* path duration (static schedulers must plan for
                   the longest path of dynamic models, Section 2.2).
* VeltairLike    — models Veltair's scheduler: threshold-based layer-blocks
                   (consecutive layers grouped until a latency threshold) with
                   earliest-deadline-first job selection on the lowest-latency
                   idle accelerator. Energy-unaware.
* PlanariaLike   — models Planaria's scheduling component: deadline-aware
                   dynamic *spatial* partitioning; active jobs receive PE
                   sub-arrays proportional to their demand (ToGo/slack) and
                   run concurrently on their partitions. Energy-unaware.

Veltair targets CPU clusters and Planaria is an HW/SW co-design; per the
paper (§5.1), only their scheduling components are modeled.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, replace
from typing import Optional

import numpy as np

from .costmodel import build_cost_table, build_tables, effective_deadline
from .simulator import (_ARRIVAL_STREAM, Dispatch, Job, SchedulerBase,
                        SimResult, Simulator)
from .types import Accelerator, Scenario, SYSTEMS
from .uxcost import WindowStats, uxcost, overall_dlv_rate, overall_norm_energy


class FCFSScheduler(SchedulerBase):
    """Dynamic FCFS, model granularity (Nexus/Clockwork-style front end)."""

    name = "FCFS"

    def schedule(self, sim: Simulator, t: float) -> Optional[Dispatch]:
        ready = sim.ready_jobs()
        idle = sim.idle_accs()
        if not ready or not idle:
            return None
        job = min(ready, key=lambda j: (j.arrival, j.jid))
        return Dispatch(job=job, acc_idx=idle[0].idx,
                        n_layers=job.n_layers - job.pos)


class StaticFCFSScheduler(SchedulerBase):
    """Static scheduling for Figure 2: an offline planner bin-packs each
    *model* onto a fixed accelerator using worst-case (longest-path) latency
    estimates — a static scheduler cannot know which layers a dynamic model
    will actually run (Section 2.2) — and at runtime every frame executes on its
    model's fixed accelerator with the slot reserved for the worst-case
    duration."""

    name = "Static-FCFS"

    def __init__(self) -> None:
        self._model_acc: dict[str, int] = {}

    def _plan(self, sim: Simulator) -> None:
        """Offline worst-case bin-packing: models in decreasing worst-case
        utilization go to the accelerator with the least accumulated load."""
        util = [0.0] * len(sim.accs)
        demands = []
        for spec in sim.scenario.models:
            table = sim.tables[spec.model.name]
            worst = [float(table.lat[a].sum()) for a in range(len(sim.accs))]
            demands.append((min(worst) * spec.fps, spec.model.name, worst))
        for _, name, worst in sorted(demands, reverse=True):
            acc = min(range(len(sim.accs)),
                      key=lambda a: util[a] + worst[a])
            self._model_acc[name] = acc
            util[acc] += worst[acc]

    def on_job_created(self, sim: Simulator, job: Job) -> None:
        if not self._model_acc:
            self._plan(sim)

    def schedule(self, sim: Simulator, t: float) -> Optional[Dispatch]:
        idle = {a.idx for a in sim.idle_accs()}
        ready = sorted(sim.ready_jobs(), key=lambda j: (j.arrival, j.jid))
        for job in ready:
            acc = self._model_acc.get(job.base_name, 0)
            if acc in idle:
                return Dispatch(job=job, acc_idx=acc,
                                n_layers=job.n_layers - job.pos,
                                reserve_worst=True)
        return None


class VeltairLikeScheduler(SchedulerBase):
    """Layer-block scheduling with an EDF job order (Veltair, ASPLOS'22)."""

    name = "Veltair"

    def __init__(self, block_latency_s: float = 1.5e-3):
        self.block_latency_s = block_latency_s

    def _block_len(self, job: Job, acc_idx: int) -> int:
        lat = job.table.lat[acc_idx, job.path[job.pos:]]
        csum = np.cumsum(lat)
        n = int(np.searchsorted(csum, self.block_latency_s)) + 1
        return max(1, min(n, len(lat)))

    def schedule(self, sim: Simulator, t: float) -> Optional[Dispatch]:
        ready = sim.ready_jobs()
        idle = sim.idle_accs()
        if not ready or not idle:
            return None
        job = min(ready, key=lambda j: (j.deadline, j.jid))  # EDF
        # Veltair targets homogeneous CPU clusters (Table 5: not
        # heterogeneity-aware): any idle unit is equivalent to it, so it
        # takes the first — it never consults per-accelerator latencies.
        acc = idle[0]
        return Dispatch(job=job, acc_idx=acc.idx,
                        n_layers=self._block_len(job, acc.idx))


# ---------------------------------------------------------------------------
# Planaria-like: deadline-aware dynamic architecture fission
# ---------------------------------------------------------------------------

_SLOTS_PER_ACC = 8  # fission granularity: each accelerator splits into 8 pods


@dataclass
class _PJob:
    jid: int
    model_idx: int
    base_name: str
    path: np.ndarray
    arrival: float
    deadline: float
    worst_energy: float
    pos: int = 0
    energy_used: float = 0.0
    host_acc: int = -1
    slots: int = 0
    running: bool = False
    done: bool = False


class PlanariaSimulator:
    """Planaria's scheduling component (MICRO'20), modeled per the paper:
    deadline-aware dynamic *architecture fission*. Each accelerator can be
    split into up to ``_SLOTS_PER_ACC`` equal sub-arrays ("pods"). At every
    scheduling event (arrival / layer completion / job finish), waiting jobs
    are considered in EDF order and admitted with the *minimal* number of
    pods whose estimated remaining latency still meets the job's slack
    (Planaria: allocate just enough resources to each task to meet its
    deadline, freeing the rest for others). Jobs that cannot be feasibly
    admitted receive all remaining pods of the emptiest accelerator (best
    effort) once no feasible job is left waiting.

    Latency/energy of a layer on a k-pod partition comes from a cost table
    built for a sub-accelerator with k/8 of the PEs and the same dataflow;
    off-chip bandwidth is shared chip-wide (each full accelerator gets
    bw/n_accs; a partition gets its PE-proportional share).
    """

    name = "Planaria"

    def __init__(self, scenario: Scenario, system: str | tuple[Accelerator, ...],
                 duration_s: float = 8.0, seed: int = 0, window_s: float = 0.5,
                 stale_periods: float = 2.0):
        self.scenario = scenario
        self.system_name = system if isinstance(system, str) else "custom"
        self.accs = list(SYSTEMS[system] if isinstance(system, str) else system)
        self.duration_s = duration_s
        self.window_s = window_s
        self.stale_periods = stale_periods
        self.rng = np.random.default_rng(seed)
        # same arrival-process protocol (and dedicated rng stream) as
        # core.simulator.Simulator, so stochastic scenarios compare fairly
        self.arrival_rng = np.random.default_rng([seed, _ARRIVAL_STREAM])
        self._arrival_procs = [Simulator._materialize_arrival(s.arrival)
                               for s in scenario.models]
        self.models = {s.model.name: s.model for s in scenario.models}
        self._full_tables = build_tables(self.models, tuple(self.accs))
        self.deadlines = {
            s.model.name: effective_deadline(s.period_s,
                                             self._full_tables[s.model.name],
                                             s.deadline_s)
            for s in scenario.models
        }
        # cost tables per (model, acc_idx, n_slots)
        self._tables: dict[tuple[str, int, int], object] = {}
        self.free_slots = [int(_SLOTS_PER_ACC)] * len(self.accs)
        self.jobs: dict[int, _PJob] = {}
        self._jid = itertools.count()
        self.events: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self.global_stats = WindowStats()
        self.window_stats = WindowStats()
        self.frames = 0
        self.aborts = 0

    # -- cost lookup ---------------------------------------------------
    def _table(self, model: str, acc_idx: int, slots: int):
        key = (model, acc_idx, slots)
        if key not in self._tables:
            acc = self.accs[acc_idx]
            frac = slots / _SLOTS_PER_ACC
            sub = replace(acc, pes=max(1, int(acc.pes * frac)),
                          dram_bw=acc.dram_bw * frac / len(self.accs),
                          sram_bytes=max(1, int(acc.sram_bytes * frac)))
            # the sub-accelerator table already has its bandwidth share baked
            # in, so build it standalone (shared_bw division done above)
            self._tables[key] = build_cost_table(self.models[model], (sub,),
                                                 shared_bw=False)
        return self._tables[key]

    def _remaining_latency(self, job: _PJob, acc_idx: int, slots: int) -> float:
        table = self._table(job.base_name, acc_idx, slots)
        return float(table.lat[0, job.path[job.pos:]].sum())

    # -- job lifecycle ---------------------------------------------------
    def _push(self, t: float, kind: int, arg) -> None:
        heapq.heappush(self.events, (t, next(self._seq), kind, arg))

    def _create_job(self, model_idx: int, t: float) -> None:
        spec = self.scenario.models[model_idx]
        graph = spec.model
        path = np.asarray(graph.sample_path(self.rng), dtype=np.int64)
        full = self._full_tables[graph.name]
        job = _PJob(
            jid=next(self._jid), model_idx=model_idx, base_name=graph.name,
            path=path, arrival=t, deadline=t + self.deadlines[graph.name],
            worst_energy=float(full.en_max[path].sum()),
        )
        self.jobs[job.jid] = job

    def _finish(self, job: _PJob, t: float, dropped: bool) -> None:
        job.done = True
        if job.slots and job.host_acc >= 0:
            self.free_slots[job.host_acc] += job.slots
            job.slots = 0
        self.jobs.pop(job.jid, None)
        st = self.window_stats.model(job.base_name)
        st.frames += 1
        st.violated += int(dropped or t > job.deadline)
        st.energy_j += job.energy_used
        st.worst_energy_j += job.worst_energy
        self.frames += 1
        if not dropped:
            for dep in self.scenario.dependents_of(job.base_name):
                spec = self.scenario.models[dep]
                if self.rng.random() < spec.trigger_prob:
                    self._create_job(dep, t)

    # -- scheduling -------------------------------------------------------
    def _allocate(self, t: float) -> None:
        """EDF admission with minimal-feasible fission allocation."""
        waiting = sorted((j for j in self.jobs.values()
                          if not j.running and not j.done),
                         key=lambda j: (j.deadline, j.jid))
        for job in waiting:
            slack = job.deadline - t
            best: tuple[int, int] | None = None  # (acc, slots)
            # minimal feasible partition across accelerators
            for acc_idx in range(len(self.accs)):
                for slots in range(1, self.free_slots[acc_idx] + 1):
                    if self._remaining_latency(job, acc_idx, slots) <= slack:
                        if best is None or slots < best[1]:
                            best = (acc_idx, slots)
                        break
            if best is None:
                # infeasible: best effort — all pods of the emptiest acc
                acc_idx = int(np.argmax(self.free_slots))
                if self.free_slots[acc_idx] == 0:
                    continue
                best = (acc_idx, self.free_slots[acc_idx])
            acc_idx, slots = best
            self.free_slots[acc_idx] -= slots
            job.host_acc, job.slots, job.running = acc_idx, slots, True
            self._start_layer(job, t)

    def _start_layer(self, job: _PJob, t: float) -> None:
        table = self._table(job.base_name, job.host_acc, job.slots)
        layer = int(job.path[job.pos])
        dur = float(table.lat[0, layer])
        job.energy_used += float(table.en[0, layer])
        self._push(t + dur, 1, job.jid)

    def _on_layer_done(self, jid: int, t: float) -> None:
        job = self.jobs.get(jid)
        if job is None or job.done:
            return
        job.pos += 1
        if job.pos >= len(job.path):
            self._finish(job, t, dropped=False)
            return
        # layer boundary: release the partition so EDF can re-fission
        self.free_slots[job.host_acc] += job.slots
        job.slots, job.running = 0, False

    def _abort_stale(self, t: float) -> None:
        for j in list(self.jobs.values()):
            period = self.scenario.models[j.model_idx].period_s
            if not j.running and j.pos == 0 and \
                    t > j.deadline + self.stale_periods * period:
                self.aborts += 1
                self._finish(j, t, dropped=True)

    def run(self) -> SimResult:
        for i, spec in enumerate(self.scenario.models):
            if spec.depends_on is None:
                first = self._arrival_procs[i].start(i, spec.period_s,
                                                     self.arrival_rng)
                if first is not None:
                    self._push(first, 0, i)
        self._push(self.window_s, 2, None)
        t = 0.0
        while self.events:
            t, _, kind, arg = heapq.heappop(self.events)
            if t > self.duration_s:
                break
            if kind == 0:
                idx = int(arg)
                self._create_job(idx, t)
                spec = self.scenario.models[idx]
                nxt = self._arrival_procs[idx].next_after(
                    t, spec.period_s, self.arrival_rng)
                if nxt is not None:
                    self._push(nxt, 0, idx)
            elif kind == 1:
                self._on_layer_done(int(arg), t)
            else:
                self.global_stats.merge(self.window_stats)
                self.window_stats = WindowStats()
                self._push(t + self.window_s, 2, None)
            self._abort_stale(t)
            self._allocate(t)
        self.global_stats.merge(self.window_stats)
        return SimResult(
            scenario=self.scenario.name, system=self.system_name,
            scheduler=self.name, duration_s=self.duration_s,
            stats=self.global_stats, uxcost=uxcost(self.global_stats),
            dlv_rate=overall_dlv_rate(self.global_stats),
            norm_energy=overall_norm_energy(self.global_stats),
            frames=self.frames, drops=0, aborts=self.aborts,
            variant_counts={}, windows=[], acc_utilization=[],
        )


def run_planaria(scenario: Scenario, system: str, duration_s: float = 8.0,
                 seed: int = 0, **kw) -> SimResult:
    return PlanariaSimulator(scenario, system, duration_s=duration_s,
                             seed=seed, **kw).run()
