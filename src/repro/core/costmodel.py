"""Analytical per-(layer, accelerator) latency & energy model.

Plays the role MAESTRO/Timeloop play in the paper (Section 3.2: "DREAM uses
energy and latency estimations generated offline using a cost model or a
simulator"). The model is a dataflow-aware roofline:

  latency = max(compute_time, memory_time) + dispatch overhead
  energy  = MACs * E_MAC + DRAM traffic * E_DRAM + SRAM traffic * E_SRAM

Dataflow-dependent terms (this is what creates the hardware heterogeneity the
paper's preference score exploits):

  * WS (NVDLA-like): PEs parallelize K x C (output x input channels).
    Great for pointwise/FC/GEMM layers; poor for depthwise convolutions
    (K==1 per group => parallel work == C only). Weights are resident:
    inputs are re-streamed once per weight tile that exceeds SRAM.
  * OS (ShiDianNao-like): PEs parallelize the output feature map (Y x X,
    falling back to K when the spatial map is tiny). Great for large
    feature maps and depthwise layers; poor for FC layers with one token.
    Outputs are resident: weights are re-streamed once per activation tile
    that exceeds SRAM.

All estimates are deterministic — the predictability of accelerator latency
(paper Section 4.3) is precisely what makes offline tables usable online.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .types import Accelerator, Dataflow, Layer, ModelGraph, OpType

# Energy constants (8-bit edge-accelerator ballpark, pJ):
E_MAC = 0.4e-12          # J per MAC (int8 MAC + local regfile traffic)
E_DRAM = 160e-12         # J per DRAM byte (LPDDR-class)
E_SRAM = 1.2e-12         # J per SRAM byte
P_PE_STATIC = 0.8e-3    # W per PE: leakage + clock tree while the layer
#                          occupies the array (couples energy to *occupancy*:
#                          a big array is fast but burns static power, a small
#                          one is slow but frugal — the Figure-13 tension)
DISPATCH_OVERHEAD_S = 2e-6  # fixed per-layer launch overhead

# Calibration derates vs the idealized analytical model (MAESTRO-class cost
# models report mapping efficiencies well below peak for edge arrays: partial
# tiles, pipeline fill/drain, NoC congestion and DRAM row misses):
MAPPING_EFF = 0.35  # achievable fraction of peak MACs for a tuned mapping
DRAM_EFF = 0.6      # achievable fraction of peak off-chip bandwidth


def _quantized_util(parallel_work: int, pes: int) -> float:
    """PE utilization with edge-quantization: waves of `parallel_work` lanes
    mapped onto `pes` PEs. util = work / (ceil(work/pes) * pes)."""
    if parallel_work <= 0:
        return 1.0 / pes
    waves = math.ceil(parallel_work / pes)
    return parallel_work / (waves * pes)


def _parallel_work(layer: Layer, df: Dataflow) -> int:
    """How many MAC lanes the dataflow can fill for this layer.

    WS (NVDLA): the PE array spatially maps K x C (output x input channels);
    depthwise layers collapse to C lanes (one input channel per group) and
    early layers with tiny C starve the array.
    OS (ShiDianNao-class): the PE array spatially maps *output elements*
    (K x Y x X), so it shines on wide feature maps / depthwise layers but
    gains nothing from input-channel depth.
    """
    if df is Dataflow.WS:
        if layer.op in (OpType.DWCONV, OpType.POOL):
            return layer.C                      # one input channel per group
        return layer.K * layer.C
    else:  # OS
        spatial = max(layer.Y * layer.X, 1)
        if layer.op in (OpType.DWCONV, OpType.POOL):
            return layer.C * spatial
        return layer.K * spatial


#: Dataflow <-> operator affinity (Herald-style): the fraction of peak a
#: well-tiled mapping of this op family reaches on each dataflow. WS arrays
#: excel at channel-deep ops (dense conv, GEMM, FC); OS arrays excel at
#: spatially wide / shallow-accumulation ops (depthwise, pooling, stems).
_MATCH: dict[Dataflow, dict[OpType, float]] = {
    Dataflow.WS: {
        OpType.CONV2D: 1.00, OpType.DWCONV: 0.45, OpType.FC: 0.90,
        OpType.RNN: 0.90, OpType.GEMM: 1.00, OpType.POOL: 0.50,
    },
    Dataflow.OS: {
        OpType.CONV2D: 0.88, OpType.DWCONV: 1.00, OpType.FC: 0.45,
        OpType.RNN: 0.45, OpType.GEMM: 0.80, OpType.POOL: 1.00,
    },
}


def _temporal_eff(layer: Layer, df: Dataflow) -> float:
    return _MATCH[df][layer.op]


def _dram_traffic_bytes(layer: Layer, acc: Accelerator) -> float:
    """Dataflow-dependent off-chip traffic (bytes)."""
    w, i, o = layer.weight_bytes, layer.in_bytes, layer.out_bytes
    usable = 0.5 * acc.sram_bytes  # double-buffering halves usable capacity
    if acc.dataflow is Dataflow.WS:
        # weights resident; inputs re-streamed per weight tile spill
        w_tiles = max(1, math.ceil(w / usable))
        return w + o + i * w_tiles
    else:
        # outputs resident; weights re-streamed per activation tile spill
        a_tiles = max(1, math.ceil((i + o) / usable))
        return i + o + w * a_tiles


def _sram_traffic_bytes(layer: Layer, acc: Accelerator) -> float:
    """Dataflow-dependent on-chip buffer traffic (bytes). This is where WS and
    OS genuinely differ energetically (MAESTRO's buffer-access counts):

      WS holds weights in PE registers; *input activations* are re-read from
      SRAM once per K-tile of the weight array, and partial sums are spilled
      once per C-tile.
      OS holds output psums in PE registers; *weights* are re-read once per
      spatial tile of the output map, inputs re-read per R*S window overlap.
    """
    w, i, o = layer.weight_bytes, layer.in_bytes, layer.out_bytes
    if acc.dataflow is Dataflow.WS:
        c_par = min(max(layer.C, 1), acc.pes)
        k_tile = max(1, acc.pes // c_par)
        k_reads = math.ceil(max(layer.K, 1) / k_tile)
        c_tile = min(max(layer.C, 1), acc.pes)
        psum_spills = math.ceil(max(layer.C, 1) / c_tile)
        return w + i * k_reads + o * (1 + psum_spills)
    else:
        spatial = max(layer.Y * layer.X, 1)
        sp_tiles = math.ceil(spatial / min(spatial, acc.pes))
        return w * sp_tiles + i * layer.R + o


def layer_latency_s(layer: Layer, acc: Accelerator) -> float:
    macs = layer.macs
    pw = _parallel_work(layer, acc.dataflow)
    util = (_quantized_util(pw, acc.pes) * _temporal_eff(layer, acc.dataflow)
            * MAPPING_EFF)
    compute_s = macs / (acc.pes * util * acc.clock_hz)
    memory_s = _dram_traffic_bytes(layer, acc) / (acc.dram_bw * DRAM_EFF)
    return max(compute_s, memory_s) + DISPATCH_OVERHEAD_S


def layer_energy_j(layer: Layer, acc: Accelerator) -> float:
    macs = layer.macs
    dram = _dram_traffic_bytes(layer, acc)
    sram = _sram_traffic_bytes(layer, acc) + dram
    static = layer_latency_s(layer, acc) * acc.pes * P_PE_STATIC
    return macs * E_MAC + dram * E_DRAM + sram * E_SRAM + static


def context_switch_energy_j(new_layer: Layer, prev_out_bytes: int) -> float:
    """Paper Section 3.4: energy to fetch the new model's activation from
    DRAM and flush the switched-out model's activation to DRAM."""
    return (new_layer.in_bytes + prev_out_bytes) * E_DRAM


@dataclass(frozen=True)
class CostTable:
    """Precomputed per-(accelerator, layer) cost arrays for one model.

    lat[a, l] / en[a, l] : latency (s) / energy (J) of layer l on accel a.
    Derived rows used by the scheduler's score computation:
      lat_mean[l]  — mean latency across accelerators  (ToGo, Starvation)
      lat_sum[l]   — summed latency across accelerators (LatPref numerator)
      lat_min[l]   — best-case latency                  (smart frame drop)
      en_sum[l]    — summed energy across accelerators  (Pref_Energy)
      en_max[l]    — worst-case energy                  (UXCost normalizer)
    """

    model_name: str
    lat: np.ndarray
    en: np.ndarray
    in_bytes: np.ndarray
    out_bytes: np.ndarray
    lat_mean: np.ndarray
    lat_sum: np.ndarray
    lat_min: np.ndarray
    en_sum: np.ndarray
    en_max: np.ndarray
    #: isolated full-model latency on the best / worst accelerator —
    #: ``lat.sum(axis=1).min()`` / ``.max()`` hoisted to build time, since
    #: the fleet's offered-load estimates and the effective-deadline rule
    #: re-derive them for every placement probe otherwise
    iso_best_s: float = 0.0
    iso_worst_s: float = 0.0

    @property
    def n_accs(self) -> int:
        return self.lat.shape[0]


#: Memo for build_cost_table keyed by (layers, accelerators, shared_bw).
#: Costs depend only on the layer list and the accelerator mix — NOT on the
#: graph's name — so renamed instances of the same architecture (two zoo
#: builds, fleet placement-namespaced copies like "s12.det") all share one
#: table, and the cache stays bounded by distinct structures, not labels.
#: Layer / Accelerator are frozen dataclasses, so structural equality works.
#: CostTable is frozen and its arrays are never written after construction,
#: so sharing across simulators / fleet nodes is safe.
_TABLE_CACHE: dict[tuple, CostTable] = {}
_TABLE_CACHE_STATS = {"hits": 0, "misses": 0}

#: identity-keyed first level of the memo.  The structural key above hashes
#: the whole ``layers`` tuple (hundreds of frozen Layer dataclasses) on
#: every lookup — profiled as the dominant cost of a cache *hit* once the
#: fleet probes the same graph thousands of times per placement wave.  A
#: graph object's layers tuple never mutates (ModelGraph is frozen), so
#: (layers id, accs id, name) resolves to the same table for the lifetime
#: of those objects; each entry pins its key objects so CPython cannot
#: recycle their ids while the entry lives.  The name is part of the key
#: because relabeled fleet copies ("s12.det") share one layers object.
_FAST_TABLE_CACHE: dict[tuple, tuple] = {}
#: wholesale-cleared when oversized (falls back to the structural level),
#: bounding the object pins on fleet runs with very large stream counts
_FAST_TABLE_MAX = 65536


def table_cache_info() -> dict:
    """Snapshot of the CostTable memo: hits, misses, current size."""
    return {**_TABLE_CACHE_STATS, "size": len(_TABLE_CACHE)}


def clear_table_cache() -> None:
    _TABLE_CACHE.clear()
    _FAST_TABLE_CACHE.clear()
    _TABLE_CACHE_STATS["hits"] = _TABLE_CACHE_STATS["misses"] = 0


def build_cost_table(model: ModelGraph, accs: tuple[Accelerator, ...],
                     shared_bw: bool = True) -> CostTable:
    """Cost table for one model on a multi-accelerator system (memoized).

    ``shared_bw``: Table 2 of the paper specifies 90 GB/s of *shared* off-chip
    bandwidth for the whole chip. The offline tables therefore charge each
    sub-accelerator its proportional share (bw / n_accs) — a deterministic,
    conservative model of shared-bus contention on an edge SoC.
    """
    sb = bool(shared_bw)
    fk = (id(model.layers), id(accs), model.name, sb)
    hit = _FAST_TABLE_CACHE.get(fk)
    if hit is not None and hit[0] is model.layers and hit[1] is accs:
        _TABLE_CACHE_STATS["hits"] += 1
        return hit[2]
    # name-free identity level: fleet churn mints a fresh namespaced label
    # per placement generation, but the layers object underneath is shared —
    # resolve the table by identity before paying the structural key's full
    # layers-tuple hash (hundreds of frozen dataclasses) on every new label
    bk = (id(model.layers), id(accs), sb)
    bhit = _FAST_TABLE_CACHE.get(bk)
    if bhit is not None and bhit[0] is model.layers and bhit[1] is accs:
        _TABLE_CACHE_STATS["hits"] += 1
        cached = bhit[2]
    else:
        key = (model.layers, tuple(accs), sb)
        cached = _TABLE_CACHE.get(key)
        if cached is not None:
            _TABLE_CACHE_STATS["hits"] += 1
        else:
            _TABLE_CACHE_STATS["misses"] += 1
            cached = _build_cost_table(model, tuple(accs), sb)
            _TABLE_CACHE[key] = cached
        if len(_FAST_TABLE_CACHE) >= _FAST_TABLE_MAX:
            _FAST_TABLE_CACHE.clear()
        _FAST_TABLE_CACHE[bk] = (model.layers, accs, cached)
    if cached.model_name != model.name:
        # same structure under another label: share the arrays, relabel
        from dataclasses import replace as _rep
        cached = _rep(cached, model_name=model.name)
    if len(_FAST_TABLE_CACHE) >= _FAST_TABLE_MAX:
        _FAST_TABLE_CACHE.clear()
    _FAST_TABLE_CACHE[fk] = (model.layers, accs, cached)
    return cached


def _build_cost_table(model: ModelGraph, accs: tuple[Accelerator, ...],
                      shared_bw: bool) -> CostTable:
    n_a, n_l = len(accs), len(model.layers)
    if shared_bw and n_a > 1:
        from dataclasses import replace as _rep
        accs = tuple(_rep(a, dram_bw=a.dram_bw / n_a) for a in accs)
    lat = np.empty((n_a, n_l), dtype=np.float64)
    en = np.empty((n_a, n_l), dtype=np.float64)
    for a, acc in enumerate(accs):
        for l, layer in enumerate(model.layers):
            lat[a, l] = layer_latency_s(layer, acc)
            en[a, l] = layer_energy_j(layer, acc)
    in_b = np.array([l.in_bytes for l in model.layers], dtype=np.float64)
    out_b = np.array([l.out_bytes for l in model.layers], dtype=np.float64)
    iso = lat.sum(axis=1)
    return CostTable(
        iso_best_s=float(iso.min()),
        iso_worst_s=float(iso.max()),
        model_name=model.name,
        lat=lat,
        en=en,
        in_bytes=in_b,
        out_bytes=out_b,
        lat_mean=lat.mean(axis=0),
        lat_sum=lat.sum(axis=0),
        lat_min=lat.min(axis=0),
        en_sum=en.sum(axis=0),
        en_max=en.max(axis=0),
    )


# ---------------------------------------------------------------------------
# Inter-node transfer / migration cost model (fleet-level)
# ---------------------------------------------------------------------------
# The per-(layer, accelerator) tables above cost *execution*; splitting a
# cascade pipeline across fleet nodes additionally costs *movement*: a
# cross-node cascade trigger ships the parent stage's output activation over
# the inter-node link, and a migration (join/drain/leave/rebalance) ships the
# moved model's weight state.  Both are charged explicitly — latency delays
# the receiving stage (eating its deadline slack) and energy lands in the
# fleet UXCost merge — so the router can only win by splitting when the
# hardware-match gain exceeds the transfer bill.

#: 10 GbE-class inter-node link defaults (edge cluster ballpark)
XFER_BANDWIDTH_BYTES_S = 1.25e9   # payload bandwidth of the inter-node link
XFER_BASE_LATENCY_S = 200e-6      # per-transfer fixed cost (NIC + RPC + hop)
XFER_ENERGY_PER_BYTE_J = 30e-12   # NIC + switch energy per byte moved


@dataclass(frozen=True)
class TransferModel:
    """Inter-node state-transfer cost: latency + energy per moved byte.

    ``bandwidth_bytes_s`` is the *per-transfer* (endpoint/NIC) rate — what
    a single transfer achieves with the fabric to itself.
    ``link_bandwidth_bytes_s`` is the capacity of the **shared wire**
    between any one node pair: when finite, concurrent transfers on the
    same pair contend (see :class:`ContendedLinks`); the default of
    ``inf`` models an uncontended fabric, in which every transfer takes
    exactly ``transfer_s(nbytes)`` regardless of what else is in flight —
    the historical (PR-3) behavior, reproduced bit-exactly.

    ``bandwidth_bytes_s == 0`` models an air-gapped fleet: every transfer
    takes infinite time, so stage-split placement degenerates to
    whole-pipeline placement (the router can never justify a cross-node
    edge) and migrations are charged energy only.
    """

    bandwidth_bytes_s: float = XFER_BANDWIDTH_BYTES_S
    base_latency_s: float = XFER_BASE_LATENCY_S
    energy_per_byte_j: float = XFER_ENERGY_PER_BYTE_J
    link_bandwidth_bytes_s: float = math.inf

    @property
    def enabled(self) -> bool:
        """Whether cross-node transfers can complete in finite time."""
        return self.bandwidth_bytes_s > 0.0

    @property
    def contended(self) -> bool:
        """Whether per-node-pair links have finite shared capacity."""
        return math.isfinite(self.link_bandwidth_bytes_s)

    @property
    def wire_bandwidth_bytes_s(self) -> float:
        """Rate one transfer realizes on the shared wire: the endpoint
        rate capped by the link capacity."""
        return min(self.bandwidth_bytes_s, self.link_bandwidth_bytes_s)

    def transfer_s(self, nbytes: float) -> float:
        """Wall-clock seconds to move ``nbytes`` between two nodes when
        the pair's link is idle (the uncontended lower bound; realized
        times come from :class:`ContendedLinks`)."""
        if not self.enabled:
            return math.inf
        return (self.base_latency_s
                + float(nbytes) / self.wire_bandwidth_bytes_s)

    def transfer_j(self, nbytes: float) -> float:
        """Link energy (J) to move ``nbytes`` between two nodes."""
        return float(nbytes) * self.energy_per_byte_j

    def to_config(self) -> dict:
        cfg = {"bandwidth_bytes_s": self.bandwidth_bytes_s,
               "base_latency_s": self.base_latency_s,
               "energy_per_byte_j": self.energy_per_byte_j}
        if self.contended:
            # only serialized when finite: keeps uncontended trace metas
            # byte-identical to the PR-3 format (and JSON has no inf)
            cfg["link_bandwidth_bytes_s"] = self.link_bandwidth_bytes_s
        return cfg

    @classmethod
    def from_config(cls, cfg: dict) -> "TransferModel":
        return cls(**cfg)


class ContendedLinks:
    """Realized transfer times over shared per-node-pair links.

    One instance tracks the live occupancy of every inter-node link of a
    fleet run.  The contention law is FIFO service on the shared wire:
    transfers between one (unordered) node pair are serviced in request
    order at ``wire_bandwidth_bytes_s``; a transfer requested while the
    pair's wire is still busy waits for it (the queueing delay), then
    occupies it for ``nbytes / wire_bandwidth`` — so two concurrent
    migrations on one link finish strictly later than either would
    alone, while transfers on *different* node pairs never interact.
    ``base_latency_s`` (NIC + RPC + hop setup) is charged per transfer
    but does not occupy the wire.

    With ``link_bandwidth_bytes_s == inf`` (the default TransferModel)
    the wire is never a bottleneck: no state is kept and every transfer
    takes exactly ``TransferModel.transfer_s(nbytes)`` — bit-identical
    to the historical uncontended model.

    Deterministic by construction: realized times depend only on the
    request sequence, which the fleet clock totally orders — so trace
    replay re-derives identical charges through this same class.
    """

    def __init__(self, model: TransferModel):
        self.model = model
        #: unordered node pair -> time its wire is busy until
        self._busy_until: dict[tuple[int, int], float] = {}
        self.n_transfers = 0
        self.n_queued = 0           # transfers that waited on a busy wire
        self.queued_s = 0.0         # total queueing delay experienced
        #: optional duck-typed metrics registry (repro.obs.MetricsRegistry),
        #: attached by the fleet when observability is on; publishing is
        #: observation only and never alters realized times
        self.metrics = None

    def transfer(self, a: int, b: int, nbytes: float,
                 t: float) -> tuple[float, float]:
        """Request moving ``nbytes`` between nodes ``a`` and ``b`` at time
        ``t``; returns ``(realized wall-clock seconds, energy J)`` and
        books the wire occupancy."""
        m = self.model
        if not m.enabled:
            return math.inf, m.transfer_j(nbytes)
        if not m.contended:
            s, j = m.transfer_s(nbytes), m.transfer_j(nbytes)
            if self.metrics is not None:
                self._publish(a, b, nbytes, 0.0, s, j)
            return s, j
        pair = (a, b) if a <= b else (b, a)
        start = max(t, self._busy_until.get(pair, t))
        service = float(nbytes) / m.wire_bandwidth_bytes_s
        self._busy_until[pair] = start + service
        wait = start - t
        self.n_transfers += 1
        if wait > 0.0:
            self.n_queued += 1
            self.queued_s += wait
        total = wait + m.base_latency_s + service
        joules = m.transfer_j(nbytes)
        if self.metrics is not None:
            self._publish(a, b, nbytes, wait, total, joules)
        return total, joules

    def _publish(self, a: int, b: int, nbytes: float, wait_s: float,
                 total_s: float, joules: float) -> None:
        reg = self.metrics
        lo, hi = (a, b) if a <= b else (b, a)
        reg.counter("link_transfers_total",
                    "transfers routed over shared inter-node links",
                    ("a", "b")).inc(a=lo, b=hi)
        reg.counter("link_bytes_total",
                    "bytes moved over inter-node links").inc(nbytes)
        if wait_s > 0.0:
            reg.counter("link_wait_seconds_total",
                        "queueing delay on busy wires").inc(wait_s)
        reg.counter("link_energy_joules_total",
                    "link energy charged to transfers").inc(joules)
        reg.histogram("link_transfer_seconds",
                      "realized wall seconds per transfer").observe(total_s)


def model_state_bytes(graph: ModelGraph) -> float:
    """Bytes of model state a migration must ship: all layer weights."""
    return float(sum(l.weight_bytes for l in graph.layers))


def activation_bytes(graph: ModelGraph) -> float:
    """Bytes a cross-node cascade trigger ships: the final activation the
    parent stage hands to its dependent (its last layer's output)."""
    return float(graph.layers[-1].out_bytes)


# Deadline convention (Planaria §evaluation: deadlines are set as a multiple
# of each model's isolated latency on the target hardware, clipped to the
# frame period; a floor keeps very light models from getting sub-queueing-
# granularity deadlines). The multiple applies to the *worst* accelerator's
# isolated latency so that any single placement is feasible in isolation —
# violations then come from contention/queueing, which is what a scheduler
# can actually influence.
DEADLINE_SLACK_MULT = 1.15  # k x isolated worst-accelerator latency
DEADLINE_MIN_FRAC = 0.05    # floor: fraction of the frame period


def genai_expected_tokens(meta) -> float:
    """Expected generation length under a variant cap: the mean of the
    token draw clamped into ``[1, max_new_tokens]``."""
    return min(max(float(meta.token_mean), 1.0), float(meta.max_new_tokens))


def genai_iso_s(table: CostTable, meta, n_tokens: float) -> np.ndarray:
    """Per-accelerator isolated latency of an autoregressive job emitting
    ``n_tokens``: the prefill segment once plus ``n_tokens`` repetitions
    of the decode segment.  The plain per-layer sum (``table.lat.sum``)
    counts the decode step exactly once and badly underestimates a
    generation."""
    pl = meta.prefill_len
    return (table.lat[:, :pl].sum(axis=1)
            + float(n_tokens) * table.lat[:, pl:].sum(axis=1))


def effective_deadline(period_s: float, table: CostTable,
                       explicit: float | None = None,
                       graph: ModelGraph | None = None) -> float:
    """Per-frame deadline for a model on a given system (seconds)."""
    if explicit is not None:
        return explicit
    # hoisted to table build time; the ``or`` re-derives it for tables
    # constructed outside _build_cost_table (none in-tree, but cheap)
    iso_worst = table.iso_worst_s or float(table.lat.sum(axis=1).max())
    if graph is not None and graph.genai is not None:
        # autoregressive graphs: the worst generation runs the decode
        # segment max_new_tokens times, not once
        iso_worst = float(genai_iso_s(table, graph.genai,
                                      graph.genai.max_new_tokens).max())
    return min(period_s, max(DEADLINE_SLACK_MULT * iso_worst,
                             DEADLINE_MIN_FRAC * period_s))


def build_tables(
    models: dict[str, ModelGraph], accs: tuple[Accelerator, ...]
) -> dict[str, CostTable]:
    """Cost tables for every model *and* every Supernet variant."""
    out: dict[str, CostTable] = {}
    for name, m in models.items():
        out[name] = build_cost_table(m, accs)
        for v in m.variants:
            out[v.name] = build_cost_table(v, accs)
    return out
