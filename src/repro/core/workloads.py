"""The five RTMM workload scenarios of the paper's Table 3.

Each scenario is a set of concurrent ML pipelines: models with FPS targets,
per-frame deadlines (1/FPS) and control dependencies ("Dep." column). The
dependent model of a pipeline is triggered by its parent's completion with a
configurable probability (paper default: 50%).
"""
from __future__ import annotations

from .types import ModelGraph, ModelSpec, Scenario
from . import zoo

def spec(model: ModelGraph, fps: float, depends_on=None, trigger_prob=0.5,
         deadline_factor: float | None = None) -> ModelSpec:
    """Deadlines: left as None here — the effective per-frame deadline is
    system-dependent (Planaria's convention: a multiple of the model's
    isolated latency on the target hardware, clipped to the frame period)
    and is resolved by ``costmodel.effective_deadline`` at simulator setup."""
    return ModelSpec(model=model, fps=fps, depends_on=depends_on,
                     trigger_prob=trigger_prob,
                     deadline_s=None if deadline_factor is None
                     else deadline_factor / fps)


def vr_gaming(cascade_prob: float = 0.5) -> Scenario:
    hd = zoo.ssd_mobilenet_v2("hand_det_ssd", res=640)
    return Scenario(
        name="VR_Gaming",
        models=(
            spec(zoo.fbnet_c("gaze_fbnet_c"), fps=60),
            spec(hd, fps=30),
            spec(zoo.handpose_net("pose_handpose", res=320), fps=30,
                      depends_on="hand_det_ssd", trigger_prob=cascade_prob),
            spec(zoo.ofa_supernet("ctx_ofa"), fps=30),
            spec(zoo.kws_res8("kws_res8"), fps=15),
            spec(zoo.gnmt("translate_gnmt"), fps=15,
                      depends_on="kws_res8", trigger_prob=cascade_prob),
        ),
    )


def ar_call(cascade_prob: float = 0.5) -> Scenario:
    return Scenario(
        name="AR_Call",
        models=(
            spec(zoo.kws_res8("kws_res8"), fps=15),
            spec(zoo.gnmt("translate_gnmt"), fps=15,
                      depends_on="kws_res8", trigger_prob=cascade_prob),
            spec(zoo.skipnet("ctx_skipnet", res=448), fps=30),
        ),
    )


def drone_outdoor(cascade_prob: float = 0.5) -> Scenario:
    del cascade_prob  # no cascaded pipeline in this scenario (Table 3)
    return Scenario(
        name="Drone_Outdoor",
        models=(
            spec(zoo.ssd_mobilenet_v2("objdet_ssd", res=640), fps=30),
            spec(zoo.trailnet("nav_trailnet"), fps=60),
            spec(zoo.sosnet("vo_sosnet", patches=144), fps=60),
        ),
    )


def drone_indoor(cascade_prob: float = 0.5) -> Scenario:
    del cascade_prob
    return Scenario(
        name="Drone_Indoor",
        models=(
            spec(zoo.ssd_mobilenet_v2("objdet_ssd", res=640), fps=30),
            spec(zoo.rapid_rl("nav_rapid_rl"), fps=60),
            spec(zoo.sosnet("obst_sosnet", patches=144), fps=60),
            spec(zoo.googlenet_car("car_googlenet"), fps=60),
        ),
    )


def ar_social(cascade_prob: float = 0.5) -> Scenario:
    return Scenario(
        name="AR_Social",
        models=(
            spec(zoo.focal_depth("depth_focal"), fps=30),
            spec(zoo.ed_tcn("action_ed_tcn"), fps=30),
            spec(zoo.ssd_mobilenet_v2("face_det_ssd", res=640), fps=30),
            spec(zoo.vgg_voxceleb("verif_vggvox"), fps=30,
                      depends_on="face_det_ssd", trigger_prob=cascade_prob),
            spec(zoo.ofa_supernet("ctx_ofa"), fps=30),
        ),
    )


SCENARIOS = {
    "VR_Gaming": vr_gaming,
    "AR_Call": ar_call,
    "Drone_Outdoor": drone_outdoor,
    "Drone_Indoor": drone_indoor,
    "AR_Social": ar_social,
}


def build_scenario(name: str, cascade_prob: float = 0.5) -> Scenario:
    return SCENARIOS[name](cascade_prob)
