"""The five RTMM workload scenarios of the paper's Table 3.

Historically this module hand-built each scenario; they now live in the
scenario engine's registry (``repro.scenarios.registry``) as declarative
:class:`ScenarioBuilder` instances alongside user-registered and fuzzer-
generated scenarios.  This module keeps the original ``build_scenario`` /
``SCENARIOS`` API as a thin delegation layer so core callers and the
benchmarks are unaffected.
"""
from __future__ import annotations

from .types import Scenario


def build_scenario(name: str, cascade_prob: float = 0.5) -> Scenario:
    from repro.scenarios import registry
    return registry.build(name, cascade_prob=cascade_prob)


def vr_gaming(cascade_prob: float = 0.5) -> Scenario:
    return build_scenario("VR_Gaming", cascade_prob)


def ar_call(cascade_prob: float = 0.5) -> Scenario:
    return build_scenario("AR_Call", cascade_prob)


def drone_outdoor(cascade_prob: float = 0.5) -> Scenario:
    return build_scenario("Drone_Outdoor", cascade_prob)


def drone_indoor(cascade_prob: float = 0.5) -> Scenario:
    return build_scenario("Drone_Indoor", cascade_prob)


def ar_social(cascade_prob: float = 0.5) -> Scenario:
    return build_scenario("AR_Social", cascade_prob)


SCENARIOS = {
    "VR_Gaming": vr_gaming,
    "AR_Call": ar_call,
    "Drone_Outdoor": drone_outdoor,
    "Drone_Indoor": drone_indoor,
    "AR_Social": ar_social,
}
