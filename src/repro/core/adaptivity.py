"""Parameter-probe search engines — the adaptivity core of Section 3.6.

Three hosts share the same idea (perturb a parameter vector, measure one
candidate per feedback window, commit, shrink):

  * the per-node online engine (``scheduler.AdaptivityState``) probes
    (alpha, beta) against live UXCost windows — it subclasses
    :class:`ProbeSearch`, the host-agnostic N-dimensional star probe;
  * the fleet weight tuner (``repro.cluster.router.TunedScoreRouter``)
    probes the routing score weights against fleet telemetry windows with
    :class:`CoordinateProbe`, a seeded coordinate search whose best-wins
    commit rule tolerates the noisier fleet-level signal;
  * the *offline* variant (:func:`optimize_params`) used to study
    convergence: each candidate is evaluated by a full (short) simulation
    and the trajectory is recorded, then compared against a grid-search
    global optimum over the constrained space [0, 2]^2.

Both online probes are plain state machines over ``step(cost, rng)`` —
no simulator, scheduler, or fleet types — which is what lets one module
serve hosts at two different system layers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

PARAM_LO, PARAM_HI = 0.0, 2.0


@dataclass
class ProbeSearch:
    """Radius-shrinking *star* probe over an N-dimensional box.

    The online analogue of :func:`optimize_params`: candidates are the
    current center, its axis neighbors at the current radius, and one
    distant random sample; each call to :meth:`step` records the cost the
    live candidate just achieved and returns the candidate to deploy for
    the next feedback window.  When every candidate is measured the center
    moves to the inverse-cost-weighted interpolation of the two best and
    the radius shrinks; below ``r_min`` the probe parks at the center.

    Hosts: ``repro.core.scheduler.AdaptivityState`` layers per-node
    DLV-drift re-triggering on top; the fleet layer re-arms explicitly via
    :meth:`retrigger` on membership churn and phase events.
    """

    center: np.ndarray
    radius: float = 0.5
    r_min: float = 0.05
    shrink: float = 0.6
    probing: bool = True
    candidates: list[np.ndarray] = field(default_factory=list)
    results: list[tuple[float, np.ndarray]] = field(default_factory=list)
    cand_idx: int = 0
    lo: float = PARAM_LO
    hi: float = PARAM_HI

    def _make_candidates(self, rng: np.random.Generator) -> None:
        n = len(self.center)
        dirs = []
        for i in range(n):
            e = np.zeros(n)
            e[i] = 1.0
            dirs += [e, -e]
        cands = [self.center.copy()]
        cands += [np.clip(self.center + self.radius * d, self.lo, self.hi)
                  for d in dirs]
        # one distant sample (the paper samples neighboring *and* distant
        # pairs)
        cands.append(rng.uniform(self.lo, self.hi, size=n))
        self.candidates = cands
        self.results = []
        self.cand_idx = 0

    def current(self) -> np.ndarray:
        if self.probing and self.candidates:
            return self.candidates[self.cand_idx]
        return self.center

    def retrigger(self, radius: float = 0.4) -> None:
        """Restart the probe from the current center — the response to an
        externally-signalled workload change (stream migration, node
        membership churn, phase event) rather than a detected drift.
        Fresh candidates are drawn on the next step."""
        self.radius = max(self.radius, radius)
        self.probing = True
        self.candidates = []
        self.results = []
        self.cand_idx = 0

    def _on_stop(self) -> None:
        """Hook: the probe just parked (radius fell below ``r_min``)."""

    def step(self, cost: float, rng: np.random.Generator) -> np.ndarray:
        """Record ``cost`` for the live candidate; return the parameters to
        deploy for the next feedback window."""
        if not self.probing:
            return self.center
        if not self.candidates:
            self._make_candidates(rng)
            return self.candidates[0]
        self.results.append((cost, self.candidates[self.cand_idx].copy()))
        self.cand_idx += 1
        if self.cand_idx < len(self.candidates):
            return self.candidates[self.cand_idx]
        # all candidates measured: interpolate between the two best
        self.results.sort(key=lambda r: r[0])
        (u1, p1), (u2, p2) = self.results[0], self.results[1]
        w1, w2 = 1.0 / (u1 + 1e-9), 1.0 / (u2 + 1e-9)
        self.center = np.clip((w1 * p1 + w2 * p2) / (w1 + w2),
                              self.lo, self.hi)
        self.radius *= self.shrink
        if self.radius < self.r_min:
            self.probing = False
            self.candidates = []
            self._on_stop()
            return self.center
        self._make_candidates(rng)
        return self.candidates[0]


@dataclass
class CoordinateProbe:
    """Seeded coordinate search with a best-wins commit rule.

    The fleet-scale analogue of :class:`ProbeSearch`, shaped by two fleet
    realities: feedback windows are *scarce* (a run sees tens, not
    hundreds), and window costs are noisy (the offered load itself drifts
    between windows).  So instead of measuring a full star of 2N+2
    candidates before committing, the probe perturbs **one coordinate at a
    time** — candidates are [center, center + r·span·e_a, center −
    r·span·e_a] — and commits the *best measured candidate* (which may be
    the center itself, bounding the damage a noisy window can do to at
    most one probing window).  After a full pass over ``axis_order`` the
    radius shrinks and one distant seeded sample joins the next pass's
    first mini-cycle, the escape hatch the paper's distant draws provide.

    ``lo``/``hi`` are per-dimension bounds; the probing step along axis
    ``a`` is ``radius * (hi[a] − lo[a]) / 2``, so one radius spans
    heterogeneous weight scales.  Deterministic given the ``rng`` handed
    to :meth:`step`.  Hosts re-arm via :meth:`retrigger` (membership
    churn, phase events) exactly like the per-node probe.
    """

    center: np.ndarray
    lo: np.ndarray
    hi: np.ndarray
    radius: float = 0.5
    r_min: float = 0.08
    shrink: float = 0.7
    #: relative commit margin: a candidate only displaces the center when
    #: its measured cost beats the center's *same-cycle* measurement by
    #: more than this fraction.  Feedback windows are noisy (the workload
    #: itself drifts between them) and a wrong commit persists until
    #: re-probed, while a missed commit merely keeps the status quo — so
    #: the asymmetric risk warrants a deadband.
    margin: float = 0.0
    axis_order: Optional[Sequence[int]] = None
    probing: bool = True
    pass_pos: int = 0                 # position within the current pass
    fresh_pass: bool = False          # add a distant sample this mini-cycle
    candidates: list[np.ndarray] = field(default_factory=list)
    results: list[tuple[float, np.ndarray]] = field(default_factory=list)
    cand_idx: int = 0
    commits: int = 0                  # mini-cycles that moved the center
    steps: int = 0                    # measured windows consumed
    retriggers: int = 0

    def __post_init__(self) -> None:
        self.center = np.asarray(self.center, dtype=np.float64)
        self.lo = np.asarray(self.lo, dtype=np.float64)
        self.hi = np.asarray(self.hi, dtype=np.float64)
        if self.axis_order is None:
            self.axis_order = tuple(range(len(self.center)))
        self.axis_order = tuple(int(a) for a in self.axis_order)

    @property
    def axis(self) -> int:
        """The coordinate the current (or next) mini-cycle perturbs."""
        return self.axis_order[self.pass_pos]

    def _clip(self, p: np.ndarray) -> np.ndarray:
        return np.clip(p, self.lo, self.hi)

    def _make_candidates(self, rng: np.random.Generator) -> None:
        a = self.axis
        step = self.radius * (self.hi[a] - self.lo[a]) / 2.0
        e = np.zeros(len(self.center))
        e[a] = 1.0
        cands = [self.center.copy(),
                 self._clip(self.center + step * e),
                 self._clip(self.center - step * e)]
        if self.fresh_pass:
            cands.append(rng.uniform(self.lo, self.hi))
            self.fresh_pass = False
        # a center pinned at a bound clips a neighbor onto itself — drop
        # the duplicate rather than spend a scarce window re-measuring it
        dedup: list[np.ndarray] = []
        for c in cands:
            if not any(np.array_equal(c, d) for d in dedup):
                dedup.append(c)
        self.candidates = dedup
        self.results = []
        self.cand_idx = 0

    def current(self) -> np.ndarray:
        if self.probing and self.candidates:
            return self.candidates[self.cand_idx]
        return self.center

    def retrigger(self, radius: float = 0.4) -> None:
        """Re-arm after an externally-signalled workload change: widen the
        radius, restart the pass, and re-earn the distant sample."""
        self.radius = max(self.radius, radius)
        self.probing = True
        self.pass_pos = 0
        self.fresh_pass = True
        self.candidates = []
        self.results = []
        self.cand_idx = 0
        self.retriggers += 1

    def step(self, cost: float, rng: np.random.Generator) -> np.ndarray:
        """Record ``cost`` for the live candidate; return the point to
        deploy for the next feedback window."""
        if not self.probing:
            return self.center
        self.steps += 1
        if not self.candidates:
            self._make_candidates(rng)
            return self.candidates[0]
        self.results.append((cost, self.candidates[self.cand_idx].copy()))
        self.cand_idx += 1
        if self.cand_idx < len(self.candidates):
            return self.candidates[self.cand_idx]
        self._commit_and_advance()
        if not self.probing:
            return self.center
        self._make_candidates(rng)
        return self.candidates[0]

    def _commit_and_advance(self) -> None:
        """Mini-cycle complete: best-wins commit, gated by the relative
        margin against the center's own measurement this cycle (the center
        is always candidate 0, so ``results[0]`` is its cost); then advance
        the pass, shrinking the radius after a full one."""
        center_cost = self.results[0][0]
        best_cost, best = min(self.results, key=lambda r: r[0])
        if (not np.array_equal(best, self.center)
                and best_cost < center_cost * (1.0 - self.margin)):
            self.center = best
            self.commits += 1
        self.candidates = []
        self.pass_pos += 1
        if self.pass_pos >= len(self.axis_order):
            self.pass_pos = 0
            self.fresh_pass = True
            self.radius *= self.shrink
            if self.radius < self.r_min:
                self.probing = False

    def step_batch(self, cost_fn: Callable[[np.ndarray], float],
                   rng: np.random.Generator) -> np.ndarray:
        """One feedback window where *all* of the mini-cycle's candidates
        can be scored on the same data (``cost_fn(point) -> cost``): score
        the center and its axis neighbors (plus the pass's distant
        sample), apply the margin-gated best-wins commit, advance the
        pass, and return the new center.

        This is the *hindsight* driver: a host that can re-score recorded
        decisions under counterfactual parameters (e.g. the fleet router
        re-picking nodes for the window's placements against realized
        node outcomes) gets a whole mini-cycle out of every window — and,
        unlike the deploy-and-measure :meth:`step`, never exposes the
        system to an untested candidate.  One commit opportunity per
        window instead of one measurement per window."""
        if not self.probing:
            return self.center
        self.steps += 1
        self._make_candidates(rng)
        self.results = [(float(cost_fn(c)), c.copy())
                        for c in self.candidates]
        self._commit_and_advance()
        return self.center


@dataclass
class SearchTrace:
    points: list[tuple[float, float]] = field(default_factory=list)
    costs: list[float] = field(default_factory=list)
    evals: int = 0

    @property
    def best(self) -> tuple[tuple[float, float], float]:
        k = int(np.argmin(self.costs))
        return self.points[k], self.costs[k]


def optimize_params(
    eval_fn: Callable[[float, float], float],
    init: tuple[float, float] | None = None,
    radius: float = 1.0,
    shrink: float = 0.6,
    r_min: float = 0.05,
    seed: int = 0,
) -> SearchTrace:
    """Radius-shrinking interpolation search (Section 3.6).

    Per step: evaluate the center, eight neighbors at the current radius
    (axis + diagonal — the paper samples "neighboring pairs") and one
    distant random sample; move to the inverse-cost-weighted interpolation
    of the two best; shrink the radius; stop below `r_min`. The initial
    radius spans half the [0, 2]^2 space so a cold (IDLE) start can reach
    any basin; warm starts (workload switches) converge in the first steps.
    """
    rng = np.random.default_rng(seed)
    center = np.asarray(init if init is not None else
                        rng.uniform(PARAM_LO, PARAM_HI, 2), dtype=np.float64)
    trace = SearchTrace()
    cache: dict[tuple[float, float], float] = {}

    def ev(p: np.ndarray) -> float:
        key = (round(float(p[0]), 6), round(float(p[1]), 6))
        if key not in cache:
            cache[key] = float(eval_fn(*key))
            trace.evals += 1
        return cache[key]

    trace.points.append((float(center[0]), float(center[1])))
    trace.costs.append(ev(center))
    r = radius
    d = 0.7071
    dirs = np.array([(1, 0), (-1, 0), (0, 1), (0, -1),
                     (d, d), (d, -d), (-d, d), (-d, -d)], dtype=np.float64)
    while r >= r_min:
        cands = [center] + [np.clip(center + r * dd, PARAM_LO, PARAM_HI)
                            for dd in dirs]
        cands.append(rng.uniform(PARAM_LO, PARAM_HI, 2))
        scored = sorted(((ev(c), tuple(c)) for c in cands), key=lambda x: x[0])
        (u1, p1), (u2, p2) = scored[0], scored[1]
        w1, w2 = 1.0 / (u1 + 1e-9), 1.0 / (u2 + 1e-9)
        center = np.clip(
            (w1 * np.asarray(p1) + w2 * np.asarray(p2)) / (w1 + w2),
            PARAM_LO, PARAM_HI,
        )
        trace.points.append((float(center[0]), float(center[1])))
        trace.costs.append(ev(center))
        r *= shrink
    return trace


def grid_search(
    eval_fn: Callable[[float, float], float], n: int = 9
) -> tuple[tuple[float, float], float, np.ndarray]:
    """Brute-force global optimum over [0,2]^2 (the Figure-3 heat map)."""
    xs = np.linspace(PARAM_LO, PARAM_HI, n)
    grid = np.empty((n, n))
    best, best_p = np.inf, (0.0, 0.0)
    for i, a in enumerate(xs):
        for j, b in enumerate(xs):
            c = float(eval_fn(float(a), float(b)))
            grid[i, j] = c
            if c < best:
                best, best_p = c, (float(a), float(b))
    return best_p, best, grid
