"""Offline (alpha, beta) optimization — the search of Figures 3/10/11.

The online engine (scheduler.AdaptivityState) uses the same radius-shrinking
method on live UXCost windows; this module exposes the *offline* variant used
to study convergence: each candidate is evaluated by a full (short) simulation
and the trajectory is recorded, then compared against a grid-search global
optimum over the constrained space [0, 2]^2.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

PARAM_LO, PARAM_HI = 0.0, 2.0


@dataclass
class SearchTrace:
    points: list[tuple[float, float]] = field(default_factory=list)
    costs: list[float] = field(default_factory=list)
    evals: int = 0

    @property
    def best(self) -> tuple[tuple[float, float], float]:
        k = int(np.argmin(self.costs))
        return self.points[k], self.costs[k]


def optimize_params(
    eval_fn: Callable[[float, float], float],
    init: tuple[float, float] | None = None,
    radius: float = 1.0,
    shrink: float = 0.6,
    r_min: float = 0.05,
    seed: int = 0,
) -> SearchTrace:
    """Radius-shrinking interpolation search (Section 3.6).

    Per step: evaluate the center, eight neighbors at the current radius
    (axis + diagonal — the paper samples "neighboring pairs") and one
    distant random sample; move to the inverse-cost-weighted interpolation
    of the two best; shrink the radius; stop below `r_min`. The initial
    radius spans half the [0, 2]^2 space so a cold (IDLE) start can reach
    any basin; warm starts (workload switches) converge in the first steps.
    """
    rng = np.random.default_rng(seed)
    center = np.asarray(init if init is not None else
                        rng.uniform(PARAM_LO, PARAM_HI, 2), dtype=np.float64)
    trace = SearchTrace()
    cache: dict[tuple[float, float], float] = {}

    def ev(p: np.ndarray) -> float:
        key = (round(float(p[0]), 6), round(float(p[1]), 6))
        if key not in cache:
            cache[key] = float(eval_fn(*key))
            trace.evals += 1
        return cache[key]

    trace.points.append((float(center[0]), float(center[1])))
    trace.costs.append(ev(center))
    r = radius
    d = 0.7071
    dirs = np.array([(1, 0), (-1, 0), (0, 1), (0, -1),
                     (d, d), (d, -d), (-d, d), (-d, -d)], dtype=np.float64)
    while r >= r_min:
        cands = [center] + [np.clip(center + r * dd, PARAM_LO, PARAM_HI)
                            for dd in dirs]
        cands.append(rng.uniform(PARAM_LO, PARAM_HI, 2))
        scored = sorted(((ev(c), tuple(c)) for c in cands), key=lambda x: x[0])
        (u1, p1), (u2, p2) = scored[0], scored[1]
        w1, w2 = 1.0 / (u1 + 1e-9), 1.0 / (u2 + 1e-9)
        center = np.clip(
            (w1 * np.asarray(p1) + w2 * np.asarray(p2)) / (w1 + w2),
            PARAM_LO, PARAM_HI,
        )
        trace.points.append((float(center[0]), float(center[1])))
        trace.costs.append(ev(center))
        r *= shrink
    return trace


def grid_search(
    eval_fn: Callable[[float, float], float], n: int = 9
) -> tuple[tuple[float, float], float, np.ndarray]:
    """Brute-force global optimum over [0,2]^2 (the Figure-3 heat map)."""
    xs = np.linspace(PARAM_LO, PARAM_HI, n)
    grid = np.empty((n, n))
    best, best_p = np.inf, (0.0, 0.0)
    for i, a in enumerate(xs):
        for j, b in enumerate(xs):
            c = float(eval_fn(float(a), float(b)))
            grid[i, j] = c
            if c < best:
                best, best_p = c, (float(a), float(b))
    return best_p, best, grid
