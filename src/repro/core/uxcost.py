"""UXCost (Algorithm 2): the paper's EDP-analogue for real-time workloads.

UXCost = (sum_m Rate_DLV[m]) * (sum_m NormEnergy[m])

  Rate_DLV[m]    — deadline-violated frames / total frames in the window,
                   floored at 1/(2*total_frames) when zero (Alg. 2 lines 7-8).
  NormEnergy[m]  — actual energy / worst-case energy, where worst case pairs
                   every executed layer with its most expensive accelerator.

Dropped frames count as violations (completion time = infinity, Section 4.2.1)
and their *would-have-run* path still contributes to the worst-case energy
normalizer, so dropping trades DLV for energy exactly as the paper describes.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class ModelWindowStats:
    frames: int = 0
    violated: int = 0
    energy_j: float = 0.0
    worst_energy_j: float = 0.0
    #: head-to-tail pipeline latency, recorded at *tail* completions only
    #: (models with live dependents record nothing): the time from the
    #: pipeline's head frame arrival to this completion, wire/queue time
    #: included.  ``pipe_frames`` counts the recorded completions;
    #: ``pipe_latency_s`` sums their latencies (mean = sum / count).
    pipe_frames: int = 0
    pipe_latency_s: float = 0.0

    def merge(self, other: "ModelWindowStats") -> None:
        self.frames += other.frames
        self.violated += other.violated
        self.energy_j += other.energy_j
        self.worst_energy_j += other.worst_energy_j
        self.pipe_frames += other.pipe_frames
        self.pipe_latency_s += other.pipe_latency_s


@dataclass
class WindowStats:
    """Per-model statistics for one UXCost evaluation window T_exec."""

    per_model: dict[str, ModelWindowStats] = field(default_factory=dict)

    def model(self, name: str) -> ModelWindowStats:
        if name not in self.per_model:
            self.per_model[name] = ModelWindowStats()
        return self.per_model[name]

    def merge(self, other: "WindowStats") -> None:
        for name, st in other.per_model.items():
            self.model(name).merge(st)


def rate_dlv(st: ModelWindowStats) -> float:
    if st.frames == 0:
        return 0.0
    if st.violated == 0:
        return 1.0 / (2.0 * st.frames)   # Alg. 2 lines 7-8
    return st.violated / st.frames


def norm_energy(st: ModelWindowStats) -> float:
    if st.worst_energy_j <= 0.0:
        return 0.0
    return st.energy_j / st.worst_energy_j


def uxcost(stats: WindowStats) -> float:
    """Algorithm 2: overall UXCost for a window."""
    overall_dlv = sum(rate_dlv(st) for st in stats.per_model.values())
    overall_en = sum(norm_energy(st) for st in stats.per_model.values())
    return overall_dlv * overall_en


def overall_dlv_rate(stats: WindowStats) -> float:
    frames = sum(st.frames for st in stats.per_model.values())
    viol = sum(st.violated for st in stats.per_model.values())
    return viol / frames if frames else 0.0


def overall_pipeline_latency(stats: WindowStats) -> float:
    """Mean head-to-tail pipeline latency (s) over every recorded tail
    completion in the window — the end-to-end metric next to per-model
    DLV (0.0 when no pipeline completed head-to-tail)."""
    n = sum(st.pipe_frames for st in stats.per_model.values())
    total = sum(st.pipe_latency_s for st in stats.per_model.values())
    return total / n if n else 0.0


def overall_norm_energy(stats: WindowStats) -> float:
    worst = sum(st.worst_energy_j for st in stats.per_model.values())
    actual = sum(st.energy_j for st in stats.per_model.values())
    return actual / worst if worst > 0 else 0.0
