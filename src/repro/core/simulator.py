"""Discrete-event simulator for RTMM workloads on multi-accelerator systems.

The engine owns: frame arrivals (pluggable arrival processes — strict
periodic per Table-3 FPS by default, or jittered / Poisson / bursty /
diurnal streams from ``repro.scenarios.arrivals``), control-dependency
triggering (cascaded pipelines), dynamic-path sampling (SkipNet skips /
RAPID-RL early exits), per-layer dispatch onto accelerators, deadline & energy
accounting (UXCost windows), and stale-job hygiene. Schedulers (DREAM and the
baselines) plug in through the `SchedulerBase` interface and only make
(job, accelerator, n_layers) decisions.

Workload dynamicity beyond path sampling comes from two hooks:

  * a ``phase_script`` (``repro.scenarios.phases.PhaseScript``) applies timed
    scenario mutations — FPS retargeting, cascade-probability shifts, models
    joining and leaving — as first-class PHASE events;
  * ``record=True`` captures the run's external stochastic input (head
    arrivals + phase actions) as a ``repro.scenarios.trace.Trace``, and
    ``replay=<trace>`` feeds a recorded trace back in.  Arrival randomness
    lives on a dedicated generator, so a replayed run with the same ``seed``
    reproduces the live run exactly (same jobs, dispatches, UXCost).

Determinism: `numpy.random.Generator`s seeded at construction drive every
stochastic draw; the event heap is tie-broken with a monotone sequence number.
Core imports nothing from ``repro.scenarios`` at module scope — arrival
processes and phase actions are duck-typed, materialized lazily.
"""
from __future__ import annotations

import heapq
import itertools
import warnings
from bisect import insort
from collections import deque
from dataclasses import dataclass, replace
from typing import Callable, Optional

import numpy as np

from .costmodel import CostTable, E_DRAM, build_tables, effective_deadline
from .engine import EngineConfig
from .types import Accelerator, ModelGraph, ModelSpec, Scenario, SYSTEMS
from .uxcost import (WindowStats, uxcost, overall_dlv_rate,
                     overall_norm_energy, overall_pipeline_latency)

ARRIVAL, DONE, WINDOW, PHASE, INJECT = 0, 1, 2, 3, 4

#: profiler keys per event kind (indexed by the constants above)
_EVENT_NAMES = ("arrival", "done", "window", "phase", "inject")

#: arrival-process rng stream id, kept distinct from the path/cascade stream
#: so trace replay (which consumes no arrival randomness) stays bit-exact.
_ARRIVAL_STREAM = 0xA221

#: token-count rng stream id (autoregressive generation lengths), distinct
#: from both the path/cascade stream and the arrival stream: legacy
#: (genai-free) populations never touch it, and replay feeds recorded draws
#: back without consuming it — both directions stay bit-exact.
_TOKEN_STREAM = 0x70C3

#: EWMA smoothing factor for the per-model generation-length predictor
#: (Sparse-DySta-style: completed generations feed the estimate).
TOKEN_EWMA_ALPHA = 0.5

#: Python-list mirrors of a CostTable's per-accelerator rows, keyed by
#: ``id(table.lat)`` with the array pinned so the id cannot be recycled.
#: ``.tolist()`` round-trips float64 exactly; the dispatch hot path sums a
#: handful of these per event, where scalar list indexing beats a numpy
#: fancy-index + reduction.  Wholesale-cleared when oversized.
_ROW_CACHE: dict[int, tuple] = {}
_ROW_CACHE_MAX = 4096


def _py_rows(table: CostTable) -> tuple:
    key = id(table.lat)
    hit = _ROW_CACHE.get(key)
    if hit is not None and hit[0] is table.lat:
        return hit
    if len(_ROW_CACHE) >= _ROW_CACHE_MAX:
        _ROW_CACHE.clear()
    entry = (table.lat, table.lat.tolist(), table.en.tolist(),
             table.in_bytes.tolist(), table.out_bytes.tolist())
    _ROW_CACHE[key] = entry
    return entry


def _genai_sched_cum(table: CostTable, path: np.ndarray, prefill_len: int,
                     decode_len: int, pred_tokens: float) -> np.ndarray:
    """Scheduler-visible remaining-time profile of an autoregressive job.

    ``out[pos]`` is the *predicted* mean remaining latency at path position
    ``pos``: the rest of the current phase (prefill tail, or the current
    decode step's tail) plus ``pred_tokens`` worth of further decode steps —
    the length predictor's estimate, not the sampled truth.  All three
    scheduler arms (scalar fast path, numpy reference, SoA batch) read this
    one precomputed array, so they agree bit-for-bit by construction.
    """
    lm = table.lat_mean
    pl, dl = prefill_len, decode_len
    decode_idx = path[pl: pl + dl]
    step_s = float(lm[decode_idx].sum())
    step_cum = [float(lm[decode_idx[w:]].sum()) for w in range(dl)]
    out = np.zeros(len(path) + 1)
    for pos in range(len(path)):
        if pos < pl:
            out[pos] = (float(lm[path[pos: pl]].sum())
                        + pred_tokens * step_s)
        else:
            w = (pos - pl) % dl
            done = (pos - pl) // dl
            out[pos] = (step_cum[w]
                        + max(pred_tokens - done - 1.0, 0.0) * step_s)
    return out


class JobTable:
    """Structure-of-arrays mirror of the live job set (the slab core's
    substrate).  One row per live :class:`Job`, appended in jid order and
    tombstoned on finish, so ``alive`` rows always enumerate the job dict's
    iteration order.  Columns hold exactly the float64 values the scalar
    hot paths read off the Job object — ``togo_mean``/``togo_min`` are the
    sequential suffix-cumsum reads (``Job.togo()``/``min_togo()``) while
    ``togo_sched`` is the *pairwise* ``togo_seconds`` sum the scheduler
    scores with; the two differ in the last bits and must never be merged
    (see docs/performance.md).  ``lat_n``/``en_n`` cache the next layer's
    per-accelerator cost rows so a batched MapScore pass is two fancy
    gathers instead of a Python loop.

    Maintenance is eager at every point ``pos``/``deadline``/``path`` can
    move (create, block completion, variant switch, inject anchor, finish,
    purge); compaction runs when tombstones outnumber live rows, preserving
    relative (jid) order.
    """

    __slots__ = ("cap", "n", "dead", "n_accs", "row_of", "jid", "arrival",
                 "deadline", "t_cmpl", "energy", "pos", "togo_mean",
                 "togo_min", "togo_sched", "lat_sum_n", "en_sum_n", "in_b_n",
                 "lat_mean_n", "base_id", "is_tail", "alive", "cost_stale",
                 "lat_n", "en_n")

    _F8 = ("arrival", "deadline", "t_cmpl", "energy", "togo_mean",
           "togo_min", "togo_sched", "lat_sum_n", "en_sum_n", "in_b_n",
           "lat_mean_n")

    def __init__(self, n_accs: int, cap: int = 64):
        self.cap = cap
        self.n = 0              # rows in use (live + tombstones)
        self.dead = 0
        self.n_accs = n_accs
        self.row_of: dict[int, int] = {}
        self.jid = np.zeros(cap, np.int64)
        self.pos = np.zeros(cap, np.int64)
        self.base_id = np.zeros(cap, np.int64)
        self.is_tail = np.zeros(cap, bool)
        self.alive = np.zeros(cap, bool)
        #: next-layer cost columns below are refreshed lazily (the batch
        #: scheduler arm is their only reader): True = row's lat_sum_n /
        #: en_sum_n / in_b_n / lat_mean_n / lat_n / en_n lag job.pos
        self.cost_stale = np.zeros(cap, bool)
        for name in self._F8:
            setattr(self, name, np.zeros(cap))
        self.lat_n = np.zeros((cap, n_accs))
        self.en_n = np.zeros((cap, n_accs))

    def grow(self) -> None:
        self.cap *= 2
        for name in ("jid", "pos", "base_id", "is_tail", "alive",
                     "cost_stale", *self._F8, "lat_n", "en_n"):
            old = getattr(self, name)
            new = np.zeros((self.cap,) + old.shape[1:], old.dtype)
            new[: self.n] = old[: self.n]
            setattr(self, name, new)

    def live_rows(self) -> np.ndarray:
        """Row indices of live jobs, ascending — i.e. jid/dict order."""
        return np.flatnonzero(self.alive[: self.n])

    def compact(self) -> None:
        keep = self.live_rows()
        m = len(keep)
        for name in ("jid", "pos", "base_id", "is_tail", "cost_stale",
                     *self._F8, "lat_n", "en_n"):
            arr = getattr(self, name)
            arr[:m] = arr[keep]
        self.alive[:m] = True
        self.alive[m: self.n] = False
        self.n = m
        self.dead = 0
        self.row_of = {int(j): i for i, j in enumerate(self.jid[:m])}


@dataclass
class Job:
    """One inference request (a frame of one model) — the paper's 'task'."""

    jid: int
    model_idx: int              # index into scenario.models
    base_name: str              # stats key (Supernet variants share it)
    graph_name: str             # concrete graph (may be a variant)
    table: CostTable
    path: np.ndarray            # sampled layer indices
    cum_mean: np.ndarray        # suffix sums of lat_mean over path (ToGo)
    cum_min: np.ndarray         # suffix sums of lat_min over path (min_to_go)
    path_list: list             # path.tolist() — dispatch-loop fast view
    arrival: float
    deadline: float
    #: pipeline origin: the head frame's arrival time, inherited down the
    #: cascade (and across nodes, wire time included) — tail completions
    #: record ``t - origin`` as head-to-tail pipeline latency
    origin: float = 0.0
    pos: int = 0
    t_cmpl: float = 0.0         # last layer completion (Alg.1 T_cmpl)
    running: bool = False
    done: bool = False
    dropped: bool = False
    energy_used: float = 0.0
    worst_energy: float = 0.0
    is_tail: bool = True        # no dependents (frame-drop condition 3)
    variant_locked: bool = False
    # ---- autoregressive (genai) jobs only; zero/None on classic frames.
    # ``sched_cum`` replaces the true-path ToGo in every scheduler arm: the
    # scheduler scores against the length *predictor*'s estimate, never the
    # sampled token count (which the engine alone knows).
    tokens_total: int = 0       # sampled generation length (tokens)
    prefill_len: int = 0        # path positions [0, prefill_len) = prompt
    decode_len: int = 0         # layers per decode step (token boundary)
    pred_tokens: float = 0.0    # predictor estimate, frozen at creation
    sched_cum: Optional[np.ndarray] = None  # predicted ToGo by position
    sched_list: Optional[list] = None       # .tolist() fast view

    @property
    def n_layers(self) -> int:
        return len(self.path)

    @property
    def finished_exec(self) -> bool:
        return self.pos >= len(self.path)

    def togo(self) -> float:
        return float(self.cum_mean[self.pos]) if self.pos < self.n_layers else 0.0

    def min_togo(self) -> float:
        return float(self.cum_min[self.pos]) if self.pos < self.n_layers else 0.0

    def slack(self, t: float) -> float:
        return self.deadline - t


@dataclass
class AccState:
    idx: int
    acc: Accelerator
    busy: bool = False
    busy_until: float = 0.0
    cur_job: Optional[Job] = None
    prev_base: Optional[str] = None   # base model name of last executed job
    prev_base_id: int = -1            # its interned id (SoA batch arm key)
    prev_jid: int = -1                # its jid (token-preemption detection)
    prev_out_bytes: float = 0.0       # its last layer's activation bytes
    busy_time: float = 0.0            # cumulative, for utilization reporting


@dataclass
class Dispatch:
    job: Job
    acc_idx: int
    n_layers: int = 1
    reserve_worst: bool = False  # static scheduling: hold the slot for the
    # worst-case duration even if the sampled path finishes earlier


class SchedulerBase:
    """Scheduler plug-in interface."""

    name = "base"

    def on_job_created(self, sim: "Simulator", job: Job) -> None:  # noqa: D401
        pass

    def on_window(self, sim: "Simulator", stats: WindowStats, uxc: float) -> None:
        pass

    def schedule(self, sim: "Simulator", t: float) -> Optional[Dispatch]:
        raise NotImplementedError


@dataclass
class SimResult:
    scenario: str
    system: str
    scheduler: str
    duration_s: float
    stats: WindowStats
    uxcost: float
    dlv_rate: float
    norm_energy: float
    frames: int
    drops: int
    aborts: int
    variant_counts: dict[str, int]
    windows: list[tuple[float, float, float, float]]  # (t, uxcost, alpha, beta)
    acc_utilization: list[float]
    trace: Optional[object] = None      # recorded Trace when record=True
    pipeline_latency_s: float = 0.0     # mean head-to-tail latency (s)

    def summary(self) -> str:
        return (f"{self.scenario:>14s} {self.system:>10s} {self.scheduler:>16s} "
                f"UXCost={self.uxcost:8.4f} DLV={self.dlv_rate:6.3f} "
                f"E={self.norm_energy:6.3f} frames={self.frames} drops={self.drops}")


class Simulator:
    #: Structure-of-arrays slab-stepping toggle.  When on, the engine
    #: mirrors every live job into a flat :class:`JobTable` and
    #: ``step_until`` advances in *time slabs*: between the boundaries an
    #: external observer can see (the fleet clock's interleave points,
    #: window/phase/arrival events), block completions bypass the global
    #: event heap through a slab-local done lane and job state lands in
    #: flat arrays.  Bit-identical to the scalar per-event oracle by
    #: construction (tests/test_vectorized_equiv.py flips this flag).
    soa_slab = True

    def __init__(
        self,
        scenario: Scenario,
        system: str | tuple[Accelerator, ...],
        scheduler: SchedulerBase,
        duration_s: float = 8.0,
        seed: int = 0,
        window_s: float = 0.5,
        stale_periods: float = 2.0,
        cs_latency_s: float = 0.0,
        phase_script=None,
        record: bool = False,
        replay=None,
        genai_predictor: bool = True,
        engine: "EngineConfig | str | None" = None,
        obs=None,
        obs_node=None,
        soa_slab: "bool | None" = None,
    ):
        self.scenario = scenario
        self.system_name = system if isinstance(system, str) else "custom"
        self.accs_spec = SYSTEMS[system] if isinstance(system, str) else system
        self.scheduler = scheduler
        if soa_slab is not None:
            # legacy flag shim: pre-EngineConfig callers toggled the slab
            # arm directly; fold it into the config so one mechanism rules
            warnings.warn(
                "Simulator(soa_slab=...) is deprecated; pass "
                "engine=EngineConfig(..., soa_slab=...) instead",
                DeprecationWarning, stacklevel=2)
            cfg = EngineConfig.make(engine) or EngineConfig()
            engine = replace(cfg, soa_slab=soa_slab)
        self.engine = EngineConfig.make(engine)
        if self.engine is not None:
            # instance-level pins; engine=None keeps class-attr behavior
            self.engine.apply_simulator(self)
        self.duration_s = duration_s
        self.window_s = window_s
        self.stale_periods = stale_periods
        self.cs_latency_s = cs_latency_s
        self.rng = np.random.default_rng(seed)
        self.arrival_rng = np.random.default_rng([seed, _ARRIVAL_STREAM])
        self.token_rng = np.random.default_rng([seed, _TOKEN_STREAM])
        #: length predictor toggle — False runs the blind ablation (every
        #: autoregressive job priced at its variant's max_new_tokens cap)
        self.genai_predictor = genai_predictor
        #: per-model EWMA of completed generation lengths
        self._tok_ewma: dict[str, float] = {}

        #: live pipeline specs — phase scripts mutate these, not the
        #: (immutable) scenario the simulator was constructed from
        self.specs: list[ModelSpec] = list(scenario.models)
        self.active: list[bool] = [True] * len(self.specs)
        #: name -> spec index and parent name -> dependent spec indices,
        #: maintained on join (specs are append-only and names unique) so
        #: the per-event lookups need no linear rescan of the spec list
        self._name_idx: dict[str, int] = {}
        self._deps_idx: dict[str, list[int]] = {}
        for i, s in enumerate(self.specs):
            self._name_idx.setdefault(s.model.name, i)   # first match wins
            if s.depends_on is not None:
                self._deps_idx.setdefault(s.depends_on, []).append(i)
        #: lazy (stale-threshold, jid) min-heap guarding _abort_stale: the
        #: scan over ready jobs only runs when some pushed threshold is
        #: actually due.  Entries are conservative — jobs re-push on
        #: deadline/period changes and finished jobs' entries just expire —
        #: so the guard never skips a scan the threshold scan would run.
        self._stale_heap: list[tuple[float, int]] = []

        self.models: dict[str, ModelGraph] = {
            s.model.name: s.model for s in self.specs
        }
        self.tables: dict[str, CostTable] = build_tables(self.models, self.accs_spec)
        self.graphs: dict[str, ModelGraph] = dict(self.models)
        for m in self.models.values():
            for v in m.variants:
                self.graphs[v.name] = v

        #: system-dependent per-model deadlines (Planaria convention)
        self.deadlines: dict[str, float] = {
            s.model.name: effective_deadline(s.period_s,
                                             self.tables[s.model.name],
                                             s.deadline_s,
                                             graph=s.model)
            for s in self.specs
        }
        self.accs = [AccState(i, a) for i, a in enumerate(self.accs_spec)]
        #: SoA job mirror (None when the scalar oracle path is active)
        self.soa: Optional[JobTable] = (
            JobTable(len(self.accs)) if self.soa_slab else None)
        #: base-name intern table shared with the scheduler batch arm
        self._base_ids: dict[str, int] = {}
        #: slab done lane: while a slab is open, _dispatch routes DONE
        #: events here (sorted (t, seq, acc_idx) triples) instead of the
        #: global heap; flushed back on slab exit so peek_t() is unchanged
        self._slab_sink: Optional[list] = None
        self._slab_dones: list[tuple[float, int, int]] = []
        self.events: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self.t = 0.0
        self.jobs: dict[int, Job] = {}
        self.ready: dict[int, Job] = {}
        self._jid = itertools.count()

        self.global_stats = WindowStats()
        self.window_stats = WindowStats()
        #: running (frames, violated) totals over global_stats — updated at
        #: each window merge so fleet DLV telemetry reads O(1) counters
        #: instead of walking per_model every node advance
        self.merged_frames = 0
        self.merged_violated = 0
        self.windows: list[tuple[float, float, float, float]] = []
        self.variant_counts: dict[str, int] = {}
        # stream-level variant pins (SLO graceful degradation): model name ->
        # variant graph every future job of that model is created on
        self._variant_override: dict[str, ModelGraph] = {}
        self.drops = 0
        self.aborts = 0
        self.frames = 0
        # frame-drop condition 4: outcome history (True == dropped) per model
        self.drop_history: dict[str, list[bool]] = {
            s.model.name: [] for s in self.specs
        }
        self.drop_window = 10
        self.max_drops_per_window = 2

        if replay is not None and phase_script is not None:
            raise ValueError("replay traces carry their own phase events; "
                             "pass either phase_script or replay, not both")
        self.phase_script = phase_script
        self.replay = replay
        self._replay_queues: dict[str, deque] = {}
        self._replay_tokens: dict[str, deque] = {}
        if replay is not None:
            rs = replay.meta.get("scenario")
            if rs is not None and rs != scenario.name:
                raise ValueError(f"trace was recorded for scenario {rs!r}, "
                                 f"not {scenario.name!r}")
            self._replay_queues = {
                name: deque(ts)
                for name, ts in replay.arrivals_by_model().items()
            }
            self._replay_tokens = {
                name: deque(ns)
                for name, ns in replay.tokens_by_model().items()
            }
            # the predictor setting is part of the recorded run's identity
            self.genai_predictor = bool(
                replay.meta.get("genai_predictor", True))
        self.recorder = None
        self.trace = None
        if record:
            from repro.scenarios.trace import TraceRecorder
            meta = {
                "scenario": scenario.name, "system": self.system_name,
                "seed": seed, "duration_s": duration_s,
                "window_s": window_s,
            }
            if not self.genai_predictor:
                # non-default only, so legacy traces keep identical headers
                meta["genai_predictor"] = False
            self.recorder = TraceRecorder(meta)
        #: cross-simulator cascade surface (used by the fleet layer when a
        #: pipeline is split across nodes): completions of models named here
        #: are queued on ``pending_completions`` for an external driver to
        #: drain and forward; both stay empty in single-node runs, so the
        #: engine's behavior and RNG consumption are untouched
        self.export_completions: set[str] = set()
        #: (model name, completion time, pipeline origin, job uid) — uid is
        #: the completing job's span uid when tracing, else None; the fleet
        #: threads it through inject_arrival so cross-node child spans link
        #: back to their parent for critical-path extraction
        self.pending_completions: list[
            tuple[str, float, float, Optional[str]]] = []
        self._arrival_procs = [self._materialize_arrival(s.arrival)
                               for s in self.specs]
        #: per-stream time origin: arrival processes run in stream-local
        #: time (0 at stream start), so a mid-run join at t anchors its
        #: process — including any internal MMPP/diurnal clock — at t
        self._arrival_origin = [0.0] * len(self.specs)
        self._started = False

        # ------------------------------------------------ observability
        # ``obs`` is a duck-typed bundle (repro.obs.Obs): tracer / metrics
        # / profiler attributes, each possibly None.  Core never imports
        # repro.obs; every hook below guards with ``is not None``, so the
        # disabled path costs one attribute check and consumes no RNG —
        # traced runs stay bit-identical to bare ones.  ``obs_node`` tags
        # spans/metrics with the hosting fleet node id.
        self.obs = obs
        self._tracer = getattr(obs, "tracer", None)
        self._metrics = getattr(obs, "metrics", None)
        self._profiler = getattr(obs, "profiler", None)
        self._obs_node = obs_node
        self._node_lbl = "-" if obs_node is None else str(obs_node)
        self._span_of: dict[int, int] = {}     # jid -> open job span id
        self._segs_of: dict[int, list] = {}    # jid -> [(t0, t1)] exec blocks
        self._uid_of: dict[int, str] = {}      # jid -> cross-node job uid
        if self._metrics is not None:
            self._m_frames = self._metrics.counter(
                "sim_frames_total", "completed frames (incl. drops)",
                ("node", "model"))
            self._m_violations = self._metrics.counter(
                "sim_violations_total", "deadline-violated frames",
                ("node", "model"))
            self._m_drops = self._metrics.counter(
                "sim_drops_total", "dropped/aborted frames",
                ("node", "model"))
            self._m_energy = self._metrics.counter(
                "sim_energy_joules_total", "energy charged to frames",
                ("node",))
            self._m_latency = self._metrics.histogram(
                "sim_frame_latency_seconds",
                "frame arrival -> completion latency", ("node",))

    @staticmethod
    def _materialize_arrival(arrival):
        """None -> legacy periodic; dict -> from_config; else duck-typed.
        Instances are shallow-copied: a process carries per-stream state
        (MMPP clocks), so streams must never share one."""
        import copy
        from repro.scenarios.arrivals import Periodic, arrival_from_config
        if arrival is None:
            return Periodic()
        if isinstance(arrival, dict):
            return arrival_from_config(arrival)
        return copy.copy(arrival)

    # --------------------------------------------------------- live specs
    def _index_of(self, name: str) -> int:
        idx = self._name_idx.get(name)
        if idx is None:
            raise KeyError(name)
        return idx

    def _dependents_of(self, name: str) -> list[int]:
        # _deps_idx preserves spec append order, so the filtered list is
        # element-identical to the original enumerate() scan
        return [i for i in self._deps_idx.get(name, ())
                if self.active[i]]

    def _is_chain_tail(self, idx: int) -> bool:
        name = self.specs[idx].model.name
        if name in self.export_completions:
            return False                # has remote (cross-node) dependents
        return not any(self.active[i]
                       for i in self._deps_idx.get(name, ()))

    # ------------------------------------------------------------- events
    def _push(self, t: float, kind: int, arg: object) -> None:
        heapq.heappush(self.events, (t, next(self._seq), kind, arg))

    def _schedule_head_arrivals(self) -> None:
        for i, spec in enumerate(self.specs):
            if spec.depends_on is None:
                self._schedule_stream_arrival(i, after_t=None)

    def _push_phase_events(self) -> None:
        if self.replay is not None:
            if self.replay.phases:
                from repro.scenarios.phases import PhaseAction
                for t, cfg in self.replay.phases:
                    self._push(t, PHASE, PhaseAction.from_config(cfg))
        elif self.phase_script is not None:
            for t, action in self.phase_script:
                self._push(t, PHASE, action)

    def _schedule_stream_arrival(self, idx: int,
                                 after_t: Optional[float]) -> None:
        """Queue stream ``idx``'s next head arrival.  ``after_t`` is the
        absolute time of the arrival just processed (None = stream start).
        Replay pops recorded times; live runs ask the arrival process in
        stream-local time and shift by the stream's origin."""
        spec = self.specs[idx]
        if self.replay is not None:
            q = self._replay_queues.get(spec.model.name)
            if q:
                self._push(q.popleft(), ARRIVAL, idx)
            return
        proc = self._arrival_procs[idx]
        origin = self._arrival_origin[idx]
        if after_t is None:
            nxt = proc.start(idx, spec.period_s, self.arrival_rng)
        else:
            nxt = proc.next_after(after_t - origin, spec.period_s,
                                  self.arrival_rng)
        if nxt is not None:
            self._push(origin + nxt, ARRIVAL, idx)

    # ------------------------------------------------------ phase actions
    def _apply_phase(self, action, t: float) -> None:
        kind, payload = action.kind, action.payload
        if kind == "set_fps":
            self._set_fps(self._index_of(payload["model"]), payload["fps"])
        elif kind == "scale_fps":
            targets = payload.get("models")
            for i, s in enumerate(self.specs):
                if targets is None or s.model.name in targets:
                    self._set_fps(i, s.fps * payload["factor"])
        elif kind == "set_trigger_prob":
            prob = payload["prob"]
            if not 0.0 <= prob <= 1.0:   # traces may be hand-edited
                raise ValueError(f"set_trigger_prob: {prob} outside [0, 1]")
            i = self._index_of(payload["model"])
            self.specs[i] = replace(self.specs[i], trigger_prob=prob)
        elif kind == "leave":
            self.active[self._index_of(payload["model"])] = False
        elif kind == "join":
            from repro.scenarios.phases import join_entry
            self._join_spec(join_entry(action).to_spec(), t)
        else:
            raise ValueError(f"unknown phase action kind {kind!r}")
        if self.recorder is not None:
            self.recorder.phase(t, action.to_config())

    def _set_fps(self, idx: int, fps: float) -> None:
        if not (np.isfinite(fps) and fps > 0):
            # a non-positive period would schedule arrivals backwards and
            # keep the event loop below duration_s forever
            raise ValueError(f"set_fps: fps must be positive, got {fps}")
        spec = replace(self.specs[idx], fps=float(fps))
        self.specs[idx] = spec
        name = spec.model.name
        # the in-flight arrival event still uses the old period; the stream
        # converges to the new rate from the next inter-arrival onward
        self.deadlines[name] = effective_deadline(
            spec.period_s, self.tables[name], spec.deadline_s,
            graph=spec.model)
        # the stale-abort threshold of queued head jobs moves with the
        # period — re-arm their lazy-heap entries so a shrunk grace window
        # still fires on time (old entries expire harmlessly)
        for j in self.ready.values():
            if j.model_idx == idx and j.pos == 0:
                heapq.heappush(
                    self._stale_heap,
                    (j.deadline + self.stale_periods * spec.period_s, j.jid))

    def _join_spec(self, spec: ModelSpec, t: float) -> None:
        name = spec.model.name
        if name in self.models:
            raise ValueError(f"join: model {name!r} already in the scenario "
                             "(leave has no rejoin; use a fresh name)")
        # joins arrive from phase scripts and hand-editable replay traces,
        # which bypass ScenarioBuilder.validate — re-check the hazards here
        # (a non-positive period would schedule arrivals backwards and keep
        # the event loop below duration_s forever)
        if not (np.isfinite(spec.fps) and spec.fps > 0):
            raise ValueError(f"join: fps must be positive, got {spec.fps}")
        if not 0.0 <= spec.trigger_prob <= 1.0:
            raise ValueError(f"join: trigger_prob {spec.trigger_prob} "
                             "outside [0, 1]")
        if spec.depends_on is not None and spec.depends_on not in self.models:
            raise ValueError(f"join: {name!r} depends on {spec.depends_on!r},"
                             " which is not in the scenario")
        self.models[name] = spec.model
        self.tables.update(build_tables({name: spec.model}, self.accs_spec))
        self.graphs[name] = spec.model
        for v in spec.model.variants:
            self.graphs[v.name] = v
        self.deadlines[name] = effective_deadline(
            spec.period_s, self.tables[name], spec.deadline_s,
            graph=spec.model)
        self.drop_history[name] = []
        idx = len(self.specs)
        self.specs.append(spec)
        self.active.append(True)
        self._name_idx.setdefault(name, idx)     # first match wins
        if spec.depends_on is not None:
            self._deps_idx.setdefault(spec.depends_on, []).append(idx)
        self._arrival_procs.append(self._materialize_arrival(spec.arrival))
        self._arrival_origin.append(t)
        if spec.depends_on is None:
            self._schedule_stream_arrival(idx, after_t=None)

    # --------------------------------------------- external-driver surface
    def join_model(self, spec: ModelSpec, t: float) -> None:
        """Add a pipeline stage at time ``t`` (fleet routers place streams
        through this; equivalent to a ``join`` phase action)."""
        self._join_spec(spec, t)

    def leave_model(self, name: str, t: float) -> None:
        """Stop a model's arrivals and cascade triggers at time ``t``.
        Already-created jobs still execute and count toward stats."""
        del t  # takes effect immediately; kept for call-site symmetry
        self.active[self._index_of(name)] = False

    def purge_model(self, name: str) -> int:
        """Discard every not-yet-running job of ``name`` without counting
        frames or violations — the load-release half of a stream
        *departure*: the stream's user walked away, so its queued frames
        stop mattering and must not count as violations or drops.  Jobs
        currently executing finish normally (an accelerator cannot abandon
        a launched layer) and still count.  Energy is the exception: a job
        evicted *between* dispatch blocks (queued with ``pos > 0``) already
        burned real joules, which the stream's final UXCost entry must keep
        — energy spent is never un-spent, mirroring how migration transfer
        energy is charged.  Returns the number of jobs purged."""
        idx = self._index_of(name)
        gone = [j for j in self.jobs.values()
                if j.model_idx == idx and not j.running]
        for j in gone:
            if j.energy_used > 0.0:
                self.window_stats.model(j.base_name).energy_j += j.energy_used
            j.done = True
            self.ready.pop(j.jid, None)
            self.jobs.pop(j.jid, None)
            if self.soa is not None:
                self._soa_kill(j.jid)
            if self._tracer is not None:
                self._uid_of.pop(j.jid, None)
                span = self._span_of.pop(j.jid, None)
                if span is not None:
                    self._tracer.close(
                        span, self.t, outcome="purged", violated=False,
                        energy_j=j.energy_used, variant=j.graph_name,
                        segs=[list(s)
                              for s in self._segs_of.pop(j.jid, ())])
        return len(gone)

    def apply_action(self, action, t: float) -> None:
        """Apply a phase action (``repro.scenarios.phases.PhaseAction``) on
        behalf of an external driver — the fleet layer forwards fleet-level
        phase events (e.g. load shifts) to the hosting nodes through this,
        exactly as a node-local phase script would."""
        self._apply_phase(action, t)

    def inject_arrival(self, name: str, t: float,
                       deadline_anchor: Optional[float] = None,
                       origin: Optional[float] = None,
                       parent_uid: Optional[str] = None,
                       xfer_s: float = 0.0) -> None:
        """Queue one externally-triggered frame of ``name`` at time ``t``
        (the fleet layer forwards cross-node cascade triggers through this).
        ``deadline_anchor`` backdates the deadline clock — a trigger that
        spent transfer latency on the wire arrives at ``t`` but its deadline
        anchors at the parent's completion time, so cross-node latency eats
        real slack.  ``origin`` carries the pipeline's head arrival time
        (defaults to ``t``) so tail completions can report head-to-tail
        pipeline latency.  ``parent_uid``/``xfer_s`` are observability
        pass-throughs (parent job span uid and wire seconds spent) — they
        affect tracing only, never scheduling.  The injected frame
        schedules no follow-up arrival."""
        self._push(t, INJECT, (self._index_of(name), deadline_anchor, origin,
                               parent_uid, xfer_s))

    # ----------------------------------------------------- SoA job mirror
    def _soa_append(self, job: Job) -> None:
        soa = self.soa
        row = soa.n
        if row == soa.cap:
            soa.grow()
        soa.jid[row] = job.jid
        soa.arrival[row] = job.arrival
        soa.deadline[row] = job.deadline
        soa.t_cmpl[row] = job.t_cmpl
        soa.energy[row] = 0.0
        soa.base_id[row] = self._base_ids.setdefault(job.base_name,
                                                     len(self._base_ids))
        soa.is_tail[row] = job.is_tail
        soa.alive[row] = True
        soa.row_of[job.jid] = row
        soa.n = row + 1
        self._soa_refresh(job, row)

    def _soa_refresh(self, job: Job, row: int) -> None:
        """Re-derive the pos/path-dependent columns of ``row`` — called
        exactly when ``job.pos`` moves (block completion) or the path and
        table change under it (supernet/SLO variant switch).  The
        next-layer cost columns are only flagged stale here; the batch
        scheduler arm (their sole reader) refreshes them on demand via
        :meth:`_soa_cost_refresh`."""
        soa = self.soa
        pos = job.pos
        tab = job.table
        soa.pos[row] = pos
        soa.togo_mean[row] = job.cum_mean[pos]
        soa.togo_min[row] = job.cum_min[pos]
        soa.energy[row] = job.energy_used
        soa.cost_stale[row] = True
        # the scheduler scores with the *pairwise* remaining-path sum
        # (mapscore.togo_seconds), not the sequential suffix cumsum above —
        # compute it here and seed the per-job memo so the scalar arm
        # never recomputes it.  Autoregressive jobs instead read the
        # precomputed predicted profile (the scheduler must not see the
        # sampled token count).
        togo = (job.sched_list[pos] if job.sched_list is not None
                else float(tab.lat_mean[job.path[pos:]].sum()))
        soa.togo_sched[row] = togo
        job._togo_at = (pos, id(tab))      # type: ignore[attr-defined]
        job._togo_v = togo                 # type: ignore[attr-defined]

    def _soa_cost_refresh(self, job: Job, row: int) -> None:
        """Bring ``row``'s next-layer cost columns up to date with
        ``job.pos`` (lazy half of :meth:`_soa_refresh`)."""
        soa = self.soa
        tab = job.table
        nxt = int(job.path[job.pos])
        soa.lat_sum_n[row] = tab.lat_sum[nxt]
        soa.en_sum_n[row] = tab.en_sum[nxt]
        soa.in_b_n[row] = tab.in_bytes[nxt]
        soa.lat_mean_n[row] = tab.lat_mean[nxt]
        soa.lat_n[row] = tab.lat[:, nxt]
        soa.en_n[row] = tab.en[:, nxt]
        soa.cost_stale[row] = False

    def _soa_kill(self, jid: int) -> None:
        soa = self.soa
        row = soa.row_of.pop(jid, None)
        if row is None:
            return
        soa.alive[row] = False
        soa.dead += 1
        if soa.dead > 16 and soa.dead > soa.n - soa.dead:
            soa.compact()

    # --------------------------------------------------------------- jobs
    def _draw_tokens(self, name: str, meta, t: float) -> int:
        """Sample (or replay) one generation length.  Draws live on the
        dedicated token stream, so genai-free populations and the
        path/cascade stream are untouched; recorded draws replay without
        consuming the stream (per-model FIFO in creation order)."""
        q = self._replay_tokens.get(name)
        if q:
            n = int(q.popleft())
        else:
            n = int(min(self.token_rng.geometric(
                1.0 / max(float(meta.token_mean), 1.0)),
                meta.max_new_tokens))
        if self.recorder is not None:
            self.recorder.tokens(t, name, n)
        return n

    def _predict_tokens(self, name: str, meta) -> float:
        """Length predictor: EWMA of this model's completed generation
        lengths, clamped to [1, cap].  Blind mode — and a cold predictor —
        prices every job at the cap (the static worst case)."""
        cap = float(meta.max_new_tokens)
        if not self.genai_predictor:
            return cap
        prev = self._tok_ewma.get(name)
        if prev is None:
            return cap
        return min(max(prev, 1.0), cap)

    def _create_job(self, model_idx: int, t: float,
                    origin: Optional[float] = None,
                    parent_uid: Optional[str] = None,
                    xfer_s: float = 0.0) -> Job:
        spec = self.specs[model_idx]
        graph = spec.model
        table = self.tables[graph.name]
        g = graph.genai
        if g is not None:
            n_tok = self._draw_tokens(graph.name, g, t)
            path = np.asarray(graph.genai_path(n_tok), dtype=np.int64)
        else:
            path = np.asarray(graph.sample_path(self.rng), dtype=np.int64)
        lat_mean = table.lat_mean[path]
        lat_min = table.lat_min[path]
        cum_mean = np.concatenate([np.cumsum(lat_mean[::-1])[::-1], [0.0]])
        cum_min = np.concatenate([np.cumsum(lat_min[::-1])[::-1], [0.0]])
        job = Job(
            jid=next(self._jid),
            model_idx=model_idx,
            base_name=graph.name,
            graph_name=graph.name,
            table=table,
            path=path,
            path_list=path.tolist(),
            cum_mean=cum_mean,
            cum_min=cum_min,
            arrival=t,
            deadline=t + self.deadlines[graph.name],
            origin=t if origin is None else origin,
            t_cmpl=t,
            worst_energy=float(table.en_max[path].sum()),
            is_tail=self._is_chain_tail(model_idx),
        )
        if g is not None:
            job.tokens_total = n_tok
            job.prefill_len = g.prefill_len
            job.decode_len = len(graph.layers) - g.prefill_len
            job.pred_tokens = self._predict_tokens(graph.name, g)
            job.sched_cum = _genai_sched_cum(
                table, path, job.prefill_len, job.decode_len,
                job.pred_tokens)
            job.sched_list = job.sched_cum.tolist()
        self.jobs[job.jid] = job
        self.ready[job.jid] = job
        heapq.heappush(
            self._stale_heap,
            (job.deadline + self.stale_periods
             * self.specs[model_idx].period_s, job.jid))
        if self.soa is not None:
            self._soa_append(job)       # variant override refreshes below
        override = self._variant_override.get(graph.name)
        if override is not None:
            # SLO degradation pin: every frame of this stream starts on the
            # pinned variant; locked so the per-job supernet engine
            # (DreamScheduler._maybe_switch_variant) keeps its hands off
            self.switch_variant(job, override)
            job.variant_locked = True
            self.variant_counts[override.name] = \
                self.variant_counts.get(override.name, 0) + 1
        if self._tracer is not None:
            uid = (f"n{self._obs_node}:j{job.jid}"
                   if self._obs_node is not None else f"j{job.jid}")
            self._uid_of[job.jid] = uid
            self._segs_of[job.jid] = []
            self._span_of[job.jid] = self._tracer.open(
                "job", t, uid=uid, model=job.base_name,
                node=self._obs_node, origin=job.origin,
                deadline=job.deadline, parent=parent_uid,
                xfer_s=xfer_s, tail=job.is_tail)
        self.scheduler.on_job_created(self, job)
        return job

    def swap_variant(self, name: str, level: int, t: float) -> ModelGraph:
        """Stream-level graceful degradation (the fleet SLO subsystem's
        actuator): pin model ``name`` to supernet-variant ``level`` — 0
        restores the original graph, k selects ``variants[k-1]`` (ordered
        heavy -> light, clamped to the ladder depth).  Takes effect for
        every job created from now on; jobs already queued or running are
        untouched (frames in flight keep their quality).  Stats keys and
        the ``worst_energy`` normalizer stay on the base graph, exactly as
        per-job supernet switching does.  Autoregressive models degrade
        *mid-generation* as well: the new level's ``max_new_tokens`` cap is
        applied to this model's queued (not running) jobs at their next
        token boundary — a long generation under pressure finishes early
        with what it has.  Returns the now-active graph."""
        graph = self.specs[self._index_of(name)].model
        if level <= 0 or not graph.variants:
            self._variant_override.pop(name, None)
            active = graph
        else:
            active = graph.variants[min(int(level), len(graph.variants)) - 1]
            self._variant_override[name] = active
        if graph.genai is not None and active.genai is not None:
            self._genai_truncate_queued(name, active.genai.max_new_tokens, t)
        return active

    def _genai_truncate_queued(self, name: str, cap: int, t: float) -> None:
        """Mid-generation degradation actuator: clamp the generation length
        of ``name``'s queued (not running) jobs to ``cap``, never below the
        tokens already (partially) emitted.  A job whose position already
        reaches the clamped path end completes immediately with what it
        has; running blocks are untouched (an accelerator cannot abandon a
        launched layer).  Promotions (cap >= sampled length) are no-ops, so
        classic populations and every pre-genai trace are unaffected."""
        idx = self._index_of(name)
        finished: list[Job] = []
        for job in self.jobs.values():
            if (job.model_idx != idx or job.running or job.done
                    or job.tokens_total <= 0):
                continue
            pl, dl = job.prefill_len, job.decode_len
            done_tok = 0 if job.pos <= pl else -((pl - job.pos) // dl)
            new_t = min(job.tokens_total, max(done_tok, int(cap)))
            if new_t >= job.tokens_total:
                continue
            table = job.table
            path = job.path[: pl + new_t * dl]
            lat_mean = table.lat_mean[path]
            lat_min = table.lat_min[path]
            job.path = path
            job.path_list = path.tolist()
            job.cum_mean = np.concatenate(
                [np.cumsum(lat_mean[::-1])[::-1], [0.0]])
            job.cum_min = np.concatenate(
                [np.cumsum(lat_min[::-1])[::-1], [0.0]])
            job.tokens_total = new_t
            job.pred_tokens = min(job.pred_tokens, float(new_t))
            job.sched_cum = _genai_sched_cum(table, path, pl, dl,
                                             job.pred_tokens)
            job.sched_list = job.sched_cum.tolist()
            if job.pos >= len(path):
                finished.append(job)
                continue
            if self.soa is not None:
                row = self.soa.row_of.get(job.jid)
                if row is not None:
                    self._soa_refresh(job, row)
        for job in finished:
            self._finish_job(job, t, dropped=False)

    def switch_variant(self, job: Job, variant: ModelGraph) -> None:
        """Supernet switching: swap the (not-yet-started) job to a lighter
        weight-sharing variant. worst_energy keeps the original's normalizer.
        Autoregressive jobs keep their sampled token count, truncated to the
        variant's ``max_new_tokens`` cap (the degradation-ladder knob)."""
        assert job.pos == 0 and not job.running
        table = self.tables[variant.name]
        g = variant.genai
        if g is not None and job.tokens_total > 0:
            n_tok = min(job.tokens_total, g.max_new_tokens)
            path = np.asarray(variant.genai_path(n_tok), dtype=np.int64)
        else:
            path = np.asarray(variant.worst_path(), dtype=np.int64)
        lat_mean = table.lat_mean[path]
        lat_min = table.lat_min[path]
        job.graph_name = variant.name
        job.table = table
        job.path = path
        job.path_list = path.tolist()
        job.cum_mean = np.concatenate([np.cumsum(lat_mean[::-1])[::-1], [0.0]])
        job.cum_min = np.concatenate([np.cumsum(lat_min[::-1])[::-1], [0.0]])
        if g is not None and job.tokens_total > 0:
            job.tokens_total = n_tok
            job.prefill_len = g.prefill_len
            job.decode_len = len(variant.layers) - g.prefill_len
            job.pred_tokens = min(job.pred_tokens, float(g.max_new_tokens))
            job.sched_cum = _genai_sched_cum(
                table, path, job.prefill_len, job.decode_len,
                job.pred_tokens)
            job.sched_list = job.sched_cum.tolist()
        elif job.tokens_total > 0:
            # the variant dropped the genai spec: the job becomes a classic
            # worst-path frame — clear the autoregressive view
            job.tokens_total = 0
            job.prefill_len = 0
            job.decode_len = 0
            job.pred_tokens = 0.0
            job.sched_cum = None
            job.sched_list = None
        if self.soa is not None:
            row = self.soa.row_of.get(job.jid)
            if row is not None:
                self._soa_refresh(job, row)

    def _finish_job(self, job: Job, t: float, dropped: bool) -> None:
        if self.soa is not None:
            self._soa_kill(job.jid)
        job.done = True
        job.dropped = dropped
        self.ready.pop(job.jid, None)
        self.jobs.pop(job.jid, None)
        violated = dropped or (t > self.deadline_of(job))
        st = self.window_stats.model(job.base_name)
        st.frames += 1
        st.violated += int(violated)
        st.energy_j += job.energy_used
        st.worst_energy_j += job.worst_energy
        self.frames += 1
        hist = self.drop_history[job.base_name]
        hist.append(dropped)
        if len(hist) > self.drop_window:
            hist.pop(0)
        uid = None
        if self._tracer is not None:
            uid = self._uid_of.pop(job.jid, None)
            span = self._span_of.pop(job.jid, None)
            if span is not None:
                self._tracer.close(
                    span, t, outcome="dropped" if dropped else "done",
                    violated=bool(violated), energy_j=job.energy_used,
                    variant=job.graph_name,
                    segs=[list(s) for s in self._segs_of.pop(job.jid, ())])
        if self._metrics is not None:
            self._m_frames.inc(node=self._node_lbl, model=job.base_name)
            if violated:
                self._m_violations.inc(node=self._node_lbl,
                                       model=job.base_name)
            if dropped:
                self._m_drops.inc(node=self._node_lbl, model=job.base_name)
            if job.energy_used > 0.0:
                self._m_energy.inc(job.energy_used, node=self._node_lbl)
            self._m_latency.observe(t - job.arrival, node=self._node_lbl)
        if not dropped:
            if job.tokens_total > 0:
                # length-predictor update: completed generations feed the
                # per-model EWMA (drops carry no length signal)
                prev = self._tok_ewma.get(job.base_name)
                tok = float(job.tokens_total)
                self._tok_ewma[job.base_name] = (
                    tok if prev is None
                    else (1.0 - TOKEN_EWMA_ALPHA) * prev
                    + TOKEN_EWMA_ALPHA * tok)
            # a completed tail (no dependents, local or remote) closes its
            # pipeline: record head-arrival -> tail-completion latency
            if job.is_tail:
                st.pipe_frames += 1
                st.pipe_latency_s += t - job.origin
            # trigger control-dependent models (cascade) on completion;
            # children inherit the pipeline origin
            for dep_idx in self._dependents_of(job.base_name):
                spec = self.specs[dep_idx]
                if self.rng.random() < spec.trigger_prob:
                    self._create_job(dep_idx, t, origin=job.origin,
                                     parent_uid=uid)
            # remote dependents (pipeline stages on other fleet nodes):
            # report the completion; the fleet clock drains and forwards
            if job.base_name in self.export_completions:
                self.pending_completions.append((job.base_name, t,
                                                 job.origin, uid))

    def deadline_of(self, job: Job) -> float:
        return job.deadline

    def drop_job(self, job: Job, t: float) -> None:
        assert not job.running
        self.drops += 1
        self._finish_job(job, t, dropped=True)

    def can_drop(self, base_name: str) -> bool:
        """Frame-drop condition 4: bounded drop rate per model."""
        hist = self.drop_history[base_name]
        return sum(hist[-self.drop_window:]) < self.max_drops_per_window

    def _abort_stale(self, t: float) -> None:
        """Simulator hygiene: a frame that has not *started* by
        deadline + stale_periods * period is abandoned (counts violated)."""
        heap = self._stale_heap
        if not heap or heap[0][0] >= t:
            # every queued head job's threshold is >= the heap minimum
            # (entries are re-armed whenever deadline or period shrink the
            # threshold), so no job can satisfy the strict t > threshold
            # test below — the ready scan would find nothing
            return
        stale = [
            j for j in self.ready.values()
            if j.pos == 0 and t > j.deadline
            + self.stale_periods * self.specs[j.model_idx].period_s
        ]
        for j in stale:
            self.aborts += 1
            self._finish_job(j, t, dropped=True)
        # expired entries are spent: any job still queued with threshold
        # < t was just aborted above (entries with threshold == t stay —
        # the strict test only fires for them at a later t)
        while heap and heap[0][0] < t:
            heapq.heappop(heap)

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, d: Dispatch, t: float) -> None:
        job, acc = d.job, self.accs[d.acc_idx]
        assert not acc.busy and not job.running and not job.finished_exec
        if (self.recorder is not None and acc.prev_jid >= 0
                and acc.prev_jid != job.jid):
            pj = self.jobs.get(acc.prev_jid)
            if (pj is not None and not pj.done and not pj.running
                    and pj.tokens_total > 0 and pj.pos > pj.prefill_len):
                # token-level preemption: the decode loop this accelerator
                # was advancing yields mid-generation to another job —
                # informational record (replay derives nothing from it)
                self.recorder.preempt(t, pj.base_name, acc.idx)
        n = min(d.n_layers, job.n_layers - job.pos)
        if n < 8:
            # numpy reduces sequentially below 8 elements (pairwise blocking
            # starts at 8), so this scalar loop is bit-identical to
            # table.lat[acc.idx, layers].sum() — and skips two fancy-index
            # array allocations per dispatch (path_list keeps the loop on
            # plain ints instead of numpy scalars)
            layers = job.path_list[job.pos: job.pos + n]
            rows = _py_rows(job.table)
            lrow = rows[1][acc.idx]
            erow = rows[2][acc.idx]
            dur = 0.0
            energy = 0.0
            for li in layers:
                dur += lrow[li]
                energy += erow[li]
            if acc.prev_base is not None and acc.prev_base != job.base_name:
                energy += (rows[3][layers[0]] + acc.prev_out_bytes) * E_DRAM
                dur += self.cs_latency_s
        else:
            layers = job.path[job.pos: job.pos + n]
            dur = float(job.table.lat[acc.idx, layers].sum())
            energy = float(job.table.en[acc.idx, layers].sum())
            if acc.prev_base is not None and acc.prev_base != job.base_name:
                energy += (float(job.table.in_bytes[layers[0]])
                           + acc.prev_out_bytes) * E_DRAM
                dur += self.cs_latency_s
        reserve = dur
        if d.reserve_worst:
            # static scheduling reserves the worst-case (full) path duration
            full = self.graphs[job.graph_name].worst_path()
            reserve = float(job.table.lat[acc.idx, np.asarray(full[job.pos:])].sum())
            reserve = max(reserve, dur)
        job.energy_used += energy
        job.running = True
        job._pending_n = n  # type: ignore[attr-defined]
        job._pending_done_at = t + dur  # type: ignore[attr-defined]
        if self._tracer is not None:
            # reserve >= dur, so completion records done_at == t + dur:
            # this block is the job's exact execution interval
            segs = self._segs_of.get(job.jid)
            if segs is not None:
                segs.append((t, t + dur))
        self.ready.pop(job.jid, None)
        acc.busy = True
        acc.cur_job = job
        acc.busy_until = t + reserve
        acc.busy_time += reserve
        sink = self._slab_sink
        if sink is None:
            self._push(t + reserve, DONE, acc.idx)
        else:
            # slab done lane: same (t, seq) total order as the heap, but a
            # sorted insert into a <= n_accs entry list instead of a push
            # onto the full event heap
            insort(sink, (t + reserve, next(self._seq), acc.idx))

    def _complete(self, acc_idx: int, t: float) -> None:
        acc = self.accs[acc_idx]
        job = acc.cur_job
        assert job is not None
        n = job._pending_n  # type: ignore[attr-defined]
        done_at = min(job._pending_done_at, t)  # type: ignore[attr-defined]
        last_layer = job.path_list[job.pos + n - 1]
        job.pos += n
        job.t_cmpl = done_at
        job.running = False
        acc.busy = False
        acc.cur_job = None
        acc.prev_base = job.base_name
        acc.prev_jid = job.jid
        acc.prev_out_bytes = _py_rows(job.table)[4][last_layer]
        soa = self.soa
        if soa is not None:
            acc.prev_base_id = self._base_ids[job.base_name]
        if job.finished_exec:
            self._finish_job(job, done_at, dropped=False)
        else:
            self.ready[job.jid] = job
            if soa is not None:
                row = soa.row_of[job.jid]
                soa.t_cmpl[row] = done_at
                self._soa_refresh(job, row)

    # --------------------------------------------------------------- run
    def idle_accs(self) -> list[AccState]:
        return [a for a in self.accs if not a.busy]

    def ready_jobs(self) -> list[Job]:
        return list(self.ready.values())

    def active_jobs(self) -> list[Job]:
        """Ready or currently-executing jobs (frame-drop condition 2 scope)."""
        return [j for j in self.jobs.values() if not j.done]

    def _drain_schedule(self, t: float) -> None:
        self._abort_stale(t)
        while True:
            if not self.ready or all(a.busy for a in self.accs):
                return
            d = self.scheduler.schedule(self, t)
            if d is None:
                return
            self._dispatch(d, t)

    def start(self, at_t: float = 0.0) -> None:
        """Arm the engine: queue initial head arrivals, phase events, and the
        first UXCost window.  ``run()`` calls this; external drivers (the
        fleet clock in ``repro.cluster``) call it directly — a node joining a
        running fleet at time t passes ``at_t=t`` so its window clock starts
        there. (Head arrivals of a pre-populated scenario always anchor at
        stream-local 0; fleet nodes start empty and gain streams via
        ``join_model``, which anchors at the join time.)"""
        if self._started:
            raise RuntimeError("Simulator.start() called twice")
        self._started = True
        self._schedule_head_arrivals()
        self._push_phase_events()
        self._push(at_t + self.window_s, WINDOW, None)

    def peek_t(self) -> Optional[float]:
        """Time of the next queued event (None when exhausted).  WINDOW
        events self-perpetuate, so bound any polling loop by duration_s."""
        return self.events[0][0] if self.events else None

    def step(self) -> bool:
        """Process the single next event if it lies within duration_s.
        Returns False (and leaves the event queued) once the horizon is
        reached — the point at which ``finalize()`` may be called."""
        if not self.events or self.events[0][0] > self.duration_s:
            return False
        t, _, kind, arg = heapq.heappop(self.events)
        self.t = t
        prof = self._profiler
        if prof is None:
            self._process_event(t, kind, arg)
            self._drain_schedule(t)
        else:
            w0 = prof.t0()
            self._process_event(t, kind, arg)
            prof.add("node." + _EVENT_NAMES[kind], w0)
            w0 = prof.t0()
            self._drain_schedule(t)
            prof.add("node.drain", w0)
        return True

    def step_until(self, t_limit: float) -> int:
        """Process every event with time <= min(t_limit, duration_s).  The
        fleet clock interleaves nodes by advancing each to the next fleet
        event time before applying it.  Returns the number of events
        processed (0 = observable state unchanged).

        With ``soa_slab`` on, the whole span is one *time slab*: the limit
        is by construction the next point an external observer (fleet
        clock, router, trigger forwarding) can read node state, so inside
        it block completions cycle through the slab done lane without
        touching the global heap, and job state moves through the flat
        :class:`JobTable` columns.  The slab drains fully before
        returning — boundaries are exactly the scalar oracle's."""
        lim = min(t_limit, self.duration_s)
        if self.soa_slab:
            return self._slab_until(lim)
        n = 0
        while self.events and self.events[0][0] <= lim:
            self.step()
            n += 1
        return n

    def _slab_until(self, lim: float) -> int:
        """One time slab: merge the global heap with the slab done lane by
        (t, seq) — seq is globally unique, so the merged order is exactly
        the single-heap order of the scalar path — and run the same
        process/drain cycle per event, metering identically."""
        events = self.events
        dones = self._slab_dones
        prof = self._profiler
        n = 0
        try:
            self._slab_sink = dones
            while True:
                if dones:
                    dt, dseq, dacc = dones[0]
                    if events and events[0][:2] < (dt, dseq):
                        if events[0][0] > lim:
                            break
                        t, _, kind, arg = heapq.heappop(events)
                    else:
                        if dt > lim:
                            break
                        del dones[0]
                        t, kind, arg = dt, DONE, dacc
                elif events and events[0][0] <= lim:
                    t, _, kind, arg = heapq.heappop(events)
                else:
                    break
                self.t = t
                if prof is None:
                    if kind == DONE:
                        self._complete(arg, t)  # type: ignore[arg-type]
                    else:
                        self._process_event(t, kind, arg)
                    self._drain_schedule(t)
                else:
                    w0 = prof.t0()
                    if kind == DONE:
                        self._complete(arg, t)  # type: ignore[arg-type]
                    else:
                        self._process_event(t, kind, arg)
                    prof.add("node." + _EVENT_NAMES[kind], w0)
                    w0 = prof.t0()
                    self._drain_schedule(t)
                    prof.add("node.drain", w0)
                n += 1
        finally:
            self._slab_sink = None
            if dones:
                for dt, dseq, dacc in dones:
                    heapq.heappush(events, (dt, dseq, DONE, dacc))
                dones.clear()
        return n

    def _process_event(self, t: float, kind: int, arg: object) -> None:
        if kind == ARRIVAL:
            idx = int(arg)  # type: ignore[arg-type]
            if self.active[idx]:
                self._create_job(idx, t)
                if self.recorder is not None:
                    self.recorder.arrival(t, self.specs[idx].model.name)
                self._schedule_stream_arrival(idx, after_t=t)
            # an inactive (left) stream dies at its pending arrival
        elif kind == INJECT:
            idx, anchor, origin, parent_uid, xfer_s = arg  # type: ignore[misc]
            if self.active[idx]:
                job = self._create_job(idx, t, origin=origin,
                                       parent_uid=parent_uid, xfer_s=xfer_s)
                if anchor is not None:
                    name = self.specs[idx].model.name
                    job.deadline = anchor + self.deadlines[name]
                    if self.soa is not None:
                        self.soa.deadline[self.soa.row_of[job.jid]] = \
                            job.deadline
                    # the anchored deadline is earlier than the create-time
                    # one _create_job armed (anchor <= t), so re-arm the
                    # stale entry or the abort would fire late
                    heapq.heappush(
                        self._stale_heap,
                        (job.deadline + self.stale_periods
                         * self.specs[idx].period_s, job.jid))
        elif kind == PHASE:
            self._apply_phase(arg, t)
        elif kind == DONE:
            self._complete(int(arg), t)  # type: ignore[arg-type]
        elif kind == WINDOW:
            uxc = uxcost(self.window_stats)
            a, b = self._current_params()
            self.windows.append((t, uxc, a, b))
            self.scheduler.on_window(self, self.window_stats, uxc)
            for st in self.window_stats.per_model.values():
                self.merged_frames += st.frames
                self.merged_violated += st.violated
            self.global_stats.merge(self.window_stats)
            self.window_stats = WindowStats()
            self._push(t + self.window_s, WINDOW, None)

    def run(self) -> SimResult:
        self.start()
        # equivalent to `while self.step(): pass` — both drain every event
        # with t <= duration_s — but routed through step_until so the SoA
        # path runs the whole horizon as slabs
        self.step_until(self.duration_s)
        return self.finalize()

    def finalize(self) -> SimResult:
        for st in self.window_stats.per_model.values():
            self.merged_frames += st.frames
            self.merged_violated += st.violated
        self.global_stats.merge(self.window_stats)
        self.window_stats = WindowStats()  # idempotent wrt. a second call
        if self.recorder is not None:
            self.trace = self.recorder.trace()
        if self._tracer is not None and self._span_of:
            # jobs still queued/running at the horizon: close their spans
            # so the emitted JSONL is complete (outcome marks them)
            for jid in sorted(self._span_of):
                j = self.jobs.get(jid)
                self._tracer.close(
                    self._span_of[jid], self.t, outcome="unfinished",
                    violated=False,
                    energy_j=j.energy_used if j is not None else 0.0,
                    variant=j.graph_name if j is not None else None,
                    segs=[list(s) for s in self._segs_of.get(jid, ())])
            self._span_of.clear()
            self._segs_of.clear()
            self._uid_of.clear()
        util = [a.busy_time / max(self.t, 1e-9) for a in self.accs]
        return SimResult(
            scenario=self.scenario.name,
            system=self.system_name,
            scheduler=self.scheduler.name,
            duration_s=self.duration_s,
            stats=self.global_stats,
            uxcost=uxcost(self.global_stats),
            dlv_rate=overall_dlv_rate(self.global_stats),
            norm_energy=overall_norm_energy(self.global_stats),
            frames=self.frames,
            drops=self.drops,
            aborts=self.aborts,
            variant_counts=dict(self.variant_counts),
            windows=self.windows,
            acc_utilization=util,
            trace=self.trace,
            pipeline_latency_s=overall_pipeline_latency(self.global_stats),
        )

    def _current_params(self) -> tuple[float, float]:
        p = getattr(self.scheduler, "params", None)
        if p is None:
            return (0.0, 0.0)
        return (p.alpha, p.beta)


def run_sim(
    scenario: Scenario,
    system: str,
    scheduler_factory: Callable[[], SchedulerBase],
    duration_s: float = 8.0,
    seed: int = 0,
    **kw,
) -> SimResult:
    sim = Simulator(scenario, system, scheduler_factory(), duration_s=duration_s,
                    seed=seed, **kw)
    return sim.run()
