"""Multi-model real-time serving: the DREAM scheduler driving JAX models."""
from .engine import (EngineReport, ModelHandle, RequestQueue,  # noqa: F401
                     ServeRequest, ServingEngine, TraceReplayQueue,
                     VirtualAccelerator)
