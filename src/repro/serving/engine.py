"""Multi-model RTMM serving engine with DREAM (MapScore) dispatch.

This is the production face of the paper: the *same* MapScore computation
that Level-1 validates in simulation drives dispatch of real JAX model
executions here. The engine owns:

  * a set of registered models (any ArchConfig; jitted forward per model),
  * virtual accelerator slices (on a real pod: disjoint mesh slices; on the
    CPU dev box: time-sliced executors with per-slice speed factors) with
    a measured-latency table per (model, slice) — the "offline cost model"
    input of Figure 4, here calibrated by direct measurement,
  * a real-time request queue (periodic frames, FPS targets, deadlines,
    model-cascade dependencies),
  * the four DREAM engines: MapScore calculator, frame-drop, adaptivity
    ((alpha, beta) UXCost feedback), and job assignment/dispatch,
  * straggler mitigation: jobs whose wall-clock exceeds a p99 watermark are
    re-dispatched to the next-best slice (MapScore already ranks them).

Energy on the dev box is modeled as latency x slice power weight (real
deployments plug in measured per-accelerator power).
"""
from __future__ import annotations

import itertools
import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.core.mapscore import MapScoreParams
from repro.core.uxcost import WindowStats, uxcost


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------


@dataclass
class ServeRequest:
    rid: int
    model: str
    tokens: np.ndarray                  # [B, S] prompt batch
    arrival: float
    deadline: float
    depends_on: Optional[str] = None
    done: bool = False
    dropped: bool = False
    completion: Optional[float] = None
    result: Any = None
    energy: float = 0.0

    @property
    def violated(self) -> bool:
        return self.dropped or (self.completion is not None
                                and self.completion > self.deadline)


@dataclass
class RequestQueue:
    """Frame generator for registered model streams.

    Streams are strictly periodic from t=0 by default; pass ``arrival`` (an
    ``repro.scenarios.arrivals`` process instance or its config dict) for
    jittered / Poisson / bursty / diurnal traffic — the same processes the
    Level-1 simulator consumes, so a workload definition ports across both
    engines unchanged.
    """

    clock: Callable[[], float]
    streams: dict[str, dict] = field(default_factory=dict)
    pending: list[ServeRequest] = field(default_factory=list)
    _rid: itertools.count = field(default_factory=itertools.count)

    def add_stream(self, model: str, fps: float, batch: int, seq: int,
                   vocab: int, deadline_frac: float = 1.0,
                   depends_on: Optional[str] = None,
                   trigger_prob: float = 1.0,
                   arrival=None) -> None:
        # crc32, not hash(): string hashing is salted per process and would
        # make stream contents differ run to run
        rng = np.random.default_rng(zlib.crc32(model.encode()) & 0xFFFF)
        proc = None
        next_t = 0.0
        if arrival is not None and depends_on is None:
            import copy
            from repro.scenarios.arrivals import arrival_from_config
            # shallow-copy: processes carry per-stream state (MMPP clocks),
            # so streams must never share one instance (same contract as
            # Simulator._materialize_arrival)
            proc = (arrival_from_config(arrival) if isinstance(arrival, dict)
                    else copy.copy(arrival))
            idx = len(self.streams)
            next_t = proc.start(idx, 1.0 / fps, rng)
        self.streams[model] = dict(
            fps=fps, batch=batch, seq=seq, vocab=vocab, next_t=next_t,
            deadline=deadline_frac / fps, depends_on=depends_on,
            trigger_prob=trigger_prob, rng=rng, arrival=proc)

    def poll(self, now: float) -> list[ServeRequest]:
        """Emit any frames whose arrival time elapsed (head streams)."""
        out = []
        for name, st in self.streams.items():
            if st["depends_on"] is not None or st["next_t"] is None:
                continue
            while st["next_t"] is not None and st["next_t"] <= now:
                t = st["next_t"]
                out.append(self._make(name, st, t))
                if st["arrival"] is None:
                    st["next_t"] = t + 1.0 / st["fps"]
                else:
                    st["next_t"] = st["arrival"].next_after(
                        t, 1.0 / st["fps"], st["rng"])
        self.pending.extend(out)
        return out

    def trigger_dependents(self, parent: str, now: float) -> list[ServeRequest]:
        out = []
        for name, st in self.streams.items():
            if st["depends_on"] == parent and \
                    st["rng"].random() < st["trigger_prob"]:
                out.append(self._make(name, st, now))
        self.pending.extend(out)
        return out

    def _make(self, name: str, st: dict, t: float) -> ServeRequest:
        tokens = st["rng"].integers(
            0, st["vocab"], size=(st["batch"], st["seq"])).astype(np.int32)
        return ServeRequest(rid=next(self._rid), model=name, tokens=tokens,
                            arrival=t, deadline=t + st["deadline"],
                            depends_on=st["depends_on"])


class TraceReplayQueue(RequestQueue):
    """Replays the head arrivals of a recorded scenario trace.

    The same ``repro.scenarios.trace.Trace`` the Level-1 simulator records
    and replays drives the serving engine here: each recorded arrival time
    becomes one request for the matching registered stream (models absent
    from the stream registry are ignored, so a trace can be replayed against
    a subset deployment).  Dependent streams stay live — cascade triggering
    remains the engine's own seeded draw, exactly as in the simulator.
    """

    def __init__(self, clock: Callable[[], float], trace) -> None:
        super().__init__(clock=clock)
        self._times: dict[str, deque] = {
            name: deque(ts) for name, ts in trace.arrivals_by_model().items()
        }

    def poll(self, now: float) -> list[ServeRequest]:
        out = []
        for name, st in self.streams.items():
            if st["depends_on"] is not None:
                continue
            q = self._times.get(name)
            while q and q[0] <= now:
                out.append(self._make(name, st, q.popleft()))
        self.pending.extend(out)
        return out


# ---------------------------------------------------------------------------
# virtual accelerators (mesh slices / time-sliced executors)
# ---------------------------------------------------------------------------


@dataclass
class VirtualAccelerator:
    """One dispatch target. On a pod this wraps a mesh slice; on the CPU dev
    box it wraps the single device with a speed/power factor so that the
    heterogeneous-hardware scheduling problem is preserved end-to-end."""

    name: str
    speed: float = 1.0          # relative throughput (1.0 = fastest)
    power: float = 1.0          # relative energy per unit work
    busy_until: float = 0.0
    last_model: Optional[str] = None
    total_busy: float = 0.0


@dataclass
class ModelHandle:
    name: str
    cfg: ArchConfig
    params: Any
    fn: Callable                # jitted logits fn(params, tokens)
    supernet: tuple[str, ...] = ()   # lighter variant model names


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


@dataclass
class EngineReport:
    frames: int
    violated: int
    dropped: int
    redispatched: int
    uxcost: float
    dlv_rate: float
    energy: float
    per_model: dict[str, dict]
    alpha: float
    beta: float

    def summary(self) -> str:
        return (f"frames={self.frames} dlv={self.dlv_rate:.3f} "
                f"drops={self.dropped} redisp={self.redispatched} "
                f"uxcost={self.uxcost:.4f} energy={self.energy:.4f}")


class ServingEngine:
    def __init__(self, accelerators: list[VirtualAccelerator],
                 alpha: float = 1.0, beta: float = 1.0,
                 adaptivity: bool = True,
                 frame_drop: bool = True,
                 supernet_switch: bool = True,
                 max_drop_per_window: int = 2, drop_window: int = 10,
                 straggler_factor: float = 3.0,
                 stale_periods: float = 2.0,
                 seed: int = 0):
        self.accs = accelerators
        self.models: dict[str, ModelHandle] = {}
        self.lat_table: dict[tuple[str, str], float] = {}  # (model, acc) -> s
        self.params = MapScoreParams(alpha=alpha, beta=beta)
        self.adaptivity = adaptivity
        self.frame_drop = frame_drop
        self.supernet_switch = supernet_switch
        self.max_drop = max_drop_per_window
        self.drop_window = drop_window
        self.straggler_factor = straggler_factor
        self.stale_periods = stale_periods
        self.aborted = 0
        self.rng = np.random.default_rng(seed)
        self.drop_hist: dict[str, list[bool]] = {}
        self.stats = WindowStats()
        self.window_stats = WindowStats()
        self.redispatched = 0
        self.dropped = 0
        self._probe: list[tuple[float, np.ndarray]] = []
        self._probe_radius = 0.4
        self._lat_samples: dict[str, list[float]] = {}

    # ------------------------------------------------------------ registry
    def register(self, handle: ModelHandle, calibrate_tokens: np.ndarray
                 ) -> None:
        """Register a model and calibrate its per-slice latency (the
        offline-cost-model input of the paper, measured here)."""
        self.models[handle.name] = handle
        self.drop_hist[handle.name] = []
        # measure the real device once (includes compile), then twice timed
        t = jnp.asarray(calibrate_tokens)
        handle.fn(handle.params, t)
        times = []
        for _ in range(2):
            t0 = time.perf_counter()
            jax.block_until_ready(handle.fn(handle.params, t))
            times.append(time.perf_counter() - t0)
        base = float(np.median(times))
        for acc in self.accs:
            self.lat_table[(handle.name, acc.name)] = base / acc.speed

    # ------------------------------------------------------------ mapscore
    def _mapscore(self, req: ServeRequest, acc: VirtualAccelerator,
                  now: float) -> float:
        lat = self.lat_table[(req.model, acc.name)]
        lat_all = [self.lat_table[(req.model, a.name)] for a in self.accs]
        togo = float(np.mean(lat_all))
        slack = req.deadline - now
        urgency = min(togo / slack, 20.0) if slack > 1e-6 else 0.0
        latpref = sum(lat_all) / lat
        tq = max(now - req.arrival, 0.0)
        starv = tq / togo
        en = lat * acc.power
        en_all = [self.lat_table[(req.model, a.name)] * a.power
                  for a in self.accs]
        cswitch = 0.0 if acc.last_model == req.model else 0.2
        score_energy = sum(en_all) / en - cswitch
        return (urgency * latpref + self.params.alpha * starv
                + self.params.beta * score_energy)

    # ----------------------------------------------------------- frame drop
    def _try_drop(self, now: float) -> None:
        waiting = [r for r in self._waiting if not r.done]
        expected_viol = [
            r for r in waiting
            if min(self.lat_table[(r.model, a.name)] for a in self.accs)
            > max(r.deadline - now, 0.0)]
        if len(expected_viol) < 2:
            return
        best, best_ratio = None, 0.0
        for r in expected_viol:
            hist = self.drop_hist[r.model][-self.drop_window:]
            if sum(hist) >= self.max_drop:
                continue
            mtg = min(self.lat_table[(r.model, a.name)] for a in self.accs)
            ratio = mtg / max(r.deadline - now, 1e-6)
            if ratio > best_ratio:
                best, best_ratio = r, ratio
        if best is not None:
            best.done, best.dropped = True, True
            self.dropped += 1
            self._finish_stats(best)

    # ------------------------------------------------------------ adaptivity
    def _adapt(self, window_ux: float) -> None:
        center = np.array([self.params.alpha, self.params.beta])
        self._probe.append((window_ux, center.copy()))
        if len(self._probe) >= 4:
            self._probe.sort(key=lambda x: x[0])
            (u1, p1), (u2, p2) = self._probe[0], self._probe[1]
            w1, w2 = 1 / (u1 + 1e-9), 1 / (u2 + 1e-9)
            new = np.clip((w1 * p1 + w2 * p2) / (w1 + w2), 0.0, 2.0)
            self.params = MapScoreParams(alpha=float(new[0]),
                                         beta=float(new[1]))
            self._probe = []
            self._probe_radius = max(self._probe_radius * 0.7, 0.05)
        else:
            cand = np.clip(center + self.rng.uniform(
                -self._probe_radius, self._probe_radius, 2), 0.0, 2.0)
            self.params = MapScoreParams(alpha=float(cand[0]),
                                         beta=float(cand[1]))

    # -------------------------------------------------------------- running
    def _finish_stats(self, req: ServeRequest) -> None:
        st = self.window_stats.model(req.model)
        st.frames += 1
        st.violated += int(req.violated)
        st.energy_j += req.energy
        worst = max(self.lat_table[(req.model, a.name)] * a.power
                    for a in self.accs)
        st.worst_energy_j += worst
        hist = self.drop_hist[req.model]
        hist.append(req.dropped)
        if len(hist) > self.drop_window:
            hist.pop(0)

    def _pick_variant(self, req: ServeRequest, now: float) -> str:
        """Supernet switching: lightest-necessary weight-sharing variant."""
        handle = self.models[req.model]
        if not (self.supernet_switch and handle.supernet):
            return req.model
        slack = max(req.deadline - now, 0.0)
        best_lat = min(self.lat_table[(req.model, a.name)]
                       for a in self.accs)
        if best_lat <= slack:
            return req.model
        for variant in handle.supernet:          # ordered heavy -> light
            vlat = min(self.lat_table[(variant, a.name)] for a in self.accs)
            if vlat <= slack:
                return variant
        return handle.supernet[-1]

    def run(self, queue: RequestQueue, duration_s: float,
            window_s: float = 0.5) -> EngineReport:
        """Drive the engine on the real clock until duration_s elapses."""
        t_start = time.perf_counter()
        now_fn = lambda: time.perf_counter() - t_start
        self._waiting: list[ServeRequest] = []
        next_window = window_s
        variant_counts: dict[str, int] = {}

        while True:
            now = now_fn()
            if now >= duration_s:
                break
            self._waiting.extend(queue.poll(now))
            self._waiting = [r for r in self._waiting if not r.done]
            # hygiene: a frame still waiting `stale_periods` past its
            # deadline-equivalent period is abandoned (counts violated)
            for r in self._waiting:
                period = r.deadline - r.arrival
                if now > r.deadline + self.stale_periods * period:
                    r.done, r.dropped = True, True
                    self.aborted += 1
                    self._finish_stats(r)
            self._waiting = [r for r in self._waiting if not r.done]
            if self.frame_drop:
                self._try_drop(now)
            ready = [r for r in self._waiting if not r.done]
            idle = [a for a in self.accs if a.busy_until <= now]
            if not ready or not idle:
                nxt = min([a.busy_until for a in self.accs
                           if a.busy_until > now] + [now + 1e-3])
                time.sleep(max(min(nxt - now, 1e-3), 1e-5))
                if now >= next_window:
                    wux = uxcost(self.window_stats)
                    if self.adaptivity and sum(
                            st.frames for st in
                            self.window_stats.per_model.values()):
                        self._adapt(wux)
                    self.stats.merge(self.window_stats)
                    self.window_stats = WindowStats()
                    next_window += window_s
                continue

            # job assignment: best (request, accelerator) MapScore pair
            best, best_score = None, -np.inf
            for r in ready:
                for a in idle:
                    s = self._mapscore(r, a, now)
                    if s > best_score:
                        best, best_score = (r, a), s
            req, acc = best
            run_as = self._pick_variant(req, now)
            variant_counts[run_as] = variant_counts.get(run_as, 0) + 1
            handle = self.models[run_as]
            tok = req.tokens
            if tok.shape[1] > 0:
                t0 = time.perf_counter()
                out = handle.fn(handle.params, jnp.asarray(tok))
                jax.block_until_ready(out)
                wall = time.perf_counter() - t0
                req.result = out
            else:
                wall = 0.0
            # straggler mitigation: re-dispatch if way past expectation
            expect = self.lat_table[(run_as, acc.name)]
            samples = self._lat_samples.setdefault(run_as, [])
            samples.append(wall)
            if wall > self.straggler_factor * expect and len(samples) > 4:
                alt = min((a for a in self.accs if a is not acc),
                          key=lambda a: self.lat_table[(run_as, a.name)],
                          default=None)
                if alt is not None:
                    self.redispatched += 1
                    acc = alt
            # virtual time accounting (speed factor models slice size)
            vlat = max(wall, self.lat_table[(run_as, acc.name)])
            done_at = now + vlat
            acc.busy_until = done_at
            acc.total_busy += vlat
            acc.last_model = run_as
            req.energy = vlat * acc.power
            req.done = True
            req.completion = done_at
            self._finish_stats(req)
            self._waiting.extend(queue.trigger_dependents(req.model, done_at))

        self.stats.merge(self.window_stats)
        self.window_stats = WindowStats()
        frames = sum(st.frames for st in self.stats.per_model.values())
        viol = sum(st.violated for st in self.stats.per_model.values())
        energy = sum(st.energy_j for st in self.stats.per_model.values())
        per_model = {
            name: dict(frames=st.frames, violated=st.violated,
                       energy=st.energy_j)
            for name, st in self.stats.per_model.items()}
        return EngineReport(
            frames=frames, violated=viol, dropped=self.dropped,
            redispatched=self.redispatched,
            uxcost=uxcost(self.stats),
            dlv_rate=viol / frames if frames else 0.0,
            energy=energy, per_model=per_model,
            alpha=self.params.alpha, beta=self.params.beta)
