"""Phi-3-vision 4.2B (hf:microsoft/Phi-3-vision-128k-instruct).

phi3-mini backbone 32L d_model=3072 32H (GQA kv=32 -> MHA) d_ff=8192
vocab=32064 + CLIP frontend stubbed as precomputed patch embeddings.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    mlp_act="silu",
    frontend="vision_patches",
    frontend_tokens=576,
    tie_embeddings=True,
)
