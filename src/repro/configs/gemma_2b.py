"""Gemma 2B (arXiv:2403.08295): GeGLU, head_dim=256, MQA (kv=1).

18L d_model=2048 8H d_ff=16384 vocab=256000.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    mlp_act="gelu",
    embed_scale=True,
    tie_embeddings=True,
)
