"""Phi-3.5-MoE 42B-A6.6B (hf:microsoft/Phi-3.5-MoE-instruct).

32L d_model=4096 32H (GQA kv=8) d_ff=6400, MoE 16 experts top-2, vocab 32064.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    num_experts_per_tok=2,
    mlp_act="silu",
    tie_embeddings=False,
)
