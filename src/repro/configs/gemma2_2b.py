"""Gemma-2 2B (arXiv:2408.00118): local+global alternating, logit softcaps.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, window 4096,
attn softcap 50, final softcap 30, GeGLU, head_dim 256.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=("local", "global"),
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_act="gelu",
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
)
