"""Architecture configuration system.

One module per assigned architecture; each exposes ``CONFIG`` (the exact
published configuration) and the registry maps ``--arch <id>`` to it.
``smoke()`` returns a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    """A complete LM-family architecture description."""

    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default: d_model // num_heads

    # attention variants
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    local_window: Optional[int] = None   # sliding-window size for local layers
    layer_pattern: tuple[str, ...] = ("global",)  # repeating per-layer kinds
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    attn_scale: Optional[float] = None   # default: head_dim ** -0.5

    # mlp variants
    mlp_act: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    mlp_gated: bool = True            # False = vanilla 2-matrix MLP
    post_norms: bool = False          # gemma2-style post-sublayer RMSNorms
    pos_embed: str = "rope"           # "rope" | "absolute" (sinusoidal)

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "einsum"          # "einsum" | "gmm" (Pallas grouped GEMM)

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0                # number of SSD heads
    ssm_expand: int = 2
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256
    ssm_impl: str = "ref"             # "ref" (XLA chunked) | "pallas"

    # hybrid (zamba2-style shared attention block)
    shared_attn_every: int = 0        # insert shared attn block every N blocks

    # frontends (stubbed modalities)
    frontend: Optional[str] = None    # "audio_frames" | "vision_patches"
    frontend_tokens: int = 0          # prompt positions fed by the frontend
    frontend_dim: int = 1024          # embedding width the stub provides

    # embedding
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma-style sqrt(d_model) scaling

    # runtime
    dtype: str = "bfloat16"
    remat: str = "none"               # none | full | dots (checkpoint policy)
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (in decode-KV) archs: SSM, hybrid, and local+global
        dense models whose global layers are linear in KV at decode."""
        return self.family in ("ssm", "hybrid") or (
            self.local_window is not None and "local" in self.layer_pattern)

    def params_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        pattern = self.layer_pattern
        for i in range(self.num_layers):
            kind = pattern[i % len(pattern)]
            if kind in ("global", "local"):
                attn = d * hd * (n_q + 2 * n_kv) + n_q * hd * d
                if self.num_experts > 0:
                    mlp = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
                else:
                    mlp = 3 * d * self.d_ff
                per_layer += attn + mlp
            elif kind == "mamba":
                d_in = self.ssm_expand * d
                per_layer += d * (2 * d_in + 2 * self.ssm_heads *
                                  self.ssm_state) + d_in * d + d_in * 3
        return emb + per_layer

    def active_params_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.params_count()
        d = self.d_model
        full = self.params_count()
        moe_total = self.num_layers * self.num_experts * 3 * d * self.d_ff
        moe_active = self.num_layers * self.num_experts_per_tok * 3 * d * self.d_ff
        return full - moe_total + moe_active


#: arch-id -> module name
_REGISTRY = {
    "musicgen-large": "musicgen_large",
    "gemma2-2b": "gemma2_2b",
    "qwen1.5-4b": "qwen1_5_4b",
    "minitron-8b": "minitron_8b",
    "gemma-2b": "gemma_2b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "qwen3-moe-235b-a22b": "qwen3_moe",
    "zamba2-2.7b": "zamba2_2_7b",
    "phi-3-vision-4.2b": "phi3_vision",
    "mamba2-130m": "mamba2_130m",
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch: str) -> ArchConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG


def smoke_config(arch: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(arch)
    pat = len(cfg.layer_pattern)
    n_layers = max(pat, 2 if pat == 1 else pat)
    updates: dict = dict(
        num_layers=n_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads else 0,
        d_ff=128,
        vocab_size=256,
        head_dim=16 if cfg.head_dim else None,
        frontend_tokens=min(cfg.frontend_tokens, 4),
        local_window=min(cfg.local_window, 8) if cfg.local_window else None,
        scan_layers=False,
    )
    if cfg.num_experts:
        # capacity_factor >= num_experts / top_k guarantees no capacity drops,
        # so decode-vs-forward consistency checks are exact
        updates.update(num_experts=4, num_experts_per_tok=2,
                       moe_capacity_factor=2.0)
    if cfg.ssm_state:
        updates.update(ssm_state=16, ssm_heads=4, ssm_chunk=8)
    if cfg.shared_attn_every:
        updates.update(shared_attn_every=2, num_layers=4)
    return replace(cfg, **updates)


# --------------------------------------------------------------------------
# Input shape cells (the assignment's per-arch shape set)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k only runs on sub-quadratic archs (assignment note)."""
    if shape == "long_500k":
        return cfg.supports_long_context
    return True
