"""Mamba2-130M (arXiv:2405.21060): SSD (state-space duality), attention-free.

24L d_model=768, ssm_state=128, expand 2 (d_inner=1536, 24 heads of 64),
vocab=50280.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=24,
    ssm_expand=2,
    layer_pattern=("mamba",),
    tie_embeddings=True,
)
