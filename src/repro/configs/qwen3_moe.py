"""Qwen3-MoE 235B-A22B-class (hf:Qwen/Qwen3-*): 128 experts top-8.

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    num_experts=128,
    num_experts_per_tok=8,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    tie_embeddings=False,
)
