"""Zamba2-2.7B (arXiv:2411.15242): Mamba2 backbone + shared attention blocks.

54 Mamba2 blocks d_model=2560, ssm_state=64; a shared (weight-tied) attention
block (32H) is interleaved every 6 mamba blocks; d_ff=10240, vocab=32000.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=80,           # expand*d_model / head 64
    ssm_expand=2,
    layer_pattern=("mamba",),
    shared_attn_every=6,
    mlp_act="gelu",
    tie_embeddings=True,
)
