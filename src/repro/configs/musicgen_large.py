"""MusicGen-large (arXiv:2306.05284): decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 -> MHA) d_ff=8192 vocab=2048. The EnCodec
audio frontend is a stub: input_specs() provides precomputed frame embeddings.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_act="gelu",
    mlp_gated=False,
    pos_embed="absolute",
    frontend="audio_frames",
    frontend_tokens=256,
    tie_embeddings=False,
)
