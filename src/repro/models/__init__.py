"""JAX model substrate: the 10 assigned LM-family architectures.

Everything is functional (init/apply pairs over plain dict pytrees) with a
parallel *logical-axis* pytree per module, consumed by
``repro.distributed.sharding`` to derive PartitionSpecs for any mesh.
"""
from .model import LM, init_params, param_axes  # noqa: F401
