"""Mamba2 (SSD) block: in_proj -> causal conv -> selective scan -> gated out.

Follows the Mamba2 layout (arXiv:2405.21060) with n_groups=1:

  u [B,S,D] --in_proj--> [z (d_in) | x (d_in) | B (N) | C (N) | dt (H)]
  (x|B|C) -> causal depthwise conv1d (K=4) -> silu
  dt -> softplus(dt + dt_bias);  A = -exp(A_log)  (scalar per head)
  y = SSD(x, dt, A, B, C, D)                      (kernels/ssd or ref)
  out = out_proj( RMSNorm(y * silu(z)) )

Full-sequence apply uses the chunked SSD kernel (Pallas on TPU, oracle on
CPU); decode-step carries (conv_state [B,K-1,C_conv], ssm_state [B,H,N,P])
and is pure jnp (a single recurrence step is bandwidth-bound anyway).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from .layers import Array, dense_init, rmsnorm, rmsnorm_init, rmsnorm_axes

Constrain = Callable[[Array, tuple], Array]
_id = lambda x, _: x


@dataclass(frozen=True)
class SSMConfig:
    d_model: int
    state: int                  # N
    heads: int                  # H
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256
    impl: str = "ref"           # "ref" (XLA chunked) | "pallas"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_inner % self.heads == 0, (self.d_inner, self.heads)
        return self.d_inner // self.heads

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.state

    @property
    def proj_out(self) -> int:
        return 2 * self.d_inner + 2 * self.state + self.heads


def ssm_init(key: Array, cfg: SSMConfig) -> dict:
    ks = jax.random.split(key, 4)
    d, di = cfg.d_model, cfg.d_inner
    # A_log init in [log 1, log 16] (mamba2 default); dt_bias so that
    # softplus(dt_bias) spans ~[1e-3, 1e-1]
    a = jnp.log(jnp.linspace(1.0, 16.0, cfg.heads, dtype=jnp.float32))
    u = jax.random.uniform(ks[2], (cfg.heads,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (d, cfg.proj_out), d),
        "conv_w": 0.1 * jax.random.normal(
            ks[1], (cfg.conv_kernel, cfg.conv_channels), jnp.float32),
        "conv_b": jnp.zeros((cfg.conv_channels,), jnp.float32),
        "A_log": a,
        "D": jnp.ones((cfg.heads,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": rmsnorm_init(di),
        "out_proj": dense_init(ks[3], (di, d), di),
    }


def ssm_axes() -> dict:
    return {
        "in_proj": ("fsdp", "ssm_inproj"),
        "conv_w": ("conv_kernel", None),
        "conv_b": (None,),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": rmsnorm_axes(),
        "out_proj": ("ffn", "fsdp"),
    }


def _split_proj(cfg: SSMConfig, zxbcdt: Array):
    di, n, h = cfg.d_inner, cfg.state, cfg.heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + cfg.conv_channels]
    dt = zxbcdt[..., di + cfg.conv_channels:]
    assert dt.shape[-1] == h
    del n
    return z, xbc, dt


def _causal_conv(params: dict, xbc: Array) -> Array:
    """Depthwise causal conv1d over [B, S, C] with kernel K.

    One fused lax.conv (feature_group_count=C) instead of K shifted
    multiply-adds: the unrolled form materialized ~3K full [B, S, C]
    intermediates per layer, which dominated the memory roofline term of
    the mamba/hybrid archs (§Perf table-wide notes).
    """
    k, c = params["conv_w"].shape
    w = params["conv_w"].astype(xbc.dtype).reshape(k, 1, c)   # [K, I=1, C]
    out = jax.lax.conv_general_dilated(
        xbc, w,
        window_strides=(1,),
        padding=[(k - 1, 0)],                                  # causal
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return out + params["conv_b"].astype(xbc.dtype)


def _run_ssd(cfg: SSMConfig, xh: Array, dt: Array, a: Array, bmat: Array,
             cmat: Array, d: Array) -> tuple[Array, Array]:
    """Dispatch to the Pallas kernel or the XLA chunked oracle, padding the
    sequence to a chunk multiple (padded tokens get dt=0: exact no-ops)."""
    s = xh.shape[1]
    ch = min(cfg.chunk, s)
    pad = (-s) % ch
    if pad:
        widths4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        widths3 = ((0, 0), (0, pad), (0, 0))
        xh = jnp.pad(xh, widths4)
        dt = jnp.pad(dt, widths3)          # zero dt => decay 1, zero update
        bmat = jnp.pad(bmat, widths3)
        cmat = jnp.pad(cmat, widths3)
    if cfg.impl == "pallas":
        from repro.kernels import ops as kops
        y, fin = kops.ssd(xh, dt, a, bmat, cmat, d, chunk=ch)
    else:
        from repro.kernels import ref as kref
        y, fin = kref.ssd_chunked(xh, dt, a, bmat, cmat, d, chunk=ch)
    return y[:, :s], fin


def ssm_apply(params: dict, cfg: SSMConfig, u: Array,
              constrain: Constrain = _id) -> Array:
    """Full-sequence Mamba2 block. u: [B, S, D] -> [B, S, D]."""
    b, s, _ = u.shape
    dtype = u.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", u, params["in_proj"].astype(dtype))
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(params, xbc))
    x = xbc[..., : cfg.d_inner]
    bmat = xbc[..., cfg.d_inner: cfg.d_inner + cfg.state].astype(jnp.float32)
    cmat = xbc[..., cfg.d_inner + cfg.state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["A_log"])
    xh = x.reshape(b, s, cfg.heads, cfg.head_dim)
    xh = constrain(xh, ("batch", "act_seq", "act_heads", None))
    y, _ = _run_ssd(cfg, xh, dt, a, bmat, cmat, params["D"])
    y = y.reshape(b, s, cfg.d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(dtype))


# ---------------------------------------------------------------------------
# decode step with carried state
# ---------------------------------------------------------------------------


def init_state(batch: int, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.conv_channels),
                          dtype),
        "ssm": jnp.zeros((batch, cfg.heads, cfg.state, cfg.head_dim),
                         jnp.float32),
    }


def state_spec(batch: int, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        init_state(batch, cfg, dtype))


def state_axes() -> dict:
    return {"conv": ("batch", None, None),
            "ssm": ("batch", "act_heads", None, None)}


def ssm_decode(params: dict, cfg: SSMConfig, u: Array, state: dict,
               constrain: Constrain = _id) -> tuple[Array, dict]:
    """One-token step. u: [B, 1, D] -> ([B, 1, D], new state)."""
    b = u.shape[0]
    dtype = u.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", u, params["in_proj"].astype(dtype))
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)          # [B,1,*]
    # conv over (state window + new input)
    window = jnp.concatenate(
        [state["conv"].astype(dtype), xbc_new], axis=1)  # [B, K, C]
    w = params["conv_w"].astype(dtype)                   # [K, C]
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"].astype(dtype)
    xbc = jax.nn.silu(conv_out)                          # [B, C]
    x = xbc[:, : cfg.d_inner]
    bmat = xbc[:, cfg.d_inner: cfg.d_inner + cfg.state].astype(jnp.float32)
    cmat = xbc[:, cfg.d_inner + cfg.state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + params["dt_bias"][None, :])   # [B, H]
    a = -jnp.exp(params["A_log"])                        # [H]
    xh = x.reshape(b, cfg.heads, cfg.head_dim).astype(jnp.float32)
    decay = jnp.exp(a[None, :] * dt)                     # [B, H]
    upd = jnp.einsum("bn,bh,bhp->bhnp", bmat, dt, xh)
    ssm = decay[:, :, None, None] * state["ssm"] + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat, ssm)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, 1, cfg.d_inner).astype(dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(dtype))
    new_state = {"conv": window[:, 1:].astype(state["conv"].dtype), "ssm": ssm}
    return out, new_state
