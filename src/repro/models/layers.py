"""Shared building blocks: norms, MLPs, embeddings, rotary embeddings.

Conventions
-----------
* Every module is an (init, apply) pair over plain dict pytrees.
* ``*_axes`` functions return a pytree of logical-axis tuples with the same
  structure as the params — the sharding layer maps these onto the mesh.
* Params are stored in float32 ("master" precision); ``apply`` casts to the
  compute dtype carried by the activations, so the same params serve the
  bf16 forward pass and the fp32 optimizer update.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key: Array, shape: tuple[int, ...], in_dim: int,
               dtype=jnp.float32) -> Array:
    """Truncated-normal fan-in init (MaxText-style 1/sqrt(fan_in))."""
    std = 1.0 / math.sqrt(in_dim)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key: Array, shape: tuple[int, ...], dtype=jnp.float32) -> Array:
    return jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# RMSNorm (all assigned archs are RMSNorm-family; gemma uses (1 + w) scale)
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int) -> dict:
    return {"scale": jnp.zeros((dim,), jnp.float32)}


def rmsnorm_axes() -> dict:
    return {"scale": (None,)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    """RMSNorm with gemma-style (1 + scale); scale==0 init is identity."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# MLP: gated (SwiGLU / GeGLU) and non-gated (gelu / relu^2) variants
# ---------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def mlp_init(key: Array, d_model: int, d_ff: int, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (d_model, d_ff), d_model),
         "wo": dense_init(ks[2], (d_ff, d_model), d_ff)}
    if gated:
        p["wg"] = dense_init(ks[1], (d_model, d_ff), d_model)
    return p


def mlp_axes(gated: bool = True) -> dict:
    p = {"wi": ("fsdp", "ffn"), "wo": ("ffn", "fsdp")}
    if gated:
        p["wg"] = ("fsdp", "ffn")
    return p


def mlp(params: dict, x: Array, act: str = "silu") -> Array:
    """[B, S, D] -> [B, S, D]. Gated if params carry ``wg``."""
    dtype = x.dtype
    fn = _ACTS[act]
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(dtype))
    if "wg" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(dtype))
        h = fn(g) * h
    else:
        h = fn(h)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# Token embedding (tied or untied unembedding)
# ---------------------------------------------------------------------------


def embedding_init(key: Array, vocab: int, d_model: int, tied: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"table": embed_init(k1, (vocab, d_model))}
    if not tied:
        p["unembed"] = dense_init(k2, (d_model, vocab), d_model)
    return p


def embedding_axes(tied: bool) -> dict:
    p = {"table": ("vocab", "fsdp")}
    if not tied:
        p["unembed"] = ("fsdp", "vocab")
    return p


def embed_tokens(params: dict, tokens: Array, scale: bool,
                 dtype=jnp.bfloat16) -> Array:
    """[B, S] int32 -> [B, S, D]."""
    table = params["table"].astype(dtype)
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(table.shape[-1]), dtype)
    return x


def unembed(params: dict, x: Array, softcap: Optional[float]) -> Array:
    """[B, S, D] -> [B, S, V] logits (fp32)."""
    w = params.get("unembed")
    if w is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["table"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Apply RoPE. x: [B, S, N, H], positions: [B, S] (int32)."""
    h = x.shape[-1]
    half = h // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_pos(positions: Array, d_model: int, dtype=jnp.bfloat16) -> Array:
    """Sinusoidal absolute position embedding [B, S] -> [B, S, D]
    (MusicGen-style transformer uses sinusoidal embeddings)."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
