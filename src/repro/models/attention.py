"""Multi-head attention: MHA / GQA / MQA with RoPE, QK-norm, logit softcap,
sliding-window (local) masking, optional QKV bias, and a KV cache for decode.

Three entry points:
  * ``attend_full``  — training / prefill self-attention over [B, S, D].
  * ``attend_decode``— one new token per sequence against a KV cache.
  * ``init_cache``   — allocate (or spec) the per-layer cache.

``constrain`` is a callback (x, logical_axes) -> x used for sharding
annotations; the transformer layer passes the mesh-aware one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import layers
from .layers import Array, dense_init, rmsnorm, rmsnorm_init, rmsnorm_axes

Constrain = Callable[[Array, tuple], Array]
_id_constrain: Constrain = lambda x, _: x

NEG_INF = -2.3819763e38  # bf16-safe large negative


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: Optional[float] = 10000.0   # None = no RoPE (absolute pos)
    logit_softcap: Optional[float] = None
    window: Optional[int] = None            # sliding window (local layers)
    scale: Optional[float] = None           # default head_dim ** -0.5
    q_in_dim: Optional[int] = None          # != d_model for zamba2 concat in
    out_dim: Optional[int] = None           # output projection width

    @property
    def resolved_scale(self) -> float:
        return self.scale if self.scale is not None else self.head_dim ** -0.5

    @property
    def in_dim(self) -> int:
        return self.q_in_dim or self.d_model

    @property
    def o_dim(self) -> int:
        return self.out_dim or self.d_model


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def attn_init(key: Array, cfg: AttnConfig) -> dict:
    ks = jax.random.split(key, 6)
    d, h = cfg.in_dim, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads, h), d),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads, h), d),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads, h), d),
        "wo": dense_init(ks[3], (cfg.num_heads, h, cfg.o_dim),
                         cfg.num_heads * h),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, h), jnp.float32)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, h), jnp.float32)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, h), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(h)
        p["k_norm"] = rmsnorm_init(h)
    return p


def attn_axes(cfg: AttnConfig) -> dict:
    p = {
        "wq": ("fsdp", "heads", "head_dim"),
        "wk": ("fsdp", "kv_heads", "head_dim"),
        "wv": ("fsdp", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("heads", "head_dim")
        p["bk"] = ("kv_heads", "head_dim")
        p["bv"] = ("kv_heads", "head_dim")
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_axes()
        p["k_norm"] = rmsnorm_axes()
    return p


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------


def _project_qkv(params: dict, cfg: AttnConfig, x: Array,
                 positions: Array) -> tuple[Array, Array, Array]:
    dtype = x.dtype
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dkh->bskh", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dkh->bskh", x, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if cfg.rope_theta is not None:
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: Array, num_heads: int) -> Array:
    """[B, S, K, H] -> [B, S, N, H] by repeating each kv head N/K times."""
    kv = k.shape[2]
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=2)


# ---------------------------------------------------------------------------
# full (train / prefill) attention
# ---------------------------------------------------------------------------


def _causal_mask(s_q: int, s_k: int, window: Optional[int],
                 q_offset: Array | int = 0) -> Array:
    """[s_q, s_k] boolean mask; True = attend. ``q_offset`` shifts query
    positions (prefill continuation)."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    ki = jnp.arange(s_k)[None, :]
    m = ki <= qi
    if window is not None:
        m = m & (ki > qi - window)
    return m


def attend_full(params: dict, cfg: AttnConfig, x: Array, positions: Array,
                constrain: Constrain = _id_constrain,
                impl: str = "xla") -> Array:
    """Causal self-attention over the whole sequence. x: [B, S, D_in]."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    q = constrain(q, ("batch", "act_seq", "act_heads", None))
    k = constrain(k, ("batch", "act_seq", "act_kv_heads", None))
    v = constrain(v, ("batch", "act_seq", "act_kv_heads", None))
    if impl == "flash":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, scale=cfg.resolved_scale,
                                 window=cfg.window,
                                 softcap=cfg.logit_softcap)
    else:
        k = _repeat_kv(k, cfg.num_heads)
        v = _repeat_kv(v, cfg.num_heads)
        logits = jnp.einsum("bqnh,bknh->bnqk", q, k) * cfg.resolved_scale
        # the [S, S] logits are the big intermediate of the XLA path —
        # pin their sharding (batch x heads, and q-seq context-parallel
        # when heads don't divide the model axis) so SPMD never
        # replicates them. The Pallas flash kernel never materializes
        # this tensor at all on TPU.
        lg_axes = ("batch", "act_heads", "act_seq_q", None)
        logits = constrain(logits, lg_axes)
        logits = layers.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        mask = _causal_mask(q.shape[1], k.shape[1], cfg.window)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        probs = constrain(probs, lg_axes)
        o = jnp.einsum("bnqk,bknh->bqnh", probs, v)
    o = constrain(o, ("batch", "act_seq", "act_heads", None))
    return jnp.einsum("bqnh,nho->bqo", o, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# KV cache + decode attention
# ---------------------------------------------------------------------------


def init_cache(batch: int, max_seq: int, cfg: AttnConfig,
               dtype=jnp.bfloat16) -> dict:
    shape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_spec(batch: int, max_seq: int, cfg: AttnConfig,
               dtype=jnp.bfloat16) -> dict:
    shape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def cache_axes() -> dict:
    return {"k": ("batch", "kv_seq", "act_kv_heads", None),
            "v": ("batch", "kv_seq", "act_kv_heads", None)}


def update_cache(cache: dict, k_new: Array, v_new: Array,
                 pos: Array) -> dict:
    """Write one new token per sequence. k_new: [B, 1, K, H], pos: [B]."""
    b = k_new.shape[0]
    idx = jnp.arange(b)
    k = cache["k"].at[idx, pos].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[idx, pos].set(v_new[:, 0].astype(cache["v"].dtype))
    return {"k": k, "v": v}


def fill_cache(cache: dict, k_new: Array, v_new: Array) -> dict:
    """Prefill: write the first S positions wholesale. k_new: [B, S, K, H]."""
    s = k_new.shape[1]
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), 0, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), 0, axis=1)
    del s
    return {"k": k, "v": v}


def attend_decode(params: dict, cfg: AttnConfig, x: Array, cache: dict,
                  pos: Array, constrain: Constrain = _id_constrain,
                  ) -> tuple[Array, dict]:
    """One-token decode. x: [B, 1, D_in], pos: [B] (current write index).

    Returns (out [B, 1, D_out], updated cache). Attends over cache[0..pos].
    The softmax statistics are computed in fp32; masking covers both the
    causal bound and the sliding window if configured.
    """
    q, k_new, v_new = _project_qkv(params, cfg, x, pos[:, None])
    cache = update_cache(cache, k_new, v_new, pos)
    k, v = cache["k"], cache["v"]
    k = constrain(k, ("batch", "kv_seq", "act_kv_heads", "head_dim"))
    v = constrain(v, ("batch", "kv_seq", "act_kv_heads", "head_dim"))
    kh = _repeat_kv(k, cfg.num_heads)
    vh = _repeat_kv(v, cfg.num_heads)
    logits = jnp.einsum("bqnh,bknh->bnqk", q, kh) * cfg.resolved_scale
    # decode logits follow the CACHE's sharding: its sequence axis when the
    # cache is seq-sharded (flash-decode style — each shard owns a KV
    # slice; softmax stats combine via tiny all-reduces), its head axis
    # otherwise. Without this pin, SPMD pulls the logits toward a layout
    # that replicates the whole cache per step.
    lg_axes = ("batch", "act_kv_heads", None, "kv_seq")
    logits = constrain(logits, lg_axes)
    logits = layers.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    ki = jnp.arange(k.shape[1])[None, None, None, :]
    mask = ki <= pos[:, None, None, None]
    if cfg.window is not None:
        mask = mask & (ki > pos[:, None, None, None] - cfg.window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    probs = constrain(probs, lg_axes)
    o = jnp.einsum("bnqk,bknh->bqnh", probs, vh)
    out = jnp.einsum("bqnh,nho->bqo", o, params["wo"].astype(x.dtype))
    return out, cache


def attend_prefill(params: dict, cfg: AttnConfig, x: Array, positions: Array,
                   cache: dict, constrain: Constrain = _id_constrain,
                   impl: str = "xla") -> tuple[Array, dict]:
    """Prefill: full attention over the prompt AND fill the cache."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    cache = fill_cache(cache, k, v)
    q = constrain(q, ("batch", "act_seq", "act_heads", None))
    if impl == "flash":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, scale=cfg.resolved_scale,
                                 window=cfg.window,
                                 softcap=cfg.logit_softcap)
    else:
        kh = _repeat_kv(k, cfg.num_heads)
        vh = _repeat_kv(v, cfg.num_heads)
        logits = jnp.einsum("bqnh,bknh->bnqk", q, kh) * cfg.resolved_scale
        logits = layers.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        mask = _causal_mask(q.shape[1], k.shape[1], cfg.window)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        o = jnp.einsum("bnqk,bknh->bqnh", probs, vh)
    out = jnp.einsum("bqnh,nho->bqo", o, params["wo"].astype(x.dtype))
    return out, cache


def flops_full(cfg: AttnConfig, batch: int, seq: int) -> int:
    """Analytic MACs for one full-attention layer (projections + attention)."""
    d, n, k_, h = cfg.in_dim, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = batch * seq * d * h * (n + 2 * k_) + batch * seq * n * h * cfg.o_dim
    ctx = seq if cfg.window is None else min(seq, cfg.window)
    attn = 2 * batch * n * seq * ctx * h // 2  # causal halves the square
    return proj + attn
