"""The LM: composes attention / MoE / SSD blocks into any assigned arch.

One code path serves all ten architectures; ``ArchConfig`` chooses the block
kinds. Layers are grouped into *super-blocks* (one repetition of the layer
pattern — e.g. (local, global) for gemma2, six mamba blocks + one shared
attention application for zamba2) and scanned with ``lax.scan`` over stacked
group parameters, which keeps HLO size O(1) in depth and is what makes the
94-layer MoE compile tractably on a 512-device mesh.

API (all functional, params are plain dict pytrees):
  init_params / param_axes            — parameters + logical sharding axes
  forward                             — [B,S] tokens -> (logits, aux) (train)
  init_cache / cache_spec / cache_axes— decode caches (KV / SSM state)
  prefill                             — forward + cache fill
  decode_step                         — one token per sequence
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from . import attention as attn
from . import layers, moe, ssm

Array = jax.Array
Constrain = Callable[[Array, tuple], Array]
_id: Constrain = lambda x, _: x


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------


def attn_cfg_for(cfg: ArchConfig, kind: str) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=None if cfg.pos_embed == "absolute" else cfg.rope_theta,
        logit_softcap=cfg.attn_logit_softcap,
        window=cfg.local_window if kind == "local" else None,
        scale=cfg.attn_scale,
    )


def shared_attn_cfg_for(cfg: ArchConfig) -> attn.AttnConfig:
    """Zamba2-style shared block: input is concat(x, x_embed) of width 2D."""
    return attn.AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=(2 * cfg.d_model) // cfg.num_heads,
        rope_theta=cfg.rope_theta,
        q_in_dim=2 * cfg.d_model,
        out_dim=cfg.d_model,
    )


def moe_cfg_for(cfg: ArchConfig) -> moe.MoEConfig:
    return moe.MoEConfig(
        d_model=cfg.d_model,
        d_ff=cfg.d_ff,
        num_experts=cfg.num_experts,
        top_k=cfg.num_experts_per_tok,
        capacity_factor=cfg.moe_capacity_factor,
        act=cfg.mlp_act,
        impl=cfg.moe_impl,
    )


def ssm_cfg_for(cfg: ArchConfig) -> ssm.SSMConfig:
    return ssm.SSMConfig(
        d_model=cfg.d_model,
        state=cfg.ssm_state,
        heads=cfg.ssm_heads,
        expand=cfg.ssm_expand,
        conv_kernel=cfg.ssm_conv_kernel,
        chunk=cfg.ssm_chunk,
        impl=cfg.ssm_impl,
    )


def group_pattern(cfg: ArchConfig) -> tuple[str, ...]:
    """Block kinds inside one scanned super-block."""
    if cfg.shared_attn_every:
        return ("mamba",) * cfg.shared_attn_every
    return cfg.layer_pattern


def num_groups(cfg: ArchConfig) -> int:
    pat = len(group_pattern(cfg))
    assert cfg.num_layers % pat == 0, (cfg.num_layers, pat)
    return cfg.num_layers // pat


def compute_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# per-block init / axes / apply
# ---------------------------------------------------------------------------


def _block_init(key: Array, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    if kind == "mamba":
        return {"ln": layers.rmsnorm_init(cfg.d_model),
                "ssm": ssm.ssm_init(ks[0], ssm_cfg_for(cfg))}
    p = {
        "ln1": layers.rmsnorm_init(cfg.d_model),
        "attn": attn.attn_init(ks[0], attn_cfg_for(cfg, kind)),
        "ln2": layers.rmsnorm_init(cfg.d_model),
    }
    if cfg.post_norms:
        p["post_ln1"] = layers.rmsnorm_init(cfg.d_model)
        p["post_ln2"] = layers.rmsnorm_init(cfg.d_model)
    if cfg.num_experts:
        p["moe"] = moe.moe_init(ks[1], moe_cfg_for(cfg))
    else:
        p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                   gated=cfg.mlp_gated)
    return p


def _block_axes(cfg: ArchConfig, kind: str) -> dict:
    if kind == "mamba":
        return {"ln": layers.rmsnorm_axes(), "ssm": ssm.ssm_axes()}
    p = {
        "ln1": layers.rmsnorm_axes(),
        "attn": attn.attn_axes(attn_cfg_for(cfg, kind)),
        "ln2": layers.rmsnorm_axes(),
    }
    if cfg.post_norms:
        p["post_ln1"] = layers.rmsnorm_axes()
        p["post_ln2"] = layers.rmsnorm_axes()
    if cfg.num_experts:
        p["moe"] = moe.moe_axes()
    else:
        p["mlp"] = layers.mlp_axes(gated=cfg.mlp_gated)
    return p


def _apply_block(params: dict, cfg: ArchConfig, kind: str, x: Array,
                 positions: Array, constrain: Constrain,
                 attn_impl: str) -> tuple[Array, Array]:
    """Full-sequence block application. Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h = layers.rmsnorm(params["ln"], x)
        x = x + ssm.ssm_apply(params["ssm"], ssm_cfg_for(cfg), h, constrain)
        return x, aux
    h = layers.rmsnorm(params["ln1"], x)
    a = attn.attend_full(params["attn"], attn_cfg_for(cfg, kind), h,
                         positions, constrain, impl=attn_impl)
    if cfg.post_norms:
        a = layers.rmsnorm(params["post_ln1"], a)
    x = x + a
    h = layers.rmsnorm(params["ln2"], x)
    if cfg.num_experts:
        m, aux = moe.moe_apply(params["moe"], moe_cfg_for(cfg), h, constrain)
    else:
        m = layers.mlp(params["mlp"], h, act=cfg.mlp_act)
    if cfg.post_norms:
        m = layers.rmsnorm(params["post_ln2"], m)
    x = x + m
    x = constrain(x, ("batch", "act_seq", "embed"))
    return x, aux


def _apply_shared_attn(params: dict, cfg: ArchConfig, x: Array, x0: Array,
                       positions: Array, constrain: Constrain,
                       attn_impl: str) -> Array:
    """Zamba2 shared block: attn over concat(x, x0) + MLP, weights shared
    across every invocation."""
    cat = jnp.concatenate([x, x0], axis=-1)
    h = layers.rmsnorm(params["ln"], cat)
    a = attn.attend_full(params["attn"], shared_attn_cfg_for(cfg), h,
                         positions, constrain, impl=attn_impl)
    x = x + a
    h = layers.rmsnorm(params["ln2"], x)
    x = x + layers.mlp(params["mlp"], h, act=cfg.mlp_act)
    return x


# ---------------------------------------------------------------------------
# whole-model init / axes
# ---------------------------------------------------------------------------


def init_params(key: Array, cfg: ArchConfig) -> dict:
    pat = group_pattern(cfg)
    g = num_groups(cfg)
    keys = jax.random.split(key, 4)
    params: dict = {
        "embed": layers.embedding_init(keys[0], cfg.vocab_size, cfg.d_model,
                                       cfg.tie_embeddings),
        "final_norm": layers.rmsnorm_init(cfg.d_model),
    }
    if cfg.frontend:
        params["frontend"] = {"proj": layers.dense_init(
            keys[1], (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim)}
    if cfg.shared_attn_every:
        ks = jax.random.split(keys[2], 3)
        params["shared_attn"] = {
            "ln": layers.rmsnorm_init(2 * cfg.d_model),
            "attn": attn.attn_init(ks[0], shared_attn_cfg_for(cfg)),
            "ln2": layers.rmsnorm_init(cfg.d_model),
            "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                   gated=cfg.mlp_gated),
        }
    gkeys = jax.random.split(keys[3], g)

    def one_group(k):
        bkeys = jax.random.split(k, len(pat))
        return {str(i): _block_init(bkeys[i], cfg, kind)
                for i, kind in enumerate(pat)}

    groups = [one_group(k) for k in gkeys]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    return params


def param_axes(cfg: ArchConfig) -> dict:
    pat = group_pattern(cfg)
    axes: dict = {
        "embed": layers.embedding_axes(cfg.tie_embeddings),
        "final_norm": layers.rmsnorm_axes(),
    }
    if cfg.frontend:
        axes["frontend"] = {"proj": ("fsdp", None)}
    if cfg.shared_attn_every:
        axes["shared_attn"] = {
            "ln": layers.rmsnorm_axes(),
            "attn": attn.attn_axes(shared_attn_cfg_for(cfg)),
            "ln2": layers.rmsnorm_axes(),
            "mlp": layers.mlp_axes(gated=cfg.mlp_gated),
        }
    block_axes = {str(i): _block_axes(cfg, kind) for i, kind in enumerate(pat)}
    # prepend the stacked group axis to every leaf
    axes["blocks"] = jax.tree.map(
        lambda lg: ("layers",) + lg, block_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return axes


# ---------------------------------------------------------------------------
# forward (train / eval)
# ---------------------------------------------------------------------------


def _embed_input(params: dict, cfg: ArchConfig, tokens: Array,
                 frontend: Optional[Array], positions: Array,
                 constrain: Constrain) -> Array:
    dtype = compute_dtype(cfg)
    x = layers.embed_tokens(params["embed"], tokens, cfg.embed_scale, dtype)
    if cfg.frontend and frontend is not None:
        f = jnp.einsum("bfe,ed->bfd", frontend.astype(dtype),
                       params["frontend"]["proj"].astype(dtype))
        nf = f.shape[1]
        x = jnp.concatenate([f, x[:, nf:]], axis=1)  # frontend fills the head
    if cfg.pos_embed == "absolute":
        x = x + layers.sinusoidal_pos(positions, cfg.d_model, dtype)
    return constrain(x, ("batch", "act_seq", "embed"))


def forward(params: dict, cfg: ArchConfig, tokens: Array,
            frontend: Optional[Array] = None,
            constrain: Constrain = _id,
            attn_impl: str = "xla") -> tuple[Array, Array]:
    """Causal LM forward. tokens: [B, S] int32 -> (logits [B,S,V] f32, aux)."""
    b, s = tokens.shape
    pat = group_pattern(cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed_input(params, cfg, tokens, frontend, positions, constrain)
    x0 = x

    def group_body(carry, gparams):
        x, aux = carry
        if cfg.shared_attn_every:
            x = _apply_shared_attn(params["shared_attn"], cfg, x, x0,
                                   positions, constrain, attn_impl)
        for i, kind in enumerate(pat):
            x, a = _apply_block(gparams[str(i)], cfg, kind, x, positions,
                                constrain, attn_impl)
            aux = aux + a
        return (x, aux), None

    body = group_body
    if cfg.remat != "none":
        policy = {
            "full": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.checkpoint_dots,
            # MaxText-style: save projection/MLP dots but NOT the [S, S]
            # attention logits (batch-dim dots) — recompute them in the
            # backward pass. This is the policy that keeps activation
            # residuals O(S * d) instead of O(S^2).
            "dots_nobatch":
                jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        }[cfg.remat]
        body = jax.checkpoint(group_body, policy=policy,
                              prevent_cse=not cfg.scan_layers)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    else:
        carry = (x, aux0)
        g = num_groups(cfg)
        for gi in range(g):
            gparams = jax.tree.map(lambda p: p[gi], params["blocks"])
            carry, _ = body(carry, gparams)
        x, aux = carry

    x = layers.rmsnorm(params["final_norm"], x)
    logits = layers.unembed(params["embed"], x, cfg.final_logit_softcap)
    logits = constrain(logits, ("batch", "act_seq", "vocab_out"))
    return logits, aux


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def _group_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype,
                 spec: bool) -> dict:
    pat = group_pattern(cfg)
    mk_attn = attn.cache_spec if spec else attn.init_cache
    mk_ssm = ssm.state_spec if spec else ssm.init_state
    cache: dict = {}
    for i, kind in enumerate(pat):
        if kind == "mamba":
            cache[str(i)] = mk_ssm(batch, ssm_cfg_for(cfg))
        else:
            cache[str(i)] = mk_attn(batch, max_seq, attn_cfg_for(cfg, kind),
                                    dtype)
    if cfg.shared_attn_every:
        cache["shared"] = mk_attn(batch, max_seq, shared_attn_cfg_for(cfg),
                                  dtype)
    return cache


def _stack_cache(cfg: ArchConfig, group_cache: dict, spec: bool) -> dict:
    g = num_groups(cfg)
    if spec:
        return jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((g,) + sd.shape, sd.dtype),
            group_cache)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (g,) + x.shape), group_cache)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    return _stack_cache(cfg, _group_cache(cfg, batch, max_seq, dtype, False),
                        False)


def cache_spec(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    return _stack_cache(cfg, _group_cache(cfg, batch, max_seq, dtype, True),
                        True)


def cache_axes(cfg: ArchConfig) -> dict:
    pat = group_pattern(cfg)
    ax: dict = {}
    for i, kind in enumerate(pat):
        ax[str(i)] = (ssm.state_axes() if kind == "mamba"
                      else attn.cache_axes())
    if cfg.shared_attn_every:
        ax["shared"] = attn.cache_axes()
    return jax.tree.map(
        lambda lg: ("layers",) + lg, ax,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# prefill + decode
# ---------------------------------------------------------------------------


def prefill(params: dict, cfg: ArchConfig, tokens: Array, cache: dict,
            frontend: Optional[Array] = None,
            constrain: Constrain = _id,
            attn_impl: str = "xla") -> tuple[Array, dict]:
    """Run the prompt, fill the caches. Returns (logits [B,S,V], cache)."""
    b, s = tokens.shape
    pat = group_pattern(cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed_input(params, cfg, tokens, frontend, positions, constrain)
    x0 = x

    def group_body(x, xs):
        gparams, gcache = xs
        new_cache = dict(gcache)
        if cfg.shared_attn_every:
            cat = jnp.concatenate([x, x0], axis=-1)
            h = layers.rmsnorm(params["shared_attn"]["ln"], cat)
            a, kv = attn.attend_prefill(
                params["shared_attn"]["attn"], shared_attn_cfg_for(cfg), h,
                positions, gcache["shared"], constrain, impl=attn_impl)
            x = x + a
            h = layers.rmsnorm(params["shared_attn"]["ln2"], x)
            x = x + layers.mlp(params["shared_attn"]["mlp"], h,
                               act=cfg.mlp_act)
            new_cache["shared"] = kv
        for i, kind in enumerate(pat):
            bp = gparams[str(i)]
            if kind == "mamba":
                h = layers.rmsnorm(bp["ln"], x)
                y, st = ssm_prefill(bp["ssm"], ssm_cfg_for(cfg), h, constrain)
                x = x + y
                new_cache[str(i)] = st
            else:
                acfg = attn_cfg_for(cfg, kind)
                h = layers.rmsnorm(bp["ln1"], x)
                a, kv = attn.attend_prefill(bp["attn"], acfg, h, positions,
                                            gcache[str(i)], constrain,
                                            impl=attn_impl)
                if cfg.post_norms:
                    a = layers.rmsnorm(bp["post_ln1"], a)
                x = x + a
                h = layers.rmsnorm(bp["ln2"], x)
                if cfg.num_experts:
                    m, _ = moe.moe_apply(bp["moe"], moe_cfg_for(cfg), h,
                                         constrain)
                else:
                    m = layers.mlp(bp["mlp"], h, act=cfg.mlp_act)
                if cfg.post_norms:
                    m = layers.rmsnorm(bp["post_ln2"], m)
                x = x + m
                new_cache[str(i)] = kv
        return x, new_cache

    if cfg.scan_layers:
        x, cache = jax.lax.scan(group_body, x, (params["blocks"], cache))
    else:
        outs = []
        for gi in range(num_groups(cfg)):
            gp = jax.tree.map(lambda p: p[gi], params["blocks"])
            gc = jax.tree.map(lambda c: c[gi], cache)
            x, nc = group_body(x, (gp, gc))
            outs.append(nc)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = layers.rmsnorm(params["final_norm"], x)
    logits = layers.unembed(params["embed"], x, cfg.final_logit_softcap)
    return logits, cache


def ssm_prefill(params: dict, scfg: ssm.SSMConfig, u: Array,
                constrain: Constrain = _id) -> tuple[Array, dict]:
    """Mamba2 full-sequence apply that also returns the decode state."""
    b, s, _ = u.shape
    dtype = u.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", u, params["in_proj"].astype(dtype))
    z, xbc_pre, dt = ssm._split_proj(scfg, zxbcdt)
    xbc = jax.nn.silu(ssm._causal_conv(params, xbc_pre))
    x = xbc[..., : scfg.d_inner]
    bmat = xbc[..., scfg.d_inner: scfg.d_inner + scfg.state].astype(jnp.float32)
    cmat = xbc[..., scfg.d_inner + scfg.state:].astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["A_log"])
    xh = x.reshape(b, s, scfg.heads, scfg.head_dim)
    y, fin = ssm._run_ssd(scfg, xh, dtp, a, bmat, cmat, params["D"])
    y = y.reshape(b, s, scfg.d_inner)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(dtype))
    k = scfg.conv_kernel
    conv_state = jnp.pad(xbc_pre, ((0, 0), (max(k - 1 - s, 0), 0), (0, 0))
                         )[:, -(k - 1):, :]
    return out, {"conv": conv_state.astype(jnp.float32), "ssm": fin}


def decode_step(params: dict, cfg: ArchConfig, tokens: Array, cache: dict,
                pos: Array, constrain: Constrain = _id,
                attn_impl: str = "xla") -> tuple[Array, dict]:
    """One decode step. tokens: [B, 1], pos: [B] (write index).
    Returns (logits [B, 1, V] f32, new cache)."""
    pat = group_pattern(cfg)
    dtype = compute_dtype(cfg)
    x = layers.embed_tokens(params["embed"], tokens, cfg.embed_scale, dtype)
    if cfg.pos_embed == "absolute":
        x = x + layers.sinusoidal_pos(pos[:, None], cfg.d_model, dtype)
    x0 = x

    def group_body(x, xs):
        gparams, gcache = xs
        new_cache = dict(gcache)
        if cfg.shared_attn_every:
            cat = jnp.concatenate([x, x0], axis=-1)
            h = layers.rmsnorm(params["shared_attn"]["ln"], cat)
            a, kv = attn.attend_decode(
                params["shared_attn"]["attn"], shared_attn_cfg_for(cfg), h,
                gcache["shared"], pos, constrain)
            x = x + a
            h = layers.rmsnorm(params["shared_attn"]["ln2"], x)
            x = x + layers.mlp(params["shared_attn"]["mlp"], h,
                               act=cfg.mlp_act)
            new_cache["shared"] = kv
        for i, kind in enumerate(pat):
            bp = gparams[str(i)]
            if kind == "mamba":
                h = layers.rmsnorm(bp["ln"], x)
                y, st = ssm.ssm_decode(bp["ssm"], ssm_cfg_for(cfg), h,
                                       gcache[str(i)], constrain)
                x = x + y
                new_cache[str(i)] = st
            else:
                acfg = attn_cfg_for(cfg, kind)
                h = layers.rmsnorm(bp["ln1"], x)
                a, kv = attn.attend_decode(bp["attn"], acfg, h,
                                           gcache[str(i)], pos, constrain)
                if cfg.post_norms:
                    a = layers.rmsnorm(bp["post_ln1"], a)
                x = x + a
                h = layers.rmsnorm(bp["ln2"], x)
                if cfg.num_experts:
                    m, _ = moe.moe_apply(bp["moe"], moe_cfg_for(cfg), h,
                                         constrain)
                else:
                    m = layers.mlp(bp["mlp"], h, act=cfg.mlp_act)
                if cfg.post_norms:
                    m = layers.rmsnorm(bp["post_ln2"], m)
                x = x + m
                new_cache[str(i)] = kv
        return x, new_cache

    if cfg.scan_layers:
        x, cache = jax.lax.scan(group_body, x, (params["blocks"], cache))
    else:
        outs = []
        for gi in range(num_groups(cfg)):
            gp = jax.tree.map(lambda p: p[gi], params["blocks"])
            gc = jax.tree.map(lambda c: c[gi], cache)
            x, nc = group_body(x, (gp, gc))
            outs.append(nc)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = layers.rmsnorm(params["final_norm"], x)
    logits = layers.unembed(params["embed"], x, cfg.final_logit_softcap)
    return logits, cache


# ---------------------------------------------------------------------------
# convenience
# ---------------------------------------------------------------------------


class LM:
    """Thin OO veneer over the functional API (examples / serving use this)."""

    def __init__(self, cfg: ArchConfig, constrain: Constrain = _id,
                 attn_impl: str = "xla"):
        self.cfg = cfg
        self.constrain = constrain
        self.attn_impl = attn_impl

    def init(self, key: Array) -> dict:
        return init_params(key, self.cfg)

    def axes(self) -> dict:
        return param_axes(self.cfg)

    def __call__(self, params, tokens, frontend=None):
        return forward(params, self.cfg, tokens, frontend, self.constrain,
                       self.attn_impl)

    def prefill(self, params, tokens, cache, frontend=None):
        return prefill(params, self.cfg, tokens, cache, frontend,
                       self.constrain, self.attn_impl)

    def decode_step(self, params, tokens, cache, pos):
        return decode_step(params, self.cfg, tokens, cache, pos,
                           self.constrain)

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        return init_cache(self.cfg, batch, max_seq, dtype)
