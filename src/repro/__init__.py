"""repro: DREAM RTMM scheduler (Level 1) + multi-pod JAX framework (Level 2)."""
__version__ = "1.0.0"
