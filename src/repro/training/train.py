"""Train-step builder + fault-tolerant trainer loop.

``build_train_step`` assembles the jitted SPMD step for any ArchConfig:
loss -> grad (with microbatch accumulation under lax.scan) -> optional
int8 error-feedback gradient compression -> AdamW update. Shardings come
from the logical-axis tables (distributed.sharding); the same function
lowers on 1 CPU device or a (pod, data, model) production mesh.

``Trainer`` owns the loop: periodic + final checkpoints (atomic, reshard-
able), ``resume="auto"``, straggler watermarks, and a fault-injection hook
the integration tests use to prove crash -> restart -> identical-trajectory
recovery.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import ArchConfig
from repro.distributed import (CheckpointManager, CompressionConfig,
                               FaultInjector, StragglerDetector,
                               compress_with_feedback, init_error_state)
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.training import loss as L
from repro.training import optim

Array = jax.Array


@dataclass(frozen=True)
class TrainConfig:
    optim: optim.OptimConfig = optim.OptimConfig()
    accum: int = 1                        # microbatch accumulation factor
    compression: Optional[CompressionConfig] = None
    aux_weight: float = 1e-2
    z_loss: float = 1e-4


def make_constrain(rules) -> Callable:
    return functools.partial(shd.constrain, rules=rules)


def build_train_step(cfg: ArchConfig, tcfg: TrainConfig,
                     rules: Optional[dict] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). state is a dict
    {params, opt, err?}; batch {tokens, labels} with global batch divisible
    by tcfg.accum."""
    constrain = make_constrain(rules) if rules is not None else (
        functools.partial(shd.constrain))

    def loss_fn(params, batch):
        logits, aux = M.forward(params, cfg, batch["tokens"],
                                batch.get("frontend"), constrain=constrain)
        return L.lm_loss(logits, batch["labels"], aux, tcfg.aux_weight,
                         tcfg.z_loss)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.accum <= 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        a = tcfg.accum
        b = batch["tokens"].shape[0]
        assert b % a == 0, (b, a)
        mbs = {k: v.reshape((a, b // a) + v.shape[1:])
               for k, v in batch.items()}

        def micro(carry, mb):
            acc, met_acc = carry
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda x, g: x + g.astype(jnp.float32),
                               acc, grads)
            met_acc = {k: met_acc[k] + metrics[k] for k in met_acc}
            return (acc, met_acc), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        _, m0 = jax.eval_shape(lambda: loss_fn(
            params, jax.tree.map(lambda v: v[0], mbs)))
        zero_m = {k: jnp.zeros(v.shape, v.dtype) for k, v in m0.items()}
        (acc, mets), _ = jax.lax.scan(micro, (zero_g, zero_m), mbs)
        grads = jax.tree.map(lambda g: g / a, acc)
        metrics = {k: v / a for k, v in mets.items()}
        return grads, metrics

    def train_step(state, batch):
        grads, metrics = compute_grads(state["params"], batch)
        if tcfg.compression is not None:
            grads, new_err = compress_with_feedback(
                grads, state["err"], tcfg.compression)
        params, opt_state, opt_metrics = optim.apply_updates(
            state["params"], grads, state["opt"], tcfg.optim)
        metrics.update(opt_metrics)
        new_state = {"params": params, "opt": opt_state}
        if tcfg.compression is not None:
            new_state["err"] = new_err
        return new_state, metrics

    return train_step


def init_train_state(key: Array, cfg: ArchConfig, tcfg: TrainConfig) -> dict:
    params = M.init_params(key, cfg)
    state = {"params": params, "opt": optim.init_state(params)}
    if tcfg.compression is not None:
        state["err"] = init_error_state(params)
    return state


def train_state_axes(cfg: ArchConfig, tcfg: TrainConfig) -> dict:
    pax = M.param_axes(cfg)
    ax = {"params": pax, "opt": optim.state_axes(pax)}
    if tcfg.compression is not None:
        ax["err"] = jax.tree.map(lambda a: a, pax)
    return ax


@dataclass
class Trainer:
    cfg: ArchConfig
    tcfg: TrainConfig
    data: Iterator[dict]
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    mesh: Optional[Mesh] = None
    rules: Optional[dict] = None
    seed: int = 0
    fault_injector: Optional[FaultInjector] = None
    straggler: StragglerDetector = field(default_factory=StragglerDetector)
    log_every: int = 10
    log_fn: Callable[[str], None] = print

    def __post_init__(self) -> None:
        self._step_fn = jax.jit(build_train_step(self.cfg, self.tcfg,
                                                 self.rules))
        self._mgr = (CheckpointManager(self.ckpt_dir)
                     if self.ckpt_dir else None)
        self.state: Optional[dict] = None
        self.step = 0
        self.metrics_history: list[dict] = []

    # ------------------------------------------------------------ lifecycle
    def init_or_resume(self, resume: str = "auto") -> None:
        if (resume in ("auto", "must") and self._mgr is not None
                and self._mgr.latest_step() is not None):
            step, state, _ = self._mgr.restore()
            self.state, self.step = state, step
            self.log_fn(f"[trainer] resumed from step {step}")
            return
        if resume == "must":
            raise FileNotFoundError("resume='must' but no checkpoint found")
        key = jax.random.PRNGKey(self.seed)
        self.state = init_train_state(key, self.cfg, self.tcfg)
        self.step = 0

    def save(self) -> None:
        if self._mgr is not None and self.state is not None:
            self._mgr.save(self.step, self.state)

    # ----------------------------------------------------------------- run
    def run(self, num_steps: int) -> list[dict]:
        assert self.state is not None, "call init_or_resume() first"
        ctx = self.mesh if self.mesh is not None else _nullcontext()
        with ctx:
            while self.step < num_steps:
                if self.fault_injector is not None:
                    self.fault_injector.check(self.step)
                batch = next(self.data)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                self.straggler.start()
                self.state, metrics = self._step_fn(self.state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                slow = self.straggler.stop(self.step)
                if slow is not None:
                    self.log_fn(f"[trainer] straggler step {self.step}: "
                                f"{slow:.1f}x median")
                self.step += 1
                metrics["step"] = self.step
                self.metrics_history.append(metrics)
                if self.step % self.log_every == 0:
                    self.log_fn(
                        f"[trainer] step {self.step} "
                        f"loss={metrics.get('loss', float('nan')):.4f} "
                        f"acc={metrics.get('accuracy', 0.0):.3f} "
                        f"gnorm={metrics.get('grad_norm', 0.0):.2f}")
                if (self._mgr is not None and self.ckpt_every
                        and self.step % self.ckpt_every == 0):
                    self.save()
        self.save()
        return self.metrics_history


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
