"""Training substrate: optimizer, losses, train-step builder, trainer."""
from .loss import cross_entropy, lm_loss, IGNORE  # noqa: F401
from .optim import OptimConfig, apply_updates, init_state, lr_at  # noqa
from .train import (TrainConfig, Trainer, build_train_step,  # noqa: F401
                    init_train_state, train_state_axes)
