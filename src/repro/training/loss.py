"""Loss functions: next-token cross entropy with z-loss and MoE aux loss."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

IGNORE = -1  # label value excluded from the loss


def cross_entropy(logits: Array, labels: Array, *,
                  z_loss: float = 1e-4) -> tuple[Array, dict[str, Array]]:
    """Token-mean CE. logits: [B, S, V] (fp32), labels: [B, S] int32.

    z-loss (log^2 Z regularizer) keeps the softmax normalizer bounded in
    bf16 training — standard large-scale practice (PaLM / MaxText).
    """
    logits = logits.astype(jnp.float32)
    mask = (labels != IGNORE).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lz = jax.nn.logsumexp(logits, axis=-1)                      # [B, S]
    tgt = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lz - tgt) * mask
    zl = z_loss * jnp.square(lz) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll + zl).sum() / denom
    metrics = {
        "nll": nll.sum() / denom,
        "z_loss": zl.sum() / denom,
        "tokens": mask.sum(),
        "accuracy": ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom,
    }
    return loss, metrics


def lm_loss(logits: Array, labels: Array, aux: Optional[Array] = None,
            aux_weight: float = 1e-2, z_loss: float = 1e-4
            ) -> tuple[Array, dict[str, Array]]:
    loss, metrics = cross_entropy(logits, labels, z_loss=z_loss)
    if aux is not None:
        loss = loss + aux_weight * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = loss
    return loss, metrics
