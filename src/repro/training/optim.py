"""Optimizer substrate: AdamW with decoupled weight decay, global-norm
clipping, and warmup+cosine schedules. Self-contained (no optax) so the
whole update is visible to XLA as one fused pytree computation.

State layout mirrors the param pytree (m, v per leaf, fp32) plus a scalar
step — so the checkpoint manager and the sharding rules treat optimizer
state exactly like parameters (same logical axes).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class OptimConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def lr_at(cfg: OptimConfig, step: Array) -> Array:
    """Linear warmup then cosine decay to min_lr_frac * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def init_state(params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def state_axes(param_axes_tree: Any) -> dict:
    """Optimizer-state logical axes: m/v shard like their parameters."""
    return {"m": param_axes_tree,
            "v": jax.tree.map(lambda a: a, param_axes_tree),
            "step": ()}


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _is_matrix(p: Array) -> bool:
    return p.ndim >= 2  # decay only matrices (norms/biases/scalars exempt)


def apply_updates(params: Any, grads: Any, state: dict, cfg: OptimConfig,
                  compress: Optional[Callable[[Any], Any]] = None,
                  ) -> tuple[Any, dict, dict[str, Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if compress is not None:
        grads = compress(grads)
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    metrics = {"lr": lr, "grad_norm": gnorm,
               "param_norm": global_norm(new_params)}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
