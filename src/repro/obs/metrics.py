"""Metrics registry: counters / gauges / histograms with label sets.

The fleet's quantitative surface — :class:`~repro.cluster.fleet.FleetSimulator`,
:class:`~repro.cluster.slo.AdmissionController`,
:class:`~repro.core.costmodel.ContendedLinks` and the weight tuner all
publish into one :class:`MetricsRegistry` when observability is enabled
(``FleetSimulator(obs=True)``), and the result exports two ways:

  * :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
    format (``# HELP`` / ``# TYPE`` headers, label-set samples, histogram
    ``_bucket``/``_sum``/``_count`` expansion), scrape-ready;
  * :meth:`MetricsRegistry.snapshot` — a JSON-serializable dict, the
    machine-readable side consumed by ``scripts/report.py``.

:func:`parse_prometheus` is the matching strict parser (used by the CI
``obs_smoke`` stage to prove the export is well-formed — and by anyone who
wants samples back out of a ``.prom`` file without a Prometheus server).

Design constraints, inherited from the simulator's determinism contract:

  * publishing is observation only — no RNG, no floats fed back into any
    decision path, so metered runs stay bit-identical to unmetered ones;
  * label values are stringified on publish and label *names* are fixed at
    metric registration, so one metric's children always share a schema;
  * everything is plain Python dicts — cheap enough for per-frame counters
    on the simulator hot path, dependency-free by construction.
"""
from __future__ import annotations

import json
import math
import re
from typing import Optional, Sequence

#: default histogram buckets (seconds): spans sub-ms kernel latencies to
#: multi-second pipeline stalls; +Inf is implicit
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


class MetricsError(ValueError):
    """Raised on malformed metric registrations or exports."""


def _escape(value: str) -> str:
    """Prometheus label-value escaping: backslash, quote, newline."""
    return (value.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _unescape(value: str) -> str:
    return (value.replace("\\n", "\n").replace('\\"', '"')
            .replace("\\\\", "\\"))


class Metric:
    """One named metric: a family of children keyed by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        if not _NAME_RE.match(name):
            raise MetricsError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise MetricsError(f"invalid label name {ln!r} on {name}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        #: label-values tuple -> child state (float for counter/gauge)
        self.children: dict[tuple[str, ...], object] = {}

    def _key(self, labels: dict) -> tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise MetricsError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}")
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def _sample_name(self, key: tuple[str, ...]) -> str:
        if not key:
            return self.name
        inner = ",".join(f'{ln}="{_escape(v)}"'
                         for ln, v in zip(self.labelnames, key))
        return f"{self.name}{{{inner}}}"


class Counter(Metric):
    """Monotone counter; ``inc`` only."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise MetricsError(f"{self.name}: counters only increase")
        key = self._key(labels)
        self.children[key] = self.children.get(key, 0.0) + float(amount)

    def value(self, **labels) -> float:
        return float(self.children.get(self._key(labels), 0.0))


class Gauge(Metric):
    """Point-in-time value; ``set`` (and ``inc`` for convenience)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self.children[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        self.children[key] = self.children.get(key, 0.0) + float(amount)

    def value(self, **labels) -> float:
        return float(self.children.get(self._key(labels), 0.0))


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Optional[Sequence[float]] = None):
        super().__init__(name, help, labelnames)
        bs = tuple(float(b) for b in (buckets or DEFAULT_BUCKETS))
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise MetricsError(f"{name}: buckets must strictly increase")
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        st = self.children.get(key)
        if st is None:
            st = {"counts": [0] * len(self.buckets), "sum": 0.0, "count": 0}
            self.children[key] = st
        v = float(value)
        st["sum"] += v
        st["count"] += 1
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                st["counts"][i] += 1


class MetricsRegistry:
    """Get-or-create metric store with Prometheus / JSON export."""

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    def _get(self, cls, name: str, help: str,
             labelnames: Sequence[str], **kw) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls or m.labelnames != tuple(labelnames):
                raise MetricsError(
                    f"{name} already registered as {m.kind} with labels "
                    f"{m.labelnames}")
            return m
        m = cls(name, help, labelnames, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def __iter__(self):
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    # ------------------------------------------------------------- export
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {m.name} {_escape(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key in sorted(m.children):
                st = m.children[key]
                if isinstance(m, Histogram):
                    cum = 0
                    for ub, c in zip(m.buckets, st["counts"]):
                        cum += c
                        le = format(ub, "g")
                        k2 = key + (le,)
                        ln2 = m.labelnames + ("le",)
                        inner = ",".join(
                            f'{ln}="{_escape(v)}"'
                            for ln, v in zip(ln2, k2))
                        lines.append(
                            f"{m.name}_bucket{{{inner}}} {cum}")
                    inner = ",".join(
                        f'{ln}="{_escape(v)}"'
                        for ln, v in zip(m.labelnames + ("le",),
                                         key + ("+Inf",)))
                    lines.append(
                        f"{m.name}_bucket{{{inner}}} {st['count']}")
                    suffix = m._sample_name(key)
                    base, _, rest = suffix.partition("{")
                    tail = ("{" + rest) if rest else ""
                    lines.append(f"{base}_sum{tail} {format(st['sum'], 'g')}")
                    lines.append(f"{base}_count{tail} {st['count']}")
                else:
                    lines.append(
                        f"{m._sample_name(key)} {format(st, 'g')}")
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> dict:
        """JSON-serializable dump: {metric: {type, help, labels, samples}}."""
        out: dict[str, dict] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            samples = []
            for key in sorted(m.children):
                st = m.children[key]
                labels = dict(zip(m.labelnames, key))
                if isinstance(m, Histogram):
                    samples.append({"labels": labels, "sum": st["sum"],
                                    "count": st["count"],
                                    "buckets": dict(zip(
                                        (format(b, "g") for b in m.buckets),
                                        st["counts"]))})
                else:
                    samples.append({"labels": labels, "value": st})
            out[name] = {"type": m.kind, "help": m.help,
                         "labelnames": list(m.labelnames),
                         "samples": samples}
        return out

    def dump_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
            f.write("\n")


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)$")
_LABEL_PAIR_RE = re.compile(
    r'(?P<name>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<value>(?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> list[dict]:
    """Strict parser for the text exposition format; returns one
    ``{"name", "labels", "value"}`` dict per sample and raises
    :class:`MetricsError` on any malformed line — the CI smoke's proof
    that :meth:`MetricsRegistry.to_prometheus` emits valid exposition."""
    samples: list[dict] = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise MetricsError(f"line {lineno}: bad comment {raw!r}")
            if parts[1] == "TYPE" and (
                    len(parts) < 4 or parts[3] not in (
                        "counter", "gauge", "histogram", "summary",
                        "untyped")):
                raise MetricsError(f"line {lineno}: bad TYPE {raw!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise MetricsError(f"line {lineno}: unparsable sample {raw!r}")
        labels: dict[str, str] = {}
        body = m.group("labels")
        if body:
            consumed = 0
            for pair in _LABEL_PAIR_RE.finditer(body):
                labels[pair.group("name")] = _unescape(pair.group("value"))
                consumed = pair.end()
                if consumed < len(body) and body[consumed] == ",":
                    consumed += 1
            if consumed < len(body):
                raise MetricsError(
                    f"line {lineno}: bad label body {body!r}")
        try:
            value = float(m.group("value"))
        except ValueError as e:
            raise MetricsError(f"line {lineno}: bad value {raw!r}") from e
        if math.isnan(value):
            raise MetricsError(f"line {lineno}: NaN sample {raw!r}")
        samples.append({"name": m.group("name"), "labels": labels,
                        "value": value})
    return samples
