"""Observability subsystem: structured tracing, metrics, hot-path profiling.

One :class:`Obs` bundle travels through the stack — pass ``obs=True`` to
:class:`repro.cluster.fleet.FleetSimulator` (or build an :class:`Obs`
yourself for finer control) and every layer lights up:

  * :class:`~repro.obs.spans.SpanTracer` — deterministic span-based
    tracing of jobs, placements, admissions, transfers (JSONL export,
    critical-path extraction via :func:`~repro.obs.spans.critical_path`);
  * :class:`~repro.obs.metrics.MetricsRegistry` — labeled counters /
    gauges / histograms published by the fleet, admission controller,
    contended links and tuner (Prometheus text + JSON snapshot export);
  * :class:`~repro.obs.profiler.HotLoopProfiler` — per-event-kind
    wall-time accounting on the simulator hot loop.

The contract every hook honors: **off costs nothing, on changes
nothing**.  Disabled observability adds only ``is not None`` checks on
attributes that are ``None``; enabled observability consumes no RNG and
feeds no value back into any decision path, so traced/metered runs are
bit-identical to bare ones in UXCost and placements.  Both halves are
asserted by ``tests/test_obs.py`` and the CI ``obs_smoke`` stage.
"""
from __future__ import annotations

import json
import os
from typing import Optional, Union

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsError,
                               MetricsRegistry, parse_prometheus)
from repro.obs.profiler import HotLoopProfiler
from repro.obs.spans import (SpanError, SpanTracer, critical_path,
                             load_jsonl, pipeline_tails, validate_span)

__all__ = [
    "Obs", "SpanTracer", "MetricsRegistry", "HotLoopProfiler",
    "Counter", "Gauge", "Histogram",
    "critical_path", "pipeline_tails", "validate_span", "load_jsonl",
    "parse_prometheus", "SpanError", "MetricsError",
]


class Obs:
    """Bundle of the three observability facilities, each optional.

    Attributes are ``None`` when the facility is off — instrumented call
    sites guard on that, which is the whole zero-overhead story.
    """

    def __init__(self, tracer: Optional[SpanTracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 profiler: Optional[HotLoopProfiler] = None):
        self.tracer = tracer
        self.metrics = metrics
        self.profiler = profiler

    @classmethod
    def make(cls, arg: Union[None, bool, dict, "Obs"]) -> Optional["Obs"]:
        """Normalize the ``obs=`` constructor argument.

        ``None``/``False`` → ``None`` (fully off); ``True`` → all three
        facilities; a dict like ``{"spans": True, "metrics": True,
        "profile": False}`` → selective; an :class:`Obs` instance →
        itself (sharing one bundle across runs is allowed — e.g. one
        registry scraped across a sweep).
        """
        if arg is None or arg is False:
            return None
        if isinstance(arg, Obs):
            return arg
        if arg is True:
            return cls(SpanTracer(), MetricsRegistry(), HotLoopProfiler())
        if isinstance(arg, dict):
            return cls(
                tracer=SpanTracer() if arg.get("spans", True) else None,
                metrics=MetricsRegistry() if arg.get("metrics", True)
                else None,
                profiler=HotLoopProfiler() if arg.get("profile", True)
                else None)
        raise TypeError(f"obs must be bool/dict/Obs/None, got {arg!r}")

    def export(self, out_dir: str) -> dict[str, str]:
        """Write every enabled facility's artifact into ``out_dir``:
        ``spans.jsonl``, ``metrics.prom``, ``metrics.json``,
        ``profile.json``.  Returns {artifact-name: path} for what was
        written."""
        os.makedirs(out_dir, exist_ok=True)
        written: dict[str, str] = {}
        if self.tracer is not None:
            p = os.path.join(out_dir, "spans.jsonl")
            self.tracer.dump_jsonl(p)
            written["spans"] = p
        if self.metrics is not None:
            p = os.path.join(out_dir, "metrics.prom")
            with open(p, "w") as f:
                f.write(self.metrics.to_prometheus())
            written["metrics_prom"] = p
            p = os.path.join(out_dir, "metrics.json")
            self.metrics.dump_json(p)
            written["metrics_json"] = p
        if self.profiler is not None:
            p = os.path.join(out_dir, "profile.json")
            with open(p, "w") as f:
                json.dump(self.profiler.snapshot(), f, indent=1,
                          sort_keys=True)
                f.write("\n")
            written["profile"] = p
        return written
