"""Render a run's spans + metrics + profile into a terminal/markdown report.

Pure functions from exported observability artifacts (the files
:meth:`repro.obs.Obs.export` writes) to text — the engine behind
``scripts/report.py``.  Each section degrades gracefully when its input
is absent, so a spans-only or metrics-only run still renders.

Sections:

  * :func:`render_timeline` — fleet event timeline (placements, churn,
    admissions, migrations, controller ticks) in sim-time order;
  * :func:`render_tier_dlv` — per-SLO-tier frames / deadline-violation
    breakdown read from the metrics snapshot;
  * :func:`render_pressure` — pressure-law term attribution for every
    degrade / reject decision (which term tripped the threshold);
  * :func:`render_critical_paths` — the N slowest completed pipelines,
    each explained as queue/exec/stall/transfer/handoff segments via
    :func:`repro.obs.spans.critical_path`;
  * :func:`render_profile` — the hot-loop "where the wall-clock goes"
    table.
"""
from __future__ import annotations

from typing import Optional

from repro.obs.spans import critical_path, pipeline_tails

#: span kinds shown on the fleet timeline (job spans are too many; they
#: surface through the critical-path section instead)
_TIMELINE_KINDS = ("node_join", "node_leave", "node_drain", "rejoin",
                   "stream", "depart", "place", "migrate", "admit",
                   "reject", "swap", "tune", "slo_tick", "xfer")


def _fmt_attrs(attrs: dict, keys: tuple[str, ...]) -> str:
    parts = []
    for k in keys:
        if k in attrs and attrs[k] is not None:
            v = attrs[k]
            parts.append(f"{k}={v:.4g}" if isinstance(v, float)
                         else f"{k}={v}")
    return " ".join(parts)


def render_timeline(records: list[dict], max_rows: int = 60) -> str:
    """Sim-time-ordered fleet event timeline (markdown table)."""
    rows = [r for r in records if r["kind"] in _TIMELINE_KINDS]
    rows.sort(key=lambda r: (r["t0"], r["sid"]))
    clipped = len(rows) - max_rows
    if clipped > 0:
        # keep an even spread rather than only the head of the run
        stride = len(rows) / max_rows
        rows = [rows[int(i * stride)] for i in range(max_rows)]
    lines = ["| t (s) | event | detail |", "|---|---|---|"]
    for r in rows:
        a = r["attrs"]
        detail = _fmt_attrs(a, ("node", "stream", "model", "tier",
                                "level", "verdict", "pressure", "src",
                                "dst", "xfer_s", "xfer_j", "uxcost"))
        t = (f"{r['t0']:.3f}" if r["t0"] == r["t1"]
             else f"{r['t0']:.3f}–{r['t1']:.3f}")
        lines.append(f"| {t} | {r['kind']} | {detail} |")
    if clipped > 0:
        lines.append(f"\n*({clipped} events elided — evenly sampled)*")
    return "\n".join(lines)


def render_tier_dlv(metrics_snapshot: dict) -> str:
    """Per-tier frames / violation table from the metrics snapshot."""
    frames = metrics_snapshot.get("fleet_tier_frames_total", {})
    dlv = metrics_snapshot.get("fleet_tier_dlv_rate", {})
    by_tier: dict[str, dict] = {}
    for s in frames.get("samples", ()):
        by_tier.setdefault(s["labels"].get("tier", "?"), {})["frames"] = \
            s["value"]
    for s in dlv.get("samples", ()):
        by_tier.setdefault(s["labels"].get("tier", "?"), {})["dlv"] = \
            s["value"]
    if not by_tier:
        return "*(no per-tier metrics in snapshot)*"
    lines = ["| tier | frames | DLV rate |", "|---|---|---|"]
    for tier in sorted(by_tier):
        row = by_tier[tier]
        lines.append(f"| {tier} | {row.get('frames', 0):.0f} "
                     f"| {row.get('dlv', 0.0):.4f} |")
    return "\n".join(lines)


def render_pressure(records: list[dict], max_rows: int = 40) -> str:
    """Pressure-law term attribution for degrade / reject decisions.

    Each admission verdict span carries the controller's ``terms`` dict
    (util / forecast / dlv / backlog / latency contributions summing to
    the pressure P).  The dominant term is flagged — that's the *why*
    behind every shed decision.
    """
    rows = [r for r in records
            if r["kind"] in ("reject", "swap", "admit")
            and r["attrs"].get("terms")]
    rows.sort(key=lambda r: (r["t0"], r["sid"]))
    if not rows:
        return "*(no admission/degrade decisions with pressure terms)*"
    shown = rows[:max_rows]
    lines = ["| t (s) | action | target | P | dominant term | terms |",
             "|---|---|---|---|---|---|"]
    for r in shown:
        a = r["attrs"]
        terms = a["terms"]
        dom = max(terms, key=lambda k: terms[k]) if terms else "-"
        tstr = " ".join(f"{k}={v:.3f}" for k, v in sorted(terms.items()))
        target = a.get("stream", a.get("model", ""))
        lines.append(
            f"| {r['t0']:.3f} | {r['kind']} | {target} "
            f"| {a.get('pressure', 0.0):.3f} "
            f"| {dom}={terms.get(dom, 0.0):.3f} | {tstr} |")
    if len(rows) > len(shown):
        lines.append(f"\n*({len(rows) - len(shown)} more decisions "
                     "elided)*")
    return "\n".join(lines)


def render_critical_paths(records: list[dict], n: int = 3) -> str:
    """The ``n`` slowest completed pipelines, segment by segment."""
    tails = pipeline_tails(records)
    if not tails:
        return "*(no completed pipelines in span records)*"
    scored = sorted(
        tails, key=lambda r: r["t1"] - float(
            r["attrs"].get("origin", r["t0"])), reverse=True)[:n]
    out = []
    for rank, tail in enumerate(scored, 1):
        cp = critical_path(records, tail_uid=tail["attrs"]["uid"])
        head = f"**#{rank} pipeline → {tail['attrs']['uid']}** " \
               f"(model {tail['attrs'].get('model', '?')}): " \
               f"{cp['total_s'] * 1e3:.2f} ms over {len(cp['chain'])} " \
               f"job(s)"
        segs = " + ".join(
            f"{name} {cp['by_seg'][name] * 1e3:.2f}ms"
            for name in sorted(cp["by_seg"],
                               key=lambda k: -cp["by_seg"][k]))
        chain = " → ".join(cp["chain"])
        out.append(f"{head}\n- segments: {segs}\n- chain: {chain}")
    return "\n\n".join(out)


def render_profile(profile_snapshot: dict, n: int = 12) -> str:
    """Hot-loop wall-time table from a profiler snapshot."""
    keys = profile_snapshot.get("keys", {})
    if not keys:
        return "*(no profile samples)*"
    rows = sorted(keys.items(), key=lambda kv: -kv[1]["wall_s"])[:n]
    metered = sum(v["wall_s"] for v in keys.values())
    lines = ["| key | wall (s) | calls | us/call | share |",
             "|---|---|---|---|---|"]
    for key, v in rows:
        c = v["count"]
        us = v["wall_s"] / c * 1e6 if c else 0.0
        share = v["wall_s"] / metered if metered else 0.0
        lines.append(f"| {key} | {v['wall_s']:.4f} | {c} "
                     f"| {us:.1f} | {share:.1%} |")
    total = profile_snapshot.get("total_wall_s", 0.0)
    if total:
        lines.append(f"\n*metered {metered:.4f}s of {total:.4f}s run "
                     "wall-clock*")
    return "\n".join(lines)


def render_report(records: Optional[list[dict]] = None,
                  metrics_snapshot: Optional[dict] = None,
                  profile_snapshot: Optional[dict] = None,
                  title: str = "Run report",
                  n_paths: int = 3,
                  timeline_rows: int = 60) -> str:
    """Full markdown report from whichever artifacts are present."""
    parts = [f"# {title}"]
    if records:
        parts.append("## Fleet timeline\n\n"
                     + render_timeline(records, max_rows=timeline_rows))
        parts.append("## Slowest pipelines (critical paths)\n\n"
                     + render_critical_paths(records, n=n_paths))
        parts.append("## Pressure-law attribution\n\n"
                     + render_pressure(records))
    if metrics_snapshot:
        parts.append("## Per-tier DLV\n\n"
                     + render_tier_dlv(metrics_snapshot))
    if profile_snapshot:
        parts.append("## Hot-loop profile\n\n"
                     + render_profile(profile_snapshot))
    return "\n\n".join(parts) + "\n"
