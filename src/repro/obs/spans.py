"""Span-based structured tracing with a pipeline critical-path extractor.

A *span* is one named interval of simulated time with attributes:
``{"sid": int, "kind": str, "t0": float, "t1": float, "attrs": {...}}``.
:class:`SpanTracer` hands out span IDs from a plain counter — never from
wall clocks or RNG — so a traced run's span stream is a pure function of
the simulated execution and traced runs stay replay-bit-exact (the same
guarantee :mod:`repro.core.trace` relies on).  The simulator and fleet
open/close spans at the event sites that matter:

  ====================  =================================================
  kind                  opened / closed at
  ====================  =================================================
  ``job``               node job lifecycle: created at enqueue, closed at
                        complete / drop / purge, carrying queue+exec
                        segments, energy, deadline outcome, parent link
  ``xfer``              cross-node cascade handoff riding a contended
                        link (wire-time interval, bytes, joules)
  ``place``/``migrate`` router placement decisions and live migrations
  ``admit``/``reject``  admission verdicts with pressure-term breakdown
  ``swap``              SLO supernet-variant ladder moves
  ``stream``/``depart`` stream lifecycle; ``node_join``/``node_leave``/
                        ``node_drain``/``rejoin`` fleet churn
  ``tune``/``slo_tick`` controller windows (weights, pressure terms)
  ====================  =================================================

Spans serialize as JSONL (:meth:`SpanTracer.dump_jsonl`), one record per
line, schema-checked by :func:`validate_span`.

:func:`critical_path` is the *why* tool: given a frame-pipeline's tail
job span it walks the parent chain back to the head arrival and explains
the whole head-to-tail latency as a sum of named segments —
``queue`` (enqueue→first dispatch), ``exec`` (dispatch blocks),
``stall`` (gaps between a job's exec blocks), ``transfer`` (cross-node
wire time) and ``handoff_wait`` (trigger→inject residue).  The segment
sums telescope: they reconcile exactly with the recorded
``overall_pipeline_latency`` contribution (``t_done - origin``) of that
frame, which the obs test-suite asserts on whole-model, stage-split and
SLO-overload runs.
"""
from __future__ import annotations

import itertools
import json
from typing import Iterable, Optional

_REQUIRED_KEYS = ("sid", "kind", "t0", "t1", "attrs")


class SpanError(ValueError):
    """Raised on malformed span records."""


def validate_span(rec: dict) -> dict:
    """Schema-check one span record; returns it unchanged or raises
    :class:`SpanError`.  Used by the CI ``obs_smoke`` stage on every line
    of an emitted span file."""
    if not isinstance(rec, dict):
        raise SpanError(f"span must be a dict, got {type(rec).__name__}")
    missing = [k for k in _REQUIRED_KEYS if k not in rec]
    if missing:
        raise SpanError(f"span missing keys {missing}: {rec!r}")
    if not isinstance(rec["sid"], int):
        raise SpanError(f"span sid must be int: {rec!r}")
    if not isinstance(rec["kind"], str) or not rec["kind"]:
        raise SpanError(f"span kind must be non-empty str: {rec!r}")
    for k in ("t0", "t1"):
        if not isinstance(rec[k], (int, float)):
            raise SpanError(f"span {k} must be numeric: {rec!r}")
    if rec["t1"] < rec["t0"]:
        raise SpanError(f"span ends before it starts: {rec!r}")
    if not isinstance(rec["attrs"], dict):
        raise SpanError(f"span attrs must be a dict: {rec!r}")
    return rec


class SpanTracer:
    """Deterministic span recorder.

    IDs come from :func:`itertools.count` — creation order *is* identity,
    so two bit-identical runs emit bit-identical span streams.  ``open``
    returns the span id; ``close`` stamps the end time and merges final
    attributes; ``event`` records an instantaneous span (``t0 == t1``);
    ``span`` records an interval known up front (e.g. a wire transfer).
    Unclosed spans are finalized by :meth:`finish` with
    ``outcome="unfinished"`` so the JSONL is always complete.
    """

    def __init__(self):
        self._ids = itertools.count()
        #: closed spans in close order (dicts per the module schema)
        self.records: list[dict] = []
        #: open spans: sid -> record-in-progress
        self._open: dict[int, dict] = {}

    def __len__(self) -> int:
        return len(self.records) + len(self._open)

    # ------------------------------------------------------------ recording
    def open(self, kind: str, t: float, **attrs) -> int:
        sid = next(self._ids)
        self._open[sid] = {"sid": sid, "kind": kind, "t0": float(t),
                           "t1": float(t), "attrs": dict(attrs)}
        return sid

    def close(self, sid: int, t: float, **attrs) -> None:
        rec = self._open.pop(sid, None)
        if rec is None:
            raise SpanError(f"close of unknown/closed span {sid}")
        rec["t1"] = float(t)
        rec["attrs"].update(attrs)
        self.records.append(rec)

    def event(self, kind: str, t: float, **attrs) -> int:
        """Instantaneous span (t0 == t1): a decision point, not a wait."""
        sid = next(self._ids)
        self.records.append({"sid": sid, "kind": kind, "t0": float(t),
                             "t1": float(t), "attrs": dict(attrs)})
        return sid

    def span(self, kind: str, t0: float, t1: float, **attrs) -> int:
        """Record an interval whose extent is already known."""
        sid = next(self._ids)
        self.records.append({"sid": sid, "kind": kind, "t0": float(t0),
                             "t1": float(t1), "attrs": dict(attrs)})
        return sid

    def finish(self, t: float) -> None:
        """Close any still-open spans at ``t`` with outcome=unfinished."""
        for sid in sorted(self._open):
            rec = self._open.pop(sid)
            rec["t1"] = max(float(t), rec["t0"])
            rec["attrs"].setdefault("outcome", "unfinished")
            self.records.append(rec)

    # ------------------------------------------------------------- export
    def to_records(self) -> list[dict]:
        """All closed spans, sorted by (t0, sid) for stable replay diffs."""
        return sorted(self.records, key=lambda r: (r["t0"], r["sid"]))

    def dump_jsonl(self, path: str) -> int:
        """Write one JSON object per line; returns the record count."""
        recs = self.to_records()
        with open(path, "w") as f:
            for rec in recs:
                f.write(json.dumps(validate_span(rec), sort_keys=True))
                f.write("\n")
        return len(recs)


def load_jsonl(path: str) -> list[dict]:
    """Read and validate a span JSONL file."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(validate_span(json.loads(line)))
    return out


# ---------------------------------------------------------------- critical path

def _job_segments(rec: dict) -> list[dict]:
    """Decompose one job span into queue / exec / stall segments.

    ``attrs.segs`` is the list of ``[t_dispatch, t_done]`` execution
    blocks the simulator recorded (a job dispatches once per path
    position).  Everything between enqueue and the first dispatch is
    ``queue``; gaps between blocks are ``stall`` (the accelerator ran
    other jobs in between); the blocks themselves are ``exec``.  The
    segments tile [t0, t1] exactly, so their durations always sum to the
    span extent.
    """
    segs: list[dict] = []
    cursor = rec["t0"]
    blocks = rec["attrs"].get("segs") or []
    for i, (b0, b1) in enumerate(blocks):
        if b0 > cursor:
            segs.append({"seg": "queue" if i == 0 else "stall",
                         "t0": cursor, "t1": b0})
        segs.append({"seg": "exec", "t0": b0, "t1": b1})
        cursor = b1
    if rec["t1"] > cursor:
        # closed after the last block finished (drop/purge tail residue)
        segs.append({"seg": "stall" if blocks else "queue",
                     "t0": cursor, "t1": rec["t1"]})
    return segs


def critical_path(records: Iterable[dict],
                  tail_uid: Optional[str] = None) -> dict:
    """Explain one pipeline's head-to-tail latency as named segments.

    Picks the tail job span (``attrs.tail`` true, ``outcome == "done"``;
    or the one with ``attrs.uid == tail_uid``), walks ``attrs.parent``
    links back to the head job, and splices per-job queue/exec/stall
    segments with inter-job ``transfer`` + ``handoff_wait`` edges.  The
    returned dict has:

      * ``segments`` — list of ``{"seg", "t0", "t1", "uid"}`` tiling
        ``[origin, t_done]`` with no gaps or overlaps;
      * ``by_seg`` — summed seconds per segment name;
      * ``total_s`` — ``t_done - origin``, which equals the sum of all
        segment durations (the reconciliation invariant) and matches this
        frame's contribution to ``overall_pipeline_latency``;
      * ``chain`` — the job uids head→tail.

    When the head job's enqueue time sits after the recorded ``origin``
    (a cascade trigger fired mid-frame), the leading gap is labeled
    ``handoff_wait`` so the telescoping still covers the full interval.
    """
    jobs = {r["attrs"]["uid"]: r for r in records
            if r["kind"] == "job" and "uid" in r["attrs"]}
    if tail_uid is not None:
        tail = jobs.get(tail_uid)
        if tail is None:
            raise SpanError(f"no job span with uid {tail_uid!r}")
    else:
        done_tails = [r for r in jobs.values()
                      if r["attrs"].get("tail")
                      and r["attrs"].get("outcome") == "done"]
        if not done_tails:
            raise SpanError("no completed tail job span in records")
        # latest-finishing tail = the frame most likely being asked about
        tail = max(done_tails, key=lambda r: (r["t1"], r["sid"]))

    chain = [tail]
    seen = {tail["attrs"]["uid"]}
    while True:
        parent = chain[-1]["attrs"].get("parent")
        if parent is None or parent not in jobs or parent in seen:
            break
        chain.append(jobs[parent])
        seen.add(parent)
    chain.reverse()  # head first

    origin = float(chain[0]["attrs"].get("origin", chain[0]["t0"]))
    segments: list[dict] = []
    cursor = origin
    for i, rec in enumerate(chain):
        uid = rec["attrs"]["uid"]
        if rec["t0"] > cursor:
            gap_t0, gap_t1 = cursor, rec["t0"]
            if i > 0:
                # split the inter-job edge: wire time first, residue waits
                xfer_s = min(float(rec["attrs"].get("xfer_s", 0.0)),
                             gap_t1 - gap_t0)
                if xfer_s > 0.0:
                    segments.append({"seg": "transfer", "t0": gap_t0,
                                     "t1": gap_t0 + xfer_s, "uid": uid})
                    gap_t0 += xfer_s
            if gap_t1 > gap_t0:
                segments.append({"seg": "handoff_wait", "t0": gap_t0,
                                 "t1": gap_t1, "uid": uid})
            cursor = rec["t0"]
        for seg in _job_segments(rec):
            if seg["t1"] <= cursor:
                continue  # overlapped by a later-chain start (clamped)
            segments.append({**seg, "t0": max(seg["t0"], cursor),
                             "uid": uid})
            cursor = segments[-1]["t1"]

    by_seg: dict[str, float] = {}
    for seg in segments:
        by_seg[seg["seg"]] = by_seg.get(seg["seg"], 0.0) \
            + (seg["t1"] - seg["t0"])
    return {"segments": segments, "by_seg": by_seg,
            "total_s": cursor - origin,
            "t0": origin, "t1": cursor,
            "chain": [r["attrs"]["uid"] for r in chain]}


def pipeline_tails(records: Iterable[dict]) -> list[dict]:
    """All completed tail job spans, ordered by finish time — the
    per-frame entry points for :func:`critical_path`."""
    return sorted((r for r in records
                   if r["kind"] == "job" and r["attrs"].get("tail")
                   and r["attrs"].get("outcome") == "done"),
                  key=lambda r: (r["t1"], r["sid"]))
