"""Hot-loop profiler: per-event-kind wall-time and count accounting.

The simulator's hot path is ``Simulator.step`` → ``_process_event`` →
``_drain_schedule``; the fleet adds its own handler dispatch on top.
:class:`HotLoopProfiler` meters both with two ``time.perf_counter`` reads
per block — and costs *nothing* when disabled, because the instrumented
call sites guard with ``if profiler is not None`` (no wrapper objects, no
no-op calls on the disabled path).  This is the ROADMAP "raw speed"
measurement baseline: before vectorizing the fleet hot path one needs to
know where the wall-clock actually goes, and after, one needs
``streams_per_wall_s`` to prove the win.

Wall-clock readings are *host-side* observations: they never touch
simulated time, RNG, or any scheduling decision, so profiling preserves
bit-exact results by construction (asserted by the obs test-suite).

Keys are free-form strings; the convention is ``node.<event>`` for
per-node simulator events (``arrival``/``done``/``window``/``phase``/
``inject``/``drain``) and ``fleet.<event>`` for fleet-level handlers
(``stream``/``place``/``tune``/``slo``/...).
"""
from __future__ import annotations

import time
from typing import Optional


class HotLoopProfiler:
    """Accumulates wall seconds and call counts per key.

    Usage at an instrumented site (hot path — keep the guard inline)::

        if prof is not None:
            _w0 = prof.t0()
        handler(...)
        if prof is not None:
            prof.add("fleet.stream", _w0)
    """

    def __init__(self):
        self.wall_s: dict[str, float] = {}
        self.counts: dict[str, int] = {}
        self._run_t0: Optional[float] = None
        self.total_wall_s = 0.0

    # ------------------------------------------------------------ metering
    @staticmethod
    def t0() -> float:
        return time.perf_counter()

    def add(self, key: str, t0: float) -> None:
        dt = time.perf_counter() - t0
        self.wall_s[key] = self.wall_s.get(key, 0.0) + dt
        self.counts[key] = self.counts.get(key, 0) + 1

    def start_run(self) -> None:
        """Mark the start of the overall run window (idempotent)."""
        if self._run_t0 is None:
            self._run_t0 = time.perf_counter()

    def stop_run(self) -> None:
        """Close the overall run window; accumulates across start/stop."""
        if self._run_t0 is not None:
            self.total_wall_s += time.perf_counter() - self._run_t0
            self._run_t0 = None

    # ------------------------------------------------------------ results
    def streams_per_wall_s(self, stream_seconds: float) -> float:
        """Simulated stream-seconds advanced per wall-clock second —
        the throughput figure of merit for the vectorization work
        (0.0 when no wall window was recorded)."""
        return stream_seconds / self.total_wall_s if self.total_wall_s \
            else 0.0

    def top(self, n: int = 10) -> list[tuple[str, float, int]]:
        """Top-``n`` keys by accumulated wall time:
        ``(key, wall_s, count)``."""
        rows = sorted(self.wall_s.items(), key=lambda kv: -kv[1])[:n]
        return [(k, w, self.counts.get(k, 0)) for k, w in rows]

    def table(self, n: int = 10) -> str:
        """Human-readable "where the wall-clock goes" table."""
        rows = self.top(n)
        if not rows:
            return "(no profile samples)"
        metered = sum(self.wall_s.values())
        lines = [f"{'key':<24} {'wall_s':>10} {'count':>9} "
                 f"{'us/call':>9} {'share':>7}"]
        for key, wall, count in rows:
            us = wall / count * 1e6 if count else 0.0
            share = wall / metered if metered else 0.0
            lines.append(f"{key:<24} {wall:>10.4f} {count:>9d} "
                         f"{us:>9.1f} {share:>6.1%}")
        lines.append(f"{'(metered total)':<24} {metered:>10.4f}"
                     + (f"   of {self.total_wall_s:.4f}s run wall"
                        if self.total_wall_s else ""))
        return "\n".join(lines)

    def snapshot(self) -> dict:
        """JSON-serializable dump for artifacts / ``scripts/report.py``."""
        return {
            "total_wall_s": self.total_wall_s,
            "keys": {k: {"wall_s": self.wall_s[k],
                         "count": self.counts.get(k, 0)}
                     for k in sorted(self.wall_s)},
        }
