"""SLO subsystem: tiered admission control and graceful degradation.

Overload is a *managed regime*, not a divergence.  Every stream carries an
:class:`SLOClass` — a service tier with a pipeline-latency budget and a
priority.  A fleet-level :class:`AdmissionController` sits in front of the
router and, from windowed telemetry plus a short-horizon load estimate,
decides for each arriving stream whether to **admit** it at full quality,
**degrade** it onto a cheaper supernet variant (the middle rung), or
**reject** it outright (a first-class outcome with its own UXCost charge —
never a silent drop).  Once streams are placed, a periodic controller tick
walks the same pressure signal through a *degradation ladder*: under
sustained pressure it swaps best-effort streams one supernet-variant level
lighter, and when pressure falls below a hysteresis band it promotes them
back.

The admission law (documented in ``docs/scheduling.md``) is a single scalar
pressure::

    P(t) = max(U(t), Uhat(t)) + w_dlv * max_n DLV_n
         + w_bklg * min(B_p90 / B0, 1) + w_lat * min(max(L/L0 - 1, 0), 1)

where ``U`` is the mean offered utilization over candidate nodes *now*,
``Uhat`` the :class:`LoadEstimator`'s short-horizon forecast (EMA level +
trend, Sparse-DySta-style: act *ahead* of saturation), ``DLV_n`` the worst
per-node deadline-violation rate of the last telemetry window, ``B_p90``
the fleet backlog p90, and ``L/L0`` the mean pipeline latency over the mean
declared budget.  Three thresholds partition the regimes::

    P < t_promote                : promote degraded streams (one level/tick)
    t_promote <= P < t_degrade   : hold (hysteresis band -- no flapping)
    t_degrade <= P < t_reject    : degrade-first (admit new non-tier-0
                                   streams one variant level down; ladder
                                   pushes placed best-effort streams deeper)
    P >= t_reject                : best-effort arrivals are rejected

Tier-0 ("guaranteed") streams are never degraded or rejected.  The
controller is deterministic — no RNG — so live decisions can be recorded as
``swap`` / ``reject`` trace records and replay bypasses it bit-exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union


class SLOError(ValueError):
    """Raised when an SLO declaration is inconsistent."""


#: Canonical tier numbers.
TIER_GUARANTEED = 0
TIER_STANDARD = 1
TIER_BEST_EFFORT = 2


@dataclass(frozen=True)
class SLOClass:
    """A service tier: latency budget (in head periods) plus priority.

    ``budget_factor`` scales the stream's head period into an end-to-end
    pipeline-latency budget (``budget_s = budget_factor / head_fps``);
    ``priority`` orders streams within a tier when the degradation ladder
    must pick victims (lower priority degrades first).
    """

    tier: int
    budget_factor: float
    priority: float

    def __post_init__(self):
        if self.tier not in TIER_DEFAULTS_SPEC:
            raise SLOError(f"unknown SLO tier {self.tier!r}; expected one of "
                           f"{sorted(TIER_DEFAULTS_SPEC)}")
        if not self.budget_factor > 0:
            raise SLOError(f"budget_factor must be positive, "
                           f"got {self.budget_factor}")
        if not self.priority > 0:
            raise SLOError(f"priority must be positive, got {self.priority}")

    def to_config(self) -> dict:
        """Minimal JSON form: a bare tier number when the tier's defaults
        apply, else the full dict (keeps trace records compact)."""
        if self == TIER_DEFAULTS[self.tier]:
            return {"tier": self.tier}
        return {"tier": self.tier, "budget_factor": self.budget_factor,
                "priority": self.priority}


#: Per-tier (budget_factor, priority) defaults; tier 1 is the legacy
#: default every pre-SLO trace and tierless stream maps onto.
TIER_DEFAULTS_SPEC = {
    TIER_GUARANTEED: (1.0, 4.0),
    TIER_STANDARD: (2.0, 2.0),
    TIER_BEST_EFFORT: (4.0, 1.0),
}
TIER_DEFAULTS = {t: SLOClass(t, bf, pr)
                 for t, (bf, pr) in TIER_DEFAULTS_SPEC.items()}
#: Legacy default: streams with no declared SLO are tier-1 "standard".
DEFAULT_SLO = TIER_DEFAULTS[TIER_STANDARD]


def slo_from_config(cfg: Union[int, dict, SLOClass, None]) -> SLOClass:
    """Normalize an SLO declaration: ``None`` -> the legacy default tier,
    a bare int -> that tier's defaults, a dict -> explicit class."""
    if cfg is None:
        return DEFAULT_SLO
    if isinstance(cfg, SLOClass):
        return cfg
    if isinstance(cfg, int) and not isinstance(cfg, bool):
        if cfg not in TIER_DEFAULTS:
            raise SLOError(f"unknown SLO tier {cfg!r}; expected one of "
                           f"{sorted(TIER_DEFAULTS)}")
        return TIER_DEFAULTS[cfg]
    if isinstance(cfg, dict):
        tier = cfg.get("tier")
        if not isinstance(tier, int) or isinstance(tier, bool):
            raise SLOError(f"SLO config needs an integer 'tier', got {cfg!r}")
        base = slo_from_config(tier)
        return SLOClass(tier=tier,
                        budget_factor=float(cfg.get("budget_factor",
                                                    base.budget_factor)),
                        priority=float(cfg.get("priority", base.priority)))
    raise SLOError(f"cannot interpret SLO declaration {cfg!r}")


class LoadEstimator:
    """Short-horizon fleet-load forecast: EMA level + EMA trend.

    Observed once per controller window with the mean offered utilization;
    ``predict()`` extrapolates ``horizon`` windows ahead so the admission
    gate reacts *before* the fleet saturates rather than after.  Purely
    deterministic (no RNG) — replay never consults it.
    """

    def __init__(self, alpha: float = 0.5, horizon: float = 2.0):
        self.alpha = float(alpha)
        self.horizon = float(horizon)
        self.level: Optional[float] = None
        self.trend = 0.0

    def observe(self, util: float) -> None:
        if self.level is None:
            self.level = float(util)
            return
        prev = self.level
        self.level = (1.0 - self.alpha) * self.level + self.alpha * float(util)
        self.trend = (1.0 - self.alpha) * self.trend \
            + self.alpha * (self.level - prev)

    def predict(self) -> float:
        if self.level is None:
            return 0.0
        return self.level + self.horizon * self.trend


@dataclass
class StreamState:
    """What the ladder needs to know about one placed stream.  ``load`` is
    the host's local pressure signal (the fleet passes the hosting node's
    window DLV rate): overload is node-local even when the admission law's
    scalar is fleet-global, so the ladder degrades victims on the hottest
    nodes first — where a swap actually relieves a pressured tier-0
    neighbour — and promotes streams on the coolest nodes first."""

    sid: int
    tier: int
    priority: float
    level: int
    max_level: int
    load: float = 0.0


class AdmissionController:
    """The fleet's SLO brain: pressure law, admission gate, ladder planner.

    Stateful but deterministic.  The host (``FleetSimulator``) feeds it one
    telemetry window per controller tick via :meth:`on_window`, asks
    :meth:`admit` at each stream arrival, and :meth:`plan` at each tick for
    degradation-ladder moves.  All thresholds are plain config so the whole
    controller round-trips through the trace meta (``to_config``) for
    provenance — replay itself applies recorded decisions and never runs
    this code.
    """

    def __init__(self, t_degrade: float = 0.85, t_reject: float = 1.05,
                 t_promote: float = 0.70, w_dlv: float = 0.5,
                 w_backlog: float = 0.25, w_latency: float = 0.5,
                 backlog_norm_s: float = 0.25, max_actions: int = 2,
                 admit_level: int = 1, alpha: float = 0.5,
                 horizon: float = 2.0):
        if not (t_promote < t_degrade <= t_reject):
            raise SLOError(
                f"thresholds must satisfy t_promote < t_degrade <= t_reject, "
                f"got {t_promote} / {t_degrade} / {t_reject}")
        self.t_degrade = float(t_degrade)
        self.t_reject = float(t_reject)
        self.t_promote = float(t_promote)
        self.w_dlv = float(w_dlv)
        self.w_backlog = float(w_backlog)
        self.w_latency = float(w_latency)
        self.backlog_norm_s = float(backlog_norm_s)
        self.max_actions = int(max_actions)
        self.admit_level = int(admit_level)
        self.estimator = LoadEstimator(alpha=alpha, horizon=horizon)
        # last-window signals (zero before the first tick: the gate runs on
        # live utilization alone until telemetry accumulates)
        self._dlv = 0.0
        self._backlog_p90 = 0.0
        self._pipe_latency_s = 0.0
        self._budgets: dict[int, float] = {}    # sid -> budget_s
        self.last_pressure = 0.0
        #: term-by-term breakdown of the last pressure evaluation
        #: (base/dlv/backlog/latency sum to last_pressure) — observability
        #: reads this to attribute every degrade/reject decision
        self.last_terms: dict[str, float] = {}
        #: optional duck-typed metrics registry (repro.obs.MetricsRegistry),
        #: attached by the fleet when observability is on; publishing is
        #: observation only and never feeds back into the law
        self.metrics = None

    # ------------------------------------------------------------- config
    def to_config(self) -> dict:
        return {"t_degrade": self.t_degrade, "t_reject": self.t_reject,
                "t_promote": self.t_promote, "w_dlv": self.w_dlv,
                "w_backlog": self.w_backlog, "w_latency": self.w_latency,
                "backlog_norm_s": self.backlog_norm_s,
                "max_actions": self.max_actions,
                "admit_level": self.admit_level,
                "alpha": self.estimator.alpha,
                "horizon": self.estimator.horizon}

    @classmethod
    def make(cls, cfg: Union[bool, dict, "AdmissionController", None],
             ) -> Optional["AdmissionController"]:
        """Normalize the FleetSimulator's ``slo=`` argument: ``None``/False
        -> disabled, True -> defaults, dict -> configured, instance -> as
        given."""
        if cfg is None or cfg is False:
            return None
        if cfg is True:
            return cls()
        if isinstance(cfg, cls):
            return cfg
        if isinstance(cfg, dict):
            return cls(**cfg)
        raise SLOError(f"cannot interpret slo={cfg!r}")

    # ----------------------------------------------------------- registry
    def register(self, sid: int, slo: SLOClass, head_period_s: float) -> None:
        """Declare a stream's latency budget (called at arrival, before the
        admission verdict — rejected streams still inform the budget mean)."""
        self._budgets[sid] = slo.budget_factor * float(head_period_s)

    def forget(self, sid: int) -> None:
        self._budgets.pop(sid, None)

    def _mean_budget_s(self) -> float:
        if not self._budgets:
            return 0.0
        return sum(self._budgets.values()) / len(self._budgets)

    # ----------------------------------------------------------- pressure
    def on_window(self, window, utils: Sequence[float]) -> float:
        """Absorb one telemetry window plus the candidates' live offered
        utilizations; returns (and stashes) the updated pressure."""
        node_dlv = getattr(window, "node_dlv", None) or {}
        self._dlv = max(node_dlv.values(), default=window.dlv_rate)
        self._backlog_p90 = window.backlog_p90
        self._pipe_latency_s = window.mean_pipeline_latency_s
        u = sum(utils) / len(utils) if utils else 0.0
        self.estimator.observe(u)
        return self.pressure(utils)

    def pressure(self, utils: Sequence[float]) -> float:
        """The admission law's scalar P(t) — see the module docstring."""
        u = sum(utils) / len(utils) if utils else 0.0
        forecast = self.estimator.predict()
        p = max(u, forecast)
        base = p
        dlv_term = self.w_dlv * self._dlv
        p += dlv_term
        backlog_term = 0.0
        if self.backlog_norm_s > 0:
            backlog_term = self.w_backlog * min(
                self._backlog_p90 / self.backlog_norm_s, 1.0)
            p += backlog_term
        latency_term = 0.0
        budget = self._mean_budget_s()
        if budget > 0 and self._pipe_latency_s > 0:
            over = max(self._pipe_latency_s / budget - 1.0, 0.0)
            latency_term = self.w_latency * min(over, 1.0)
            p += latency_term
        self.last_pressure = p
        # base + dlv + backlog + latency telescopes back to P exactly;
        # util/forecast document which side the max() took
        self.last_terms = {"base": base, "util": u, "forecast": forecast,
                           "dlv": dlv_term, "backlog": backlog_term,
                           "latency": latency_term}
        if self.metrics is not None:
            self.metrics.gauge(
                "slo_pressure", "admission-law pressure P(t)").set(p)
            gt = self.metrics.gauge(
                "slo_pressure_term",
                "pressure-law term contributions (sum to slo_pressure)",
                ("term",))
            for k in ("base", "dlv", "backlog", "latency"):
                gt.set(self.last_terms[k], term=k)
        return p

    # ---------------------------------------------------------- admission
    def admit(self, slo: SLOClass, ladder_depth: int,
              utils: Sequence[float]) -> tuple[str, int]:
        """Verdict for one arriving stream: ``("admit", 0)``,
        ``("degrade", level)`` or ``("reject", 0)``.

        Tier-0 is always admitted at full quality.  Above ``t_reject``
        best-effort arrivals are rejected; between ``t_degrade`` and
        ``t_reject`` (and for non-best-effort tiers above ``t_reject``)
        arrivals with a variant ladder are admitted one level down.
        """
        p = self.pressure(utils)
        if slo.tier == TIER_GUARANTEED or p < self.t_degrade:
            return ("admit", 0)
        if p >= self.t_reject and slo.tier >= TIER_BEST_EFFORT:
            return ("reject", 0)
        if ladder_depth > 0:
            return ("degrade", min(self.admit_level, ladder_depth))
        return ("admit", 0)

    # -------------------------------------------------------------- ladder
    def plan(self, streams: Sequence[StreamState]) -> list[tuple[int, int]]:
        """Degradation-ladder moves for one controller tick: ``[(sid,
        new_level), ...]``.  Uses the pressure computed by the immediately
        preceding :meth:`on_window`.  Within the hysteresis band
        ``[t_promote, t_degrade)`` nothing moves — that band is what keeps
        the ladder from flapping.
        """
        p = self.last_pressure
        if p >= self.t_degrade:
            victims = [s for s in streams
                       if s.tier > TIER_GUARANTEED and s.level < s.max_level]
            victims.sort(key=lambda s: (-s.load, -s.tier, s.priority, s.sid))
            return [(s.sid, s.level + 1) for s in victims[:self.max_actions]]
        if p <= self.t_promote:
            lucky = [s for s in streams if s.level > 0]
            lucky.sort(key=lambda s: (s.load, s.tier, -s.priority, s.sid))
            return [(s.sid, s.level - 1) for s in lucky[:self.max_actions]]
        return []
