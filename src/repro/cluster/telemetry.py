"""Windowed fleet telemetry: the feedback signal of the online weight tuner.

The fleet tuner (``repro.cluster.router.TunedScoreRouter``) needs the same
kind of feedback the per-node (alpha, beta) probe gets from UXCost windows
— but at fleet scale, where no single simulator owns the statistics.  This
module aggregates them: :class:`FleetTelemetry` snapshots the fleet at
placement-generation boundaries (the tune ticks of
``repro.cluster.fleet.FleetSimulator``) and emits one
:class:`TelemetryWindow` per interval, each a *delta* over the previous
snapshot:

  * fleet UXCost of the window (Algorithm 2 over the window's per-model
    frame/energy deltas, generation-canonicalized) — the scalar the tuner
    probe minimizes;
  * per-node deadline-violation rates (which nodes degraded this window);
  * backlog percentiles across live nodes (p50 / p90 / max of summed
    to-go latency) — the live pressure signal;
  * migration count and transfer-energy spend charged in the window;
  * per-stream UXCost deltas (``"s<sid>"`` canonical prefix), so a tuner
    or an operator can see *which* streams paid for a bad weight vector.

Invariants:

  * windows are pure deltas: merging every window's per-model frame counts
    reproduces the fleet totals (finalization aside);
  * a window with zero completed frames reports ``uxcost = 0.0`` and
    ``frames = 0`` — consumers (the tuner) treat it as *no signal* and
    hold their committed parameters rather than chase a vacuous zero;
  * snapshots read only cheap per-node state (window stats + telemetry
    gauges); nothing here perturbs any RNG stream, so telemetry can be
    attached to any run without disturbing determinism.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.uxcost import (ModelWindowStats, WindowStats,
                               overall_dlv_rate, uxcost)


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Linear-interpolation percentile of an ascending list (0 for empty)."""
    if not sorted_vals:
        return 0.0
    pos = q * (len(sorted_vals) - 1)
    lo = int(np.floor(pos))
    hi = int(np.ceil(pos))
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


@dataclass(frozen=True)
class TelemetryWindow:
    """One fleet feedback interval: deltas between consecutive snapshots."""

    t0: float
    t1: float
    frames: int                       # frames completed fleet-wide
    violated: int                     # of which deadline-violated
    dlv_rate: float                   # violated / frames (0 when empty)
    uxcost: float                     # Algorithm-2 UXCost of the window
    node_dlv: dict[int, float]        # per live node: window DLV rate
    node_frames: dict[int, int]       # per live node: frames this window
    backlog_p50: float                # percentiles of per-node backlog_s
    backlog_p90: float
    backlog_max: float
    migrations: int                   # migrations charged in the window
    xfer_j: float                     # transfer energy charged in the window
    stream_uxcost: dict[str, float]   # per-stream ("s<sid>") UXCost delta
    n_models: int = 0                 # models that completed frames
    pipe_frames: int = 0              # pipelines completed head-to-tail
    pipe_latency_s: float = 0.0       # summed head-to-tail latency (s)
    departures: int = 0               # stream departures in the window
    rejections: int = 0               # SLO admission rejections in the window
    swaps: int = 0                    # SLO variant swaps in the window

    @property
    def norm_uxcost(self) -> float:
        """Window UXCost normalized by the active-model count squared.

        Raw Algorithm-2 UXCost is a product of two per-model *sums*, so it
        scales ~quadratically with how many models completed frames in the
        window.  Under a drifting workload consecutive windows see
        different populations (arrival ramps, load swings), which would
        bias any probe that compares candidates measured in *different*
        windows toward whichever one ran when the fleet was emptier.
        Dividing by ``n_models**2`` makes the signal approximately
        population-invariant (≈ mean DLV rate × mean NormEnergy) — this is
        the cost the weight tuner minimizes."""
        if self.n_models == 0:
            return 0.0
        return self.uxcost / float(self.n_models) ** 2

    @property
    def mean_pipeline_latency_s(self) -> float:
        """Mean head-to-tail pipeline latency over the window's completed
        pipelines (0 when none completed) — the end-to-end metric next to
        the per-model DLV rates."""
        return self.pipe_latency_s / self.pipe_frames if self.pipe_frames \
            else 0.0

    @property
    def empty(self) -> bool:
        """True when the window carries no feedback signal (no frames
        completed — e.g. a zero-length window between same-time ticks).
        Tuners must fall back to their committed parameters on empty
        windows instead of treating the vacuous 0-cost as a measurement."""
        return self.frames == 0


class FleetTelemetry:
    """Snapshot-differencing aggregator over a live fleet.

    ``observe(t, nodes, migrations, xfer_energy)`` is called by the fleet
    simulator at each tune tick with the current node map and the
    cumulative migration/transfer counters; it returns the
    :class:`TelemetryWindow` covering the interval since the previous call
    (the first call covers from fleet start) and appends it to
    :attr:`windows`.
    """

    def __init__(self, canonical=None):
        #: name canonicalizer applied to per-model stats (the fleet passes
        #: ``canonical_stream_model`` so placement generations and stage
        #: splits collapse to one logical model per stream)
        self.canonical = canonical or (lambda name: name)
        self.windows: list[TelemetryWindow] = []
        self._t_last = 0.0
        #: per canonical model: (frames, violated, energy, worst_energy,
        #: pipe_frames, pipe_latency_s) cumulative at the last snapshot
        self._last: dict[str, tuple] = {}
        self._last_by_node: dict[int, tuple[int, int]] = {}
        self._last_migrations = 0
        self._last_xfer_j = 0.0
        self._last_departures = 0
        self._last_rejections = 0
        self._last_swaps = 0

    # ------------------------------------------------------------ snapshot
    def _cumulative(self, nodes: dict) -> tuple[
            dict[str, tuple], dict[int, tuple[int, int]]]:
        """Fleet-cumulative per-canonical-model stats and per-node frame
        counters.  Reads each node's merged global stats plus the open
        UXCost window, so tune ticks need not align with node windows."""
        per_model: dict[str, tuple] = {}
        per_node: dict[int, tuple[int, int]] = {}
        for nid in sorted(nodes):
            node = nodes[nid]
            nf = nv = 0
            for stats in (node.sim.global_stats, node.sim.window_stats):
                for name, st in stats.per_model.items():
                    cname = self.canonical(name)
                    f, v, e, w, qf, ql = per_model.get(
                        cname, (0, 0, 0.0, 0.0, 0, 0.0))
                    per_model[cname] = (f + st.frames, v + st.violated,
                                        e + st.energy_j,
                                        w + st.worst_energy_j,
                                        qf + st.pipe_frames,
                                        ql + st.pipe_latency_s)
                    nf += st.frames
                    nv += st.violated
            per_node[nid] = (nf, nv)
        return per_model, per_node

    def observe(self, t: float, nodes: dict, migrations: int,
                xfer_energy_j: float,
                departures: int = 0, rejections: int = 0,
                swaps: int = 0) -> TelemetryWindow:
        """Close the current window at fleet time ``t`` and return it.
        ``departures`` / ``rejections`` / ``swaps`` are the fleet's
        cumulative counters (the window reports deltas, like
        migrations)."""
        cum, by_node = self._cumulative(nodes)
        delta = WindowStats()
        for cname in sorted(cum):
            f, v, e, w, qf, ql = cum[cname]
            pf, pv, pe, pw, pqf, pql = self._last.get(
                cname, (0, 0, 0.0, 0.0, 0, 0.0))
            if f - pf > 0 or w - pw > 0.0:
                delta.per_model[cname] = ModelWindowStats(
                    frames=f - pf, violated=v - pv, energy_j=e - pe,
                    worst_energy_j=w - pw, pipe_frames=qf - pqf,
                    pipe_latency_s=ql - pql)
        node_dlv: dict[int, float] = {}
        node_frames: dict[int, int] = {}
        for nid in sorted(by_node):
            f, v = by_node[nid]
            pf, pv = self._last_by_node.get(nid, (0, 0))
            df, dv = f - pf, v - pv
            node_frames[nid] = df
            node_dlv[nid] = dv / df if df > 0 else 0.0
        backlogs = sorted(
            nodes[nid].telemetry().backlog_s
            for nid in sorted(nodes) if nodes[nid].alive)
        frames = sum(st.frames for st in delta.per_model.values())
        stream_ux = {}
        by_stream: dict[str, WindowStats] = {}
        for cname, st in delta.per_model.items():
            sid = cname.split(".", 1)[0]
            by_stream.setdefault(sid, WindowStats()).per_model[cname] = st
        for sid in sorted(by_stream):
            stream_ux[sid] = uxcost(by_stream[sid])
        win = TelemetryWindow(
            t0=self._t_last, t1=t,
            frames=frames,
            violated=sum(st.violated for st in delta.per_model.values()),
            dlv_rate=overall_dlv_rate(delta),
            uxcost=uxcost(delta),
            node_dlv=node_dlv,
            node_frames=node_frames,
            backlog_p50=_percentile(backlogs, 0.50),
            backlog_p90=_percentile(backlogs, 0.90),
            backlog_max=backlogs[-1] if backlogs else 0.0,
            migrations=migrations - self._last_migrations,
            xfer_j=xfer_energy_j - self._last_xfer_j,
            stream_uxcost=stream_ux,
            n_models=sum(1 for st in delta.per_model.values()
                         if st.frames > 0),
            pipe_frames=sum(st.pipe_frames
                            for st in delta.per_model.values()),
            pipe_latency_s=sum(st.pipe_latency_s
                               for st in delta.per_model.values()),
            departures=departures - self._last_departures,
            rejections=rejections - self._last_rejections,
            swaps=swaps - self._last_swaps,
        )
        self.windows.append(win)
        self._t_last = t
        self._last = cum
        self._last_by_node = by_node
        self._last_migrations = migrations
        self._last_xfer_j = xfer_energy_j
        self._last_departures = departures
        self._last_rejections = rejections
        self._last_swaps = swaps
        return win
