"""One DREAM node inside a fleet: a per-node Simulator plus the telemetry
and placement surface the global router consumes.

A :class:`FleetNode` wraps an *empty-scenario* ``repro.core.Simulator``
(streams arrive later, placed by the router through ``Simulator.join_model``)
driven through the step/peek API so the fleet clock can interleave nodes.
Telemetry is a cheap snapshot — queue depth, backlog, the latest UXCost
window, utilization — and the MapScore-style cross-node summaries (how well
a candidate stream's models suit this node's accelerator mix, and how much
utilization it would add) come from the memoized offline cost tables, so
evaluating a stream against every node of a 16-node fleet costs a handful
of dict lookups.

Invariants:

  * placement keys are opaque to the node (the fleet passes stream ids or
    (sid, stage) tuples) and homogeneous within one run;
  * every placement/eviction re-arms the node's (alpha, beta) adaptivity
    probe (``retrigger_probe``) — churn is a workload change by definition;
  * ``offered_s`` tracks the summed offered load of *currently placed*
    streams under the weights the fleet supplied at placement time, so
    whole-stream and stage-split runs report comparable utilization;
  * ``recent_dlv`` covers only the latest advance span — a node is not
    penalized forever for early violations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.costmodel import (build_cost_table, genai_expected_tokens,
                                  genai_iso_s)
from repro.core.simulator import SchedulerBase, SimResult, Simulator
from repro.core.types import Accelerator, ModelGraph, Scenario, SYSTEMS


@dataclass(frozen=True)
class NodeTelemetry:
    """Router-visible snapshot of one node (all fields cheap to compute)."""

    node_id: int
    system: str
    n_accs: int
    queue_depth: int        # jobs ready or running right now
    active_streams: int     # streams currently placed here
    backlog_s: float        # summed mean to-go latency of live jobs (s)
    offered_util: float     # placed streams' offered load / accelerator count
    window_uxcost: float    # most recent UXCost window (0 before the first)
    window_dlv: float       # DLV rate over the most recent advance span
    utilization: float      # cumulative busy fraction so far
    drops: int
    draining: bool


@dataclass(frozen=True)
class StreamCost:
    """MapScore-style summary of one stream on one node's accelerator mix."""

    iso_s: float            # best-accelerator isolated latency, full pipeline
    offered_s: float        # expected busy-seconds per wall-clock second
    urgency: float          # iso latency / head period (deadline tightness)


class FleetNode:
    """A member of the fleet: simulator + stream bookkeeping + telemetry."""

    def __init__(self, node_id: int, system: str | tuple[Accelerator, ...],
                 scheduler: SchedulerBase, *, duration_s: float,
                 seed: int, window_s: float = 0.5, at_t: float = 0.0,
                 genai_predictor: bool = True, engine=None, obs=None):
        self.node_id = node_id
        self.system = system if isinstance(system, str) else "custom"
        self.accs_spec = SYSTEMS[system] if isinstance(system, str) else system
        # the obs bundle is fleet-shared: every node's spans/metrics land
        # in one tracer/registry, tagged with this node's id
        self.sim = Simulator(Scenario(name=f"node{node_id}", models=()),
                             self.accs_spec, scheduler,
                             duration_s=duration_s, seed=seed,
                             window_s=window_s,
                             genai_predictor=genai_predictor,
                             engine=engine,
                             obs=obs, obs_node=node_id)
        self.sim.start(at_t=at_t)
        self.join_t = at_t
        self.draining = False
        self.alive = True
        #: placement key -> namespaced model names placed under it.  The
        #: key is opaque to the node: the fleet uses the stream id for
        #: whole-stream placements and (sid, stage) tuples in stage-split
        #: mode; keys within one run are always homogeneous
        self.placements: dict[object, list[str]] = {}
        #: sum of offered load (busy-s per s) of currently placed streams
        self.offered_s = 0.0
        #: per-model offered-load weights (cascade stages placed standalone
        #: carry their trigger probability here, since their specs no longer
        #: declare a local dependency)
        self._load_weights: dict[str, float] = {}
        self.probe_retriggers = 0
        #: SLO degradation pins: model name -> currently-active variant
        #: graph (the original graph when promoted back), so offered-load
        #: telemetry reflects what a degraded stream actually costs
        self._active_graph: dict[str, ModelGraph] = {}
        #: DLV rate over the most recent advance span (not run-cumulative,
        #: so a node is not penalized forever for early violations)
        self.recent_dlv = 0.0
        self._dlv_snapshot = (0, 0)          # (frames, violated) seen so far
        #: memoized telemetry() snapshot.  Telemetry walks every live job;
        #: the router reads it once per node per placement and once per
        #: candidate per rebalanced stream — identical values within one
        #: fleet event, since node state only changes through the
        #: invalidation points below (advance/place/evict/swap/phase)
        self._tel_cache: "Optional[NodeTelemetry]" = None
        #: fleet-installed dirty hook (node_id -> None): fires whenever the
        #: telemetry memo is invalidated, so the fleet's SoA telemetry
        #: columns refresh exactly the rows that can have changed
        self.tel_dirty_hook = None
        #: id(graph) -> (graph pin, iso_best_s) memo for _iso_best
        self._iso_cache: dict[int, tuple] = {}

    def _invalidate_telemetry(self) -> None:
        self._tel_cache = None
        if self.tel_dirty_hook is not None:
            self.tel_dirty_hook(self.node_id)

    # ------------------------------------------------------------- clock
    def advance_to(self, t: float) -> None:
        # telemetry is a pure function of processed-event state: when the
        # clock advance pops no events, every reading (backlog, util span,
        # merged DLV counters) is unchanged, so the memo stays valid
        if self.alive and self.sim.step_until(t):
            self._update_recent_dlv()
            self._invalidate_telemetry()

    def _update_recent_dlv(self) -> None:
        # O(1): the simulator keeps running totals over global_stats (the
        # same integers the old per_model walk summed at every advance)
        frames = self.sim.merged_frames
        viol = self.sim.merged_violated
        df = frames - self._dlv_snapshot[0]
        if df > 0:
            self.recent_dlv = (viol - self._dlv_snapshot[1]) / df
            self._dlv_snapshot = (frames, viol)

    def finalize(self) -> SimResult:
        return self.sim.finalize()

    # -------------------------------------------------------- placement
    def place(self, key: object, specs: list, names: list[str],
              t: float, weights: "Optional[list[float]]" = None) -> None:
        """Join a stream's pipeline — or a single stage of one — under
        ``key`` (ModelSpecs in dependency order, head first).  ``weights``
        overrides the offered-load weight per spec (the fleet passes the
        stage's trigger probability for standalone cascade stages, keeping
        load telemetry consistent across placement granularities)."""
        self._invalidate_telemetry()
        for spec in specs:
            self.sim.join_model(spec, t)
        self.placements[key] = list(names)
        for i, (g, fps, weight) in enumerate(_spec_loads(specs)):
            if weights is not None:
                weight = weights[i]
            self._load_weights[names[i]] = weight
            self.offered_s += weight * fps * self._iso_best(g)
        self.retrigger_probe()

    def evict(self, key: object, t: float) -> None:
        """Stop a placement's arrivals here (jobs in flight still
        complete, and exported completions still drain)."""
        for name in self.placements.pop(key, ()):
            self.sim.leave_model(name, t)
            # every re-placement mints a generation-fresh name, so a
            # weight kept past eviction would never be read again
            self._load_weights.pop(name, None)
            self._active_graph.pop(name, None)
        # offered load is recomputed from scratch on eviction: the spec
        # objects are gone, so track via the remaining placements instead
        self._recompute_offered()
        self.retrigger_probe()

    def release(self, key: object, t: float) -> int:
        """Departure eviction: evict the placement *and* purge its queued
        (not-yet-running) jobs — the stream left, so its backlog vanishes
        with it instead of counting as violations (migration eviction, by
        contrast, lets queued jobs finish: the stream still exists, only
        elsewhere).  Returns the number of jobs purged."""
        names = list(self.placements.get(key, ()))
        self.evict(key, t)
        return sum(self.sim.purge_model(name) for name in names)

    def swap_level(self, names: "list[str]", level: int, t: float) -> None:
        """Apply an SLO degradation-ladder level to the placed models in
        ``names``: pin each onto its ``level``-th supernet variant (0 =
        original quality; models without variants are untouched), then
        refresh offered-load telemetry and re-arm the (alpha, beta) probe —
        a quality swap is a workload change by definition."""
        for name in names:
            self._active_graph[name] = self.sim.swap_variant(name, level, t)
        self._recompute_offered()
        self.retrigger_probe()

    def _recompute_offered(self) -> None:
        self._invalidate_telemetry()
        live = {n for names in self.placements.values() for n in names}
        total = 0.0
        for i, spec in enumerate(self.sim.specs):
            if spec.model.name in live and self.sim.active[i]:
                w = self._load_weights.get(
                    spec.model.name,
                    1.0 if spec.depends_on is None else spec.trigger_prob)
                g = self._active_graph.get(spec.model.name, spec.model)
                total += w * spec.fps * self._iso_best(g)
        self.offered_s = total

    def retrigger_probe(self) -> None:
        """Membership/placement churn re-arms the node's (alpha, beta)
        probe — the simulator-level analogue of the paper's workload-change
        re-trigger, signalled explicitly by the fleet."""
        fn = getattr(self.sim.scheduler, "retrigger_probe", None)
        if fn is not None:
            fn()
            self.probe_retriggers += 1

    # -------------------------------------------------------- estimates
    def _iso_best(self, graph: ModelGraph) -> float:
        # memoized per node: candidate evaluation asks for the same few
        # graphs thousands of times; the graph is pinned in the value so
        # its id cannot be recycled while the entry lives
        hit = self._iso_cache.get(id(graph))
        if hit is not None and hit[0] is graph:
            return hit[1]
        table = build_cost_table(graph, self.accs_spec)
        if graph.genai is not None:
            # autoregressive streams are priced at the *expected* generation
            # length: the router and SLO ladder see the predictor's view,
            # not one decode pass and not the worst-case cap.  The blind
            # ablation prices every surface at the cap, so admission and
            # the degradation ladder act on phantom decode load
            n = (genai_expected_tokens(graph.genai)
                 if self.sim.genai_predictor
                 else float(graph.genai.max_new_tokens))
            iso = float(genai_iso_s(table, graph.genai, n).min())
        else:
            iso = table.iso_best_s
        if len(self._iso_cache) >= 4096:
            self._iso_cache.clear()
        self._iso_cache[id(graph)] = (graph, iso)
        return iso

    def stream_cost(self, graphs: list[tuple[ModelGraph, float, float]],
                    head_period_s: float) -> StreamCost:
        """Estimate a candidate stream on this node.  ``graphs`` is a list
        of (graph, fps, weight) with weight = cascade trigger probability
        (1.0 for heads); cost tables are memoized so this is cheap."""
        iso = 0.0
        offered = 0.0
        for g, fps, weight in graphs:
            best = self._iso_best(g)
            iso += weight * best
            offered += weight * fps * best
        urgency = iso / max(head_period_s, 1e-9)
        return StreamCost(iso_s=iso, offered_s=offered, urgency=urgency)

    # -------------------------------------------------------- telemetry
    def telemetry(self) -> NodeTelemetry:
        if self._tel_cache is not None:
            return self._tel_cache
        sim = self.sim
        if sim.soa is not None and len(sim.jobs) >= 16:
            # SoA arm: togo_mean holds exactly Job.togo() per live row in
            # jid (dict) order, and cumsum accumulates sequentially — the
            # same left-to-right float64 additions as the scalar sum()
            # below (the size gate is a pure perf crossover, not semantic)
            rows = sim.soa.live_rows()
            n_live = len(rows)
            backlog = (float(np.cumsum(sim.soa.togo_mean[rows])[-1])
                       if n_live else 0.0)
        else:
            live = [j for j in sim.jobs.values() if not j.done]
            n_live = len(live)
            backlog = sum(j.togo() for j in live)
        n_accs = len(sim.accs)
        if sim.windows:
            _, wux, _, _ = sim.windows[-1]
        else:
            wux = 0.0
        span = max(sim.t - self.join_t, 1e-9)   # busy fraction since join
        util = sum(a.busy_time for a in sim.accs) / (n_accs * span)
        self._tel_cache = tel = NodeTelemetry(
            node_id=self.node_id,
            system=self.system,
            n_accs=n_accs,
            queue_depth=n_live,
            active_streams=len(self.placements),
            backlog_s=backlog,
            offered_util=self.offered_s / n_accs,
            window_uxcost=wux,
            window_dlv=self.recent_dlv,
            utilization=min(util, 1.0),
            drops=sim.drops,
            draining=self.draining,
        )
        return tel


def _spec_loads(specs: list) -> list[tuple[ModelGraph, float, float]]:
    return [(s.model, s.fps, 1.0 if s.depends_on is None else s.trigger_prob)
            for s in specs]
