"""Fleet subsystem: multi-node DREAM behind a score-driven global router.

Composes N per-node simulators (heterogeneous Table-2 systems per node)
under a fleet clock with pluggable routing policies, elastic membership
(node join / drain / leave with stream migration and adaptivity-probe
re-triggering), fleet-level UXCost aggregation, and a JSONL fleet trace
whose replay reproduces an entire run bit-exactly.

Placement is stream- or *stage*-granular: with ``split_stages=True`` and a
:class:`repro.core.costmodel.TransferModel`, the router places each cascade
stage independently, cross-node triggers pay explicit activation-transfer
latency/energy, and migrations charge state-transfer cost into the fleet
UXCost — see ``docs/architecture.md`` and ``docs/scheduling.md``.

Overload is a managed regime: the SLO subsystem (:mod:`.slo`) gives every
stream a service tier, gates admission (admit / degrade onto a cheaper
supernet variant / reject with explicit UXCost accounting), and walks a
hysteresis-banded degradation ladder over placed streams — all recorded
in the trace so replay bypasses the controller bit-exactly.
"""
from repro.core.costmodel import ContendedLinks, TransferModel

from .builder import (CascadeFuzz, FleetEvent, FleetScenario,
                      FleetScenarioBuilder, FuzzSpec, GenAIFuzz,
                      LifecycleFuzz, SLOFuzz, split_pipelines)
from .fleet import (FleetResult, FleetSimulator, StreamView,
                    canonical_stream_model, node_seed, run_fleet)
from .node import FleetNode, NodeTelemetry, StreamCost
from .router import (POLICIES, STATIC_WEIGHTS, WEIGHT_NAMES,
                     LeastLoadedRouter, RoundRobinRouter, RouterPolicy,
                     ScoreDrivenRouter, TunedScoreRouter, make_policy)
from .slo import (DEFAULT_SLO, TIER_BEST_EFFORT, TIER_DEFAULTS,
                  TIER_GUARANTEED, TIER_STANDARD, AdmissionController,
                  LoadEstimator, SLOClass, SLOError, StreamState,
                  slo_from_config)
from .telemetry import FleetTelemetry, TelemetryWindow
from .trace import (FLEET_EVENT_KINDS, FLEET_TRACE_VERSION, FleetTrace,
                    FleetTraceRecorder, dumps, load_trace, loads, save_trace)

__all__ = [
    "ContendedLinks", "TransferModel",
    "CascadeFuzz", "FleetEvent", "FleetScenario", "FleetScenarioBuilder",
    "FuzzSpec", "GenAIFuzz", "LifecycleFuzz", "SLOFuzz", "split_pipelines",
    "FleetResult", "FleetSimulator", "StreamView", "canonical_stream_model",
    "node_seed", "run_fleet",
    "FleetNode", "NodeTelemetry", "StreamCost",
    "POLICIES", "STATIC_WEIGHTS", "WEIGHT_NAMES", "LeastLoadedRouter",
    "RoundRobinRouter", "RouterPolicy", "ScoreDrivenRouter",
    "TunedScoreRouter", "make_policy",
    "DEFAULT_SLO", "TIER_BEST_EFFORT", "TIER_DEFAULTS", "TIER_GUARANTEED",
    "TIER_STANDARD", "AdmissionController", "LoadEstimator", "SLOClass",
    "SLOError", "StreamState", "slo_from_config",
    "FleetTelemetry", "TelemetryWindow",
    "FLEET_EVENT_KINDS", "FLEET_TRACE_VERSION", "FleetTrace",
    "FleetTraceRecorder", "dumps", "load_trace", "loads", "save_trace",
]
