"""Fleet scenarios: node membership + stream arrivals as declarative data.

A :class:`FleetScenario` is an ordered list of timed fleet events — nodes
joining/leaving/draining, streams arriving, *departing and rejoining*
(the full task lifecycle: RTMM tasks stop when the user's context
changes, not only start), fleet-level phase events (stream-addressed
workload mutations such as diurnal load shifts) —
exactly the external input a multi-node deployment sees.  The builder shards existing single-node
workload definitions across the fleet: a registry scenario or a fuzzer
sample splits into its independent pipelines (a head model plus its
cascade children), each becoming one routable stream whose stages the
stage-split router may later place on different nodes.

Invariants:

  * everything is plain data (``to_config``/``from_config``): fleet
    scenarios serialize, and fleet traces can embed the streams they
    placed;
  * every stream starts with a head entry and names its models explicitly
    (serializable ModelRefs) — the fleet's placement-generation
    namespacing needs stable base names;
  * ``build()`` enforces temporal consistency (no drain/leave before the
    node's join) and sorts events by (time, declaration order);
  * fuzzed populations are deterministic at build time — the resulting
    FleetScenario needs no runtime randomness.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.scenarios.builder import ModelEntry, ScenarioBuilder, ScenarioError
from repro.scenarios.fuzzer import fuzz_scenario

from .slo import slo_from_config


# ---------------------------------------------------------------------------
# Fuzzed-population specification
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CascadeFuzz:
    """Cascade shape of a fuzzed population."""

    prob: float = 0.5           # per-child trigger probability
    max_depth: int = 2          # max cascade chain length
    only: bool = False          # drop single-stage pipelines entirely
    max_pipelines: int = 1      # pipelines per fuzzer sample


@dataclass(frozen=True)
class LifecycleFuzz:
    """Stream departure/rejoin churn of a fuzzed population."""

    depart_frac: float = 0.0    # fraction of streams departing mid-run
    rejoin_frac: float = 0.0    # fraction of departures that rejoin
    t0: "float | None" = None   # depart window start (default: arrival t1)
    t1: "float | None" = None   # depart window end (default: 2 * arrival t1)


@dataclass(frozen=True)
class SLOFuzz:
    """Service-tier structure of a fuzzed population."""

    #: (tier-0, tier-1, best-effort) draw weights; None = tierless
    tier_mix: "tuple[float, float, float] | None" = None
    #: fraction of stream heads re-headed onto the OFA supernet
    #: (index-strided, no RNG) so the degradation ladder has rungs
    supernet_frac: float = 0.0


@dataclass(frozen=True)
class GenAIFuzz:
    """Autoregressive share of a fuzzed population."""

    #: fraction of stream heads re-headed onto the chat_llm generative
    #: family (index-strided, no RNG; wins over the supernet stride on
    #: collisions) — token-level preemption and the length predictor then
    #: have traffic to act on
    frac: float = 0.0


#: generation-length profiles cycled (deterministically, by genai-stream
#: index) across fuzzed chat heads: short replies, medium chat turns, long
#: form.  Heterogeneous caps are what separate a blind scheduler (prices
#: every generation at max_new_tokens) from the EWMA length predictor
GENAI_PROFILES: "tuple[dict, ...]" = (
    {"max_new_tokens": 16, "token_mean": 6.0},
    {"max_new_tokens": 24, "token_mean": 10.0},
    {"max_new_tokens": 48, "token_mean": 18.0},
)


@dataclass(frozen=True)
class FuzzSpec:
    """Full specification of one seeded fuzz_streams population.

    Replaces the historical 16-kwarg call form; sub-specs group the knobs
    by subsystem.  For a fixed (seed, knobs) combination the population is
    byte-stable against the legacy form (tests/test_fuzz_spec.py pins the
    recorded fingerprints)."""

    n_streams: int
    seed: int
    t0: float = 0.0             # arrival window start
    t1: float = 1.0             # arrival window end
    fps_scale: float = 1.0
    deterministic_arrivals: bool = False
    cascade: CascadeFuzz = field(default_factory=CascadeFuzz)
    lifecycle: LifecycleFuzz = field(default_factory=LifecycleFuzz)
    slo: SLOFuzz = field(default_factory=SLOFuzz)
    genai: GenAIFuzz = field(default_factory=GenAIFuzz)


def _legacy_fuzz_spec(n_streams: int, seed: int, t0: float = 0.0,
                      t1: float = 1.0, max_pipelines: int = 1,
                      fps_scale: float = 1.0, cascade_prob: float = 0.5,
                      max_depth: int = 2, cascades_only: bool = False,
                      deterministic_arrivals: bool = False,
                      depart_frac: float = 0.0, rejoin_frac: float = 0.0,
                      t_depart0: "float | None" = None,
                      t_depart1: "float | None" = None,
                      tier_mix: "tuple[float, float, float] | None" = None,
                      supernet_frac: float = 0.0,
                      genai_frac: float = 0.0) -> FuzzSpec:
    """Map the historical flat kwargs onto a :class:`FuzzSpec`."""
    return FuzzSpec(
        n_streams=int(n_streams), seed=int(seed), t0=t0, t1=t1,
        fps_scale=fps_scale, deterministic_arrivals=deterministic_arrivals,
        cascade=CascadeFuzz(prob=cascade_prob, max_depth=max_depth,
                            only=cascades_only, max_pipelines=max_pipelines),
        lifecycle=LifecycleFuzz(depart_frac=depart_frac,
                                rejoin_frac=rejoin_frac,
                                t0=t_depart0, t1=t_depart1),
        slo=SLOFuzz(tier_mix=None if tier_mix is None else tuple(tier_mix),
                    supernet_frac=supernet_frac),
        genai=GenAIFuzz(frac=genai_frac),
    )


@dataclass(frozen=True)
class FleetEvent:
    """One timed fleet-level event (serializable kind + payload)."""

    t: float
    #: node_join | node_leave | node_drain | stream | depart | rejoin | phase
    kind: str
    payload: dict

    def to_config(self) -> dict:
        return {"t": self.t, "kind": self.kind, **self.payload}

    @classmethod
    def from_config(cls, cfg: dict) -> "FleetEvent":
        d = dict(cfg)
        return cls(t=float(d.pop("t")), kind=d.pop("kind"), payload=d)


@dataclass(frozen=True)
class FleetScenario:
    """A full fleet workload: membership churn + stream arrivals."""

    name: str
    events: tuple[FleetEvent, ...]      # sorted by (t, declaration order)

    def to_config(self) -> dict:
        return {"name": self.name,
                "events": [e.to_config() for e in self.events]}

    @classmethod
    def from_config(cls, cfg: dict) -> "FleetScenario":
        return cls(name=cfg["name"],
                   events=tuple(FleetEvent.from_config(e)
                                for e in cfg["events"]))

    @property
    def n_nodes(self) -> int:
        return sum(1 for e in self.events if e.kind == "node_join")

    @property
    def n_streams(self) -> int:
        return sum(1 for e in self.events if e.kind == "stream")


def split_pipelines(builder: ScenarioBuilder) -> list[list[dict]]:
    """Shard a scenario into its independent pipelines (head + cascade
    children), as lists of serialized ModelEntry configs, head first.
    Cross-pipeline dependencies cannot exist (the scenario builder only
    allows forward references), so pipelines route independently."""
    builder.validate()
    pipelines: list[list[dict]] = []
    owner: dict[str, int] = {}      # model name -> pipeline index
    for entry in builder.entries:
        cfg = entry.to_config()
        # pin the effective instance name so fleet namespacing is stable
        cfg["model"]["name"] = entry.model_name
        if entry.depends_on is None:
            owner[entry.model_name] = len(pipelines)
            pipelines.append([cfg])
        else:
            pidx = owner[entry.depends_on]
            owner[entry.model_name] = pidx
            pipelines[pidx].append(cfg)
    return pipelines


class FleetScenarioBuilder:
    """Fluent builder for fleet scenarios."""

    def __init__(self, name: str):
        self.name = name
        self._events: list[FleetEvent] = []
        self._next_node = 0
        self._next_sid = 0
        self._node_ids: set[int] = set()

    # -------------------------------------------------------- membership
    def node(self, system: str = "4K_1WS2OS", at: float = 0.0) -> int:
        """Declare a node joining the fleet at time ``at`` (a Table-2
        system name). Returns its node id."""
        nid = self._next_node
        self._next_node += 1
        self._node_ids.add(nid)
        self._events.append(FleetEvent(float(at), "node_join",
                                       {"node": nid, "system": system}))
        return nid

    def node_leave(self, node_id: int, at: float) -> "FleetScenarioBuilder":
        """Abrupt departure: the node stops at ``at``; its streams migrate,
        jobs in flight there are lost."""
        self._check_node(node_id)
        self._events.append(FleetEvent(float(at), "node_leave",
                                       {"node": node_id}))
        return self

    def node_drain(self, node_id: int, at: float) -> "FleetScenarioBuilder":
        """Graceful departure: streams migrate away at ``at`` and the node
        stops accepting placements, but keeps executing its queue."""
        self._check_node(node_id)
        self._events.append(FleetEvent(float(at), "node_drain",
                                       {"node": node_id}))
        return self

    def _check_node(self, node_id: int) -> None:
        if node_id not in self._node_ids:
            raise ScenarioError(f"unknown fleet node id {node_id}")

    # ------------------------------------------------------------- phases
    #: fleet-level phase-action kinds: mutations that apply uniformly to a
    #: *stream* (every stage of it, wherever placed).  Model-addressed
    #: actions (set_fps, set_trigger_prob, join, leave) stay node-local —
    #: their model names are namespaced per placement, which a scenario
    #: cannot know ahead of routing.
    FLEET_PHASE_KINDS = ("scale_fps",)

    def phase(self, action, at: float,
              sids: "list[int] | None" = None) -> "FleetScenarioBuilder":
        """A timed fleet-level workload mutation: apply ``action`` (a
        ``repro.scenarios.phases.PhaseAction`` or its config dict) to the
        streams in ``sids`` (None = every stream declared so far) at time
        ``at``.  The fleet forwards the action to each targeted stream's
        hosting node(s), re-arms the touched nodes' (alpha, beta) probes,
        and — under a tuned router — re-arms the fleet weight tuner: a
        phase event is a workload change by definition."""
        cfg = action if isinstance(action, dict) else action.to_config()
        if cfg.get("kind") not in self.FLEET_PHASE_KINDS:
            raise ScenarioError(
                f"fleet phase supports kinds {self.FLEET_PHASE_KINDS}, "
                f"got {cfg.get('kind')!r}")
        if cfg.get("models") is not None:
            raise ScenarioError("fleet phase actions target streams via "
                                "`sids`, not model names (placement "
                                "namespacing owns the names)")
        if sids is not None:
            unknown = [s for s in sids if not 0 <= s < self._next_sid]
            if unknown:
                raise ScenarioError(f"phase targets unknown stream ids "
                                    f"{unknown}")
            sids = [int(s) for s in sids]
        payload: dict = {"action": dict(cfg)}
        if sids is not None:
            payload["sids"] = sids
        self._events.append(FleetEvent(float(at), "phase", payload))
        return self

    # --------------------------------------------------- stream lifecycle
    def depart(self, sid: int, at: float) -> "FleetScenarioBuilder":
        """Stream ``sid`` departs at ``at`` — the load-release half of
        task-level dynamicity: the user's context changed and the task
        stopped.  The fleet evicts the stream from its hosting node(s),
        purges its queued (not-yet-running) frames from the backlog
        without counting them against UXCost, and re-arms the touched
        nodes' probes and the fleet weight tuner.  ``build()`` validates
        ordering: a depart must follow the stream's arrival (and any
        earlier depart must have been rejoined)."""
        self._check_sid(sid)
        self._events.append(FleetEvent(float(at), "depart", {"sid": sid}))
        return self

    def rejoin(self, sid: int, at: float) -> "FleetScenarioBuilder":
        """A departed stream returns at ``at`` with its recorded pipeline
        definition: the router re-places it (fresh placement generation)
        exactly like a new arrival.  Must follow a ``depart`` of the same
        stream (validated by ``build()``)."""
        self._check_sid(sid)
        self._events.append(FleetEvent(float(at), "rejoin", {"sid": sid}))
        return self

    def _check_sid(self, sid: int) -> None:
        if not 0 <= sid < self._next_sid:
            raise ScenarioError(f"unknown stream id {sid}")

    # ----------------------------------------------------------- streams
    def add_stream(self, entries: "list[dict] | list[ModelEntry]",
                   at: float = 0.0, slo: "int | dict | None" = None) -> int:
        """One routable stream: a pipeline of ModelEntry configs (head
        first).  ``slo`` optionally declares the stream's service tier (a
        bare tier number or an SLO config dict — see
        :mod:`repro.cluster.slo`); validated here, carried in the event
        payload, and omitted entirely for tierless streams so legacy
        scenarios and traces stay byte-stable.  Returns the stream id."""
        cfgs = []
        for e in entries:
            cfg = e.to_config() if isinstance(e, ModelEntry) else dict(e)
            if cfg.get("model", {}).get("name") is None:
                raise ScenarioError("fleet stream entries need explicit "
                                    "model names (serializable ModelRefs)")
            cfgs.append(cfg)
        if not cfgs:
            raise ScenarioError("fleet stream has no entries")
        if cfgs[0].get("depends_on") is not None:
            raise ScenarioError("fleet stream must start with a head entry")
        sid = self._next_sid
        self._next_sid += 1
        payload: dict = {"sid": sid, "entries": cfgs}
        if slo is not None:
            payload["slo"] = slo_from_config(slo).to_config()
        self._events.append(FleetEvent(float(at), "stream", payload))
        return sid

    def add_scenario(self, builder: ScenarioBuilder,
                     at: float = 0.0) -> list[int]:
        """Shard a whole single-node scenario into per-pipeline streams."""
        return [self.add_stream(p, at=at) for p in split_pipelines(builder)]

    def fuzz_streams(self, spec: "FuzzSpec | int",
                     seed: "int | None" = None, **kw) -> list[int]:
        """Seeded stream population: fuzzer-sampled pipelines with arrival
        times uniform over [spec.t0, spec.t1).  Deterministic at build
        time, so the resulting FleetScenario needs no runtime randomness.

        Pass a :class:`FuzzSpec`.  The historical flat call form —
        ``fuzz_streams(n_streams, seed, cascade_prob=..., tier_mix=...,
        ...)`` — still works, maps byte-stably onto the same populations,
        and emits a :class:`DeprecationWarning`.

        ``fps_scale`` rescales every stream's FPS targets: the fuzzer pools
        are sized for one pipeline per multi-accelerator node, while a fleet
        serves *many* light streams per node — ~0.25 puts a 12-streams-per-
        node fleet near 50% offered utilization.

        ``spec.cascade`` shapes the pipelines (``prob``/``max_depth``
        thread to the fuzzer; ``only`` drops single-stage pipelines, so
        every admitted stream has at least one cross-placeable edge).

        ``deterministic_arrivals`` replaces every sampled arrival process
        with an explicitly-phased periodic one (phase hashed from the
        stream id).  Stochastic arrival processes draw from a *per-node*
        RNG in event order, so their realizations depend on which streams
        share a node — pinning them makes the offered workload identical
        across placement policies, which is what a fair routing comparison
        (e.g. whole-pipeline vs stage-split) needs.

        ``spec.lifecycle`` makes the population churned: ``depart_frac``
        of the streams departs mid-run, each at a time uniform over
        [``t0``, ``t1``) of the lifecycle window (defaulting to
        [t1, 2*t1) of the arrival window), and ``rejoin_frac`` of the
        departed streams rejoins later.  Lifecycle draws come from a
        dedicated RNG stream, so populations with ``depart_frac=0``
        reproduce their historical arrivals bit-for-bit.

        ``spec.slo.tier_mix`` declares an SLO-tiered population: per-stream
        tiers (guaranteed / standard / best-effort) drawn with the given
        weights from a dedicated RNG stream, so tierless populations
        reproduce their historical draws bit-for-bit.  ``supernet_frac``
        swaps that fraction of stream heads (index-strided, no RNG) onto
        the OFA supernet so the SLO degradation ladder has variant rungs
        to act on; ``spec.genai.frac`` does the same onto the chat_llm
        autoregressive family (and wins on stride collisions)."""
        if isinstance(spec, FuzzSpec):
            if seed is not None or kw:
                raise ScenarioError(
                    "fuzz_streams(FuzzSpec) takes no further arguments")
            return self._fuzz_streams_impl(spec)
        warnings.warn(
            "FleetScenarioBuilder.fuzz_streams(n_streams, seed, **kwargs) "
            "is deprecated; pass a repro.cluster.FuzzSpec instead",
            DeprecationWarning, stacklevel=2)
        if seed is None:
            raise ScenarioError("legacy fuzz_streams needs (n_streams, seed)")
        return self._fuzz_streams_impl(_legacy_fuzz_spec(spec, seed, **kw))

    def _fuzz_streams_impl(self, spec: "FuzzSpec") -> list[int]:
        cas, life, slo, genai = (spec.cascade, spec.lifecycle, spec.slo,
                                 spec.genai)
        n_streams, seed, t0, t1 = spec.n_streams, spec.seed, spec.t0, spec.t1
        if cas.only and not cas.prob > 0.0:
            raise ScenarioError("cascade.only with cascade.prob=0 can "
                                "never admit a stream")
        if not 0.0 <= life.depart_frac <= 1.0 \
                or not 0.0 <= life.rejoin_frac <= 1.0:
            raise ScenarioError(
                "depart_frac / rejoin_frac must be in [0, 1], got "
                f"{life.depart_frac}/{life.rejoin_frac}")
        if not 0.0 <= slo.supernet_frac <= 1.0:
            raise ScenarioError(
                f"supernet_frac must be in [0, 1], got {slo.supernet_frac}")
        if not 0.0 <= genai.frac <= 1.0:
            raise ScenarioError(
                f"genai.frac must be in [0, 1], got {genai.frac}")
        if slo.tier_mix is not None:
            if len(slo.tier_mix) != 3 or any(w < 0 for w in slo.tier_mix) \
                    or not sum(slo.tier_mix) > 0:
                raise ScenarioError(
                    "tier_mix must be three non-negative weights "
                    f"(tier-0, tier-1, best-effort), got {slo.tier_mix!r}")
        stride = (int(round(1.0 / slo.supernet_frac))
                  if slo.supernet_frac > 0 else 0)
        gstride = int(round(1.0 / genai.frac)) if genai.frac > 0 else 0
        rng = np.random.default_rng([seed, 0xF1EE7])
        sids: list[int] = []
        arrivals: list[float] = []
        k = 0
        while len(sids) < n_streams:
            b = fuzz_scenario(seed * 100_003 + k,
                              max_pipelines=cas.max_pipelines,
                              cascade_prob=cas.prob, max_depth=cas.max_depth)
            k += 1
            for pipe in split_pipelines(b):
                if len(sids) >= n_streams:
                    break
                if cas.only and len(pipe) < 2:
                    continue
                for cfg in pipe:
                    if spec.fps_scale != 1.0:
                        cfg["fps"] = float(cfg["fps"]) * spec.fps_scale
                    if spec.deterministic_arrivals:
                        phase = ((len(sids) * 7919) % 97) / 97.0
                        cfg["arrival"] = {"kind": "periodic",
                                          "phase_frac": round(phase, 6)}
                if gstride and len(sids) % gstride == 0:
                    # re-head this stream onto the chat_llm autoregressive
                    # family (keeping the sampled instance name and FPS) —
                    # no RNG, so genai-free populations are byte-identical;
                    # wins over the supernet stride on collisions (chat_llm
                    # carries its own degradation-ladder variants).  Profiles
                    # cycle deterministically so the population mixes short/
                    # medium/long generations: a blind scheduler prices every
                    # one at its cap, a length predictor tells them apart
                    prof = GENAI_PROFILES[(len(sids) // gstride)
                                          % len(GENAI_PROFILES)]
                    pipe[0]["model"] = {"builder": "chat_llm",
                                        "name": pipe[0]["model"]["name"],
                                        "kwargs": dict(prof)}
                elif stride and len(sids) % stride == 0:
                    # re-head this stream onto the OFA supernet (keeping the
                    # sampled instance name and FPS) so the degradation
                    # ladder has variant rungs in the population
                    pipe[0]["model"] = {"builder": "ofa",
                                        "name": pipe[0]["model"]["name"],
                                        "kwargs": {}}
                t = round(float(rng.uniform(t0, t1)), 6)
                sids.append(self.add_stream(pipe, at=t))
                arrivals.append(t)
        if slo.tier_mix is not None:
            # dedicated stream: tier draws must not perturb the arrival/
            # pipeline draws above for tierless populations
            trng = np.random.default_rng([seed, 0x510C1A55])
            total = float(sum(slo.tier_mix))
            c0 = slo.tier_mix[0] / total
            c1 = c0 + slo.tier_mix[1] / total
            payloads = {e.payload["sid"]: e.payload for e in self._events
                        if e.kind == "stream" and e.payload["sid"] in sids}
            for sid in sids:
                u = float(trng.random())
                tier = 0 if u < c0 else (1 if u < c1 else 2)
                payloads[sid]["slo"] = slo_from_config(tier).to_config()
        if life.depart_frac > 0.0:
            # dedicated stream: lifecycle draws must not perturb the
            # arrival/pipeline draws above for depart_frac=0 populations
            lrng = np.random.default_rng([seed, 0xDE9A27])
            d0 = t1 if life.t0 is None else float(life.t0)
            d1 = 2.0 * t1 if life.t1 is None else float(life.t1)
            n_depart = int(round(life.depart_frac * len(sids)))
            leavers = sorted(lrng.choice(len(sids), size=n_depart,
                                         replace=False).tolist())
            for i in leavers:
                # clamp to the arrival: 6-decimal rounding of a draw near
                # the window edge must not put a depart before its stream
                td = max(round(float(lrng.uniform(d0, d1)), 6), arrivals[i])
                self.depart(sids[i], at=td)
                if lrng.random() < life.rejoin_frac and td < d1:
                    self.rejoin(sids[i],
                                at=round(float(lrng.uniform(td, d1)), 6))
        return sids

    # ------------------------------------------------------------- build
    def build(self) -> FleetScenario:
        if not self._node_ids:
            raise ScenarioError(f"fleet scenario {self.name!r} has no nodes")
        if not any(e.kind == "stream" for e in self._events):
            raise ScenarioError(f"fleet scenario {self.name!r} has no streams")
        indexed = sorted(enumerate(self._events),
                         key=lambda p: (p[1].t, p[0]))
        events = tuple(e for _, e in indexed)
        joined: set[int] = set()            # temporal consistency check
        #: per-stream lifecycle state: absent -> present -> departed -> ...
        present: set[int] = set()
        departed: set[int] = set()
        for e in events:
            if e.kind == "node_join":
                joined.add(e.payload["node"])
            elif e.kind in ("node_leave", "node_drain"):
                if e.payload["node"] not in joined:
                    raise ScenarioError(
                        f"{e.kind} of node {e.payload['node']} at t={e.t} "
                        "precedes its join")
            elif e.kind == "stream":
                present.add(e.payload["sid"])
            elif e.kind == "depart":
                sid = e.payload["sid"]
                if sid not in present:
                    raise ScenarioError(
                        f"depart of stream {sid} at t={e.t} precedes its "
                        "arrival" if sid not in departed else
                        f"stream {sid} departs twice without a rejoin "
                        f"(second depart at t={e.t})")
                present.discard(sid)
                departed.add(sid)
            elif e.kind == "rejoin":
                sid = e.payload["sid"]
                if sid not in departed:
                    raise ScenarioError(
                        f"rejoin of stream {sid} at t={e.t} has no "
                        "preceding depart")
                departed.discard(sid)
                present.add(sid)
        return FleetScenario(name=self.name, events=events)
