"""Fleet scenarios: node membership + stream arrivals as declarative data.

A :class:`FleetScenario` is an ordered list of timed fleet events — nodes
joining/leaving/draining, streams arriving, *departing and rejoining*
(the full task lifecycle: RTMM tasks stop when the user's context
changes, not only start), fleet-level phase events (stream-addressed
workload mutations such as diurnal load shifts) —
exactly the external input a multi-node deployment sees.  The builder shards existing single-node
workload definitions across the fleet: a registry scenario or a fuzzer
sample splits into its independent pipelines (a head model plus its
cascade children), each becoming one routable stream whose stages the
stage-split router may later place on different nodes.

Invariants:

  * everything is plain data (``to_config``/``from_config``): fleet
    scenarios serialize, and fleet traces can embed the streams they
    placed;
  * every stream starts with a head entry and names its models explicitly
    (serializable ModelRefs) — the fleet's placement-generation
    namespacing needs stable base names;
  * ``build()`` enforces temporal consistency (no drain/leave before the
    node's join) and sorts events by (time, declaration order);
  * fuzzed populations are deterministic at build time — the resulting
    FleetScenario needs no runtime randomness.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.scenarios.builder import ModelEntry, ScenarioBuilder, ScenarioError
from repro.scenarios.fuzzer import fuzz_scenario

from .slo import slo_from_config


@dataclass(frozen=True)
class FleetEvent:
    """One timed fleet-level event (serializable kind + payload)."""

    t: float
    #: node_join | node_leave | node_drain | stream | depart | rejoin | phase
    kind: str
    payload: dict

    def to_config(self) -> dict:
        return {"t": self.t, "kind": self.kind, **self.payload}

    @classmethod
    def from_config(cls, cfg: dict) -> "FleetEvent":
        d = dict(cfg)
        return cls(t=float(d.pop("t")), kind=d.pop("kind"), payload=d)


@dataclass(frozen=True)
class FleetScenario:
    """A full fleet workload: membership churn + stream arrivals."""

    name: str
    events: tuple[FleetEvent, ...]      # sorted by (t, declaration order)

    def to_config(self) -> dict:
        return {"name": self.name,
                "events": [e.to_config() for e in self.events]}

    @classmethod
    def from_config(cls, cfg: dict) -> "FleetScenario":
        return cls(name=cfg["name"],
                   events=tuple(FleetEvent.from_config(e)
                                for e in cfg["events"]))

    @property
    def n_nodes(self) -> int:
        return sum(1 for e in self.events if e.kind == "node_join")

    @property
    def n_streams(self) -> int:
        return sum(1 for e in self.events if e.kind == "stream")


def split_pipelines(builder: ScenarioBuilder) -> list[list[dict]]:
    """Shard a scenario into its independent pipelines (head + cascade
    children), as lists of serialized ModelEntry configs, head first.
    Cross-pipeline dependencies cannot exist (the scenario builder only
    allows forward references), so pipelines route independently."""
    builder.validate()
    pipelines: list[list[dict]] = []
    owner: dict[str, int] = {}      # model name -> pipeline index
    for entry in builder.entries:
        cfg = entry.to_config()
        # pin the effective instance name so fleet namespacing is stable
        cfg["model"]["name"] = entry.model_name
        if entry.depends_on is None:
            owner[entry.model_name] = len(pipelines)
            pipelines.append([cfg])
        else:
            pidx = owner[entry.depends_on]
            owner[entry.model_name] = pidx
            pipelines[pidx].append(cfg)
    return pipelines


class FleetScenarioBuilder:
    """Fluent builder for fleet scenarios."""

    def __init__(self, name: str):
        self.name = name
        self._events: list[FleetEvent] = []
        self._next_node = 0
        self._next_sid = 0
        self._node_ids: set[int] = set()

    # -------------------------------------------------------- membership
    def node(self, system: str = "4K_1WS2OS", at: float = 0.0) -> int:
        """Declare a node joining the fleet at time ``at`` (a Table-2
        system name). Returns its node id."""
        nid = self._next_node
        self._next_node += 1
        self._node_ids.add(nid)
        self._events.append(FleetEvent(float(at), "node_join",
                                       {"node": nid, "system": system}))
        return nid

    def node_leave(self, node_id: int, at: float) -> "FleetScenarioBuilder":
        """Abrupt departure: the node stops at ``at``; its streams migrate,
        jobs in flight there are lost."""
        self._check_node(node_id)
        self._events.append(FleetEvent(float(at), "node_leave",
                                       {"node": node_id}))
        return self

    def node_drain(self, node_id: int, at: float) -> "FleetScenarioBuilder":
        """Graceful departure: streams migrate away at ``at`` and the node
        stops accepting placements, but keeps executing its queue."""
        self._check_node(node_id)
        self._events.append(FleetEvent(float(at), "node_drain",
                                       {"node": node_id}))
        return self

    def _check_node(self, node_id: int) -> None:
        if node_id not in self._node_ids:
            raise ScenarioError(f"unknown fleet node id {node_id}")

    # ------------------------------------------------------------- phases
    #: fleet-level phase-action kinds: mutations that apply uniformly to a
    #: *stream* (every stage of it, wherever placed).  Model-addressed
    #: actions (set_fps, set_trigger_prob, join, leave) stay node-local —
    #: their model names are namespaced per placement, which a scenario
    #: cannot know ahead of routing.
    FLEET_PHASE_KINDS = ("scale_fps",)

    def phase(self, action, at: float,
              sids: "list[int] | None" = None) -> "FleetScenarioBuilder":
        """A timed fleet-level workload mutation: apply ``action`` (a
        ``repro.scenarios.phases.PhaseAction`` or its config dict) to the
        streams in ``sids`` (None = every stream declared so far) at time
        ``at``.  The fleet forwards the action to each targeted stream's
        hosting node(s), re-arms the touched nodes' (alpha, beta) probes,
        and — under a tuned router — re-arms the fleet weight tuner: a
        phase event is a workload change by definition."""
        cfg = action if isinstance(action, dict) else action.to_config()
        if cfg.get("kind") not in self.FLEET_PHASE_KINDS:
            raise ScenarioError(
                f"fleet phase supports kinds {self.FLEET_PHASE_KINDS}, "
                f"got {cfg.get('kind')!r}")
        if cfg.get("models") is not None:
            raise ScenarioError("fleet phase actions target streams via "
                                "`sids`, not model names (placement "
                                "namespacing owns the names)")
        if sids is not None:
            unknown = [s for s in sids if not 0 <= s < self._next_sid]
            if unknown:
                raise ScenarioError(f"phase targets unknown stream ids "
                                    f"{unknown}")
            sids = [int(s) for s in sids]
        payload: dict = {"action": dict(cfg)}
        if sids is not None:
            payload["sids"] = sids
        self._events.append(FleetEvent(float(at), "phase", payload))
        return self

    # --------------------------------------------------- stream lifecycle
    def depart(self, sid: int, at: float) -> "FleetScenarioBuilder":
        """Stream ``sid`` departs at ``at`` — the load-release half of
        task-level dynamicity: the user's context changed and the task
        stopped.  The fleet evicts the stream from its hosting node(s),
        purges its queued (not-yet-running) frames from the backlog
        without counting them against UXCost, and re-arms the touched
        nodes' probes and the fleet weight tuner.  ``build()`` validates
        ordering: a depart must follow the stream's arrival (and any
        earlier depart must have been rejoined)."""
        self._check_sid(sid)
        self._events.append(FleetEvent(float(at), "depart", {"sid": sid}))
        return self

    def rejoin(self, sid: int, at: float) -> "FleetScenarioBuilder":
        """A departed stream returns at ``at`` with its recorded pipeline
        definition: the router re-places it (fresh placement generation)
        exactly like a new arrival.  Must follow a ``depart`` of the same
        stream (validated by ``build()``)."""
        self._check_sid(sid)
        self._events.append(FleetEvent(float(at), "rejoin", {"sid": sid}))
        return self

    def _check_sid(self, sid: int) -> None:
        if not 0 <= sid < self._next_sid:
            raise ScenarioError(f"unknown stream id {sid}")

    # ----------------------------------------------------------- streams
    def add_stream(self, entries: "list[dict] | list[ModelEntry]",
                   at: float = 0.0, slo: "int | dict | None" = None) -> int:
        """One routable stream: a pipeline of ModelEntry configs (head
        first).  ``slo`` optionally declares the stream's service tier (a
        bare tier number or an SLO config dict — see
        :mod:`repro.cluster.slo`); validated here, carried in the event
        payload, and omitted entirely for tierless streams so legacy
        scenarios and traces stay byte-stable.  Returns the stream id."""
        cfgs = []
        for e in entries:
            cfg = e.to_config() if isinstance(e, ModelEntry) else dict(e)
            if cfg.get("model", {}).get("name") is None:
                raise ScenarioError("fleet stream entries need explicit "
                                    "model names (serializable ModelRefs)")
            cfgs.append(cfg)
        if not cfgs:
            raise ScenarioError("fleet stream has no entries")
        if cfgs[0].get("depends_on") is not None:
            raise ScenarioError("fleet stream must start with a head entry")
        sid = self._next_sid
        self._next_sid += 1
        payload: dict = {"sid": sid, "entries": cfgs}
        if slo is not None:
            payload["slo"] = slo_from_config(slo).to_config()
        self._events.append(FleetEvent(float(at), "stream", payload))
        return sid

    def add_scenario(self, builder: ScenarioBuilder,
                     at: float = 0.0) -> list[int]:
        """Shard a whole single-node scenario into per-pipeline streams."""
        return [self.add_stream(p, at=at) for p in split_pipelines(builder)]

    def fuzz_streams(self, n_streams: int, seed: int, t0: float = 0.0,
                     t1: float = 1.0, max_pipelines: int = 1,
                     fps_scale: float = 1.0, cascade_prob: float = 0.5,
                     max_depth: int = 2, cascades_only: bool = False,
                     deterministic_arrivals: bool = False,
                     depart_frac: float = 0.0, rejoin_frac: float = 0.0,
                     t_depart0: "float | None" = None,
                     t_depart1: "float | None" = None,
                     tier_mix: "tuple[float, float, float] | None" = None,
                     supernet_frac: float = 0.0) -> list[int]:
        """Seeded stream population: fuzzer-sampled pipelines with arrival
        times uniform over [t0, t1).  Deterministic at build time, so the
        resulting FleetScenario needs no runtime randomness.

        ``fps_scale`` rescales every stream's FPS targets: the fuzzer pools
        are sized for one pipeline per multi-accelerator node, while a fleet
        serves *many* light streams per node — ~0.25 puts a 12-streams-per-
        node fleet near 50% offered utilization.

        ``cascade_prob`` / ``max_depth`` thread to the fuzzer (cascade
        sharding specs: 1.0 / 3 yields a cascade-heavy population whose
        pipelines the stage-split router can shard across nodes);
        ``cascades_only`` additionally drops single-stage pipelines, so
        every admitted stream has at least one cross-placeable edge.

        ``deterministic_arrivals`` replaces every sampled arrival process
        with an explicitly-phased periodic one (phase hashed from the
        stream id).  Stochastic arrival processes draw from a *per-node*
        RNG in event order, so their realizations depend on which streams
        share a node — pinning them makes the offered workload identical
        across placement policies, which is what a fair routing comparison
        (e.g. whole-pipeline vs stage-split) needs.

        ``depart_frac`` makes the population *lifecycle-churned*: that
        fraction of streams departs mid-run, each at a time uniform over
        [``t_depart0``, ``t_depart1``) (defaulting to [t1, 2*t1) — after
        the arrival window), and ``rejoin_frac`` of the departed streams
        rejoins later, uniform over (depart time, ``t_depart1``).
        Lifecycle draws come from a dedicated RNG stream, so populations
        with ``depart_frac=0`` reproduce their historical arrivals
        bit-for-bit.

        ``tier_mix`` declares an SLO-tiered population: per-stream tiers
        (guaranteed / standard / best-effort) drawn with the given weights
        from a dedicated RNG stream, so tierless populations (``None``)
        reproduce their historical draws bit-for-bit.  ``supernet_frac``
        swaps that fraction of stream heads (index-strided, no RNG) onto
        the OFA supernet so the SLO degradation ladder has variant rungs
        to act on."""
        if cascades_only and not cascade_prob > 0.0:
            raise ScenarioError("cascades_only with cascade_prob=0 can "
                                "never admit a stream")
        if not 0.0 <= depart_frac <= 1.0 or not 0.0 <= rejoin_frac <= 1.0:
            raise ScenarioError("depart_frac / rejoin_frac must be in "
                                f"[0, 1], got {depart_frac}/{rejoin_frac}")
        if not 0.0 <= supernet_frac <= 1.0:
            raise ScenarioError(
                f"supernet_frac must be in [0, 1], got {supernet_frac}")
        if tier_mix is not None:
            if len(tier_mix) != 3 or any(w < 0 for w in tier_mix) \
                    or not sum(tier_mix) > 0:
                raise ScenarioError(
                    "tier_mix must be three non-negative weights "
                    f"(tier-0, tier-1, best-effort), got {tier_mix!r}")
        stride = int(round(1.0 / supernet_frac)) if supernet_frac > 0 else 0
        rng = np.random.default_rng([seed, 0xF1EE7])
        sids: list[int] = []
        arrivals: list[float] = []
        k = 0
        while len(sids) < n_streams:
            b = fuzz_scenario(seed * 100_003 + k, max_pipelines=max_pipelines,
                              cascade_prob=cascade_prob, max_depth=max_depth)
            k += 1
            for pipe in split_pipelines(b):
                if len(sids) >= n_streams:
                    break
                if cascades_only and len(pipe) < 2:
                    continue
                for cfg in pipe:
                    if fps_scale != 1.0:
                        cfg["fps"] = float(cfg["fps"]) * fps_scale
                    if deterministic_arrivals:
                        phase = ((len(sids) * 7919) % 97) / 97.0
                        cfg["arrival"] = {"kind": "periodic",
                                          "phase_frac": round(phase, 6)}
                if stride and len(sids) % stride == 0:
                    # re-head this stream onto the OFA supernet (keeping the
                    # sampled instance name and FPS) so the degradation
                    # ladder has variant rungs in the population
                    pipe[0]["model"] = {"builder": "ofa",
                                        "name": pipe[0]["model"]["name"],
                                        "kwargs": {}}
                t = round(float(rng.uniform(t0, t1)), 6)
                sids.append(self.add_stream(pipe, at=t))
                arrivals.append(t)
        if tier_mix is not None:
            # dedicated stream: tier draws must not perturb the arrival/
            # pipeline draws above for tierless populations
            trng = np.random.default_rng([seed, 0x510C1A55])
            total = float(sum(tier_mix))
            c0 = tier_mix[0] / total
            c1 = c0 + tier_mix[1] / total
            payloads = {e.payload["sid"]: e.payload for e in self._events
                        if e.kind == "stream" and e.payload["sid"] in sids}
            for sid in sids:
                u = float(trng.random())
                tier = 0 if u < c0 else (1 if u < c1 else 2)
                payloads[sid]["slo"] = slo_from_config(tier).to_config()
        if depart_frac > 0.0:
            # dedicated stream: lifecycle draws must not perturb the
            # arrival/pipeline draws above for depart_frac=0 populations
            lrng = np.random.default_rng([seed, 0xDE9A27])
            d0 = t1 if t_depart0 is None else float(t_depart0)
            d1 = 2.0 * t1 if t_depart1 is None else float(t_depart1)
            n_depart = int(round(depart_frac * len(sids)))
            leavers = sorted(lrng.choice(len(sids), size=n_depart,
                                         replace=False).tolist())
            for i in leavers:
                # clamp to the arrival: 6-decimal rounding of a draw near
                # the window edge must not put a depart before its stream
                td = max(round(float(lrng.uniform(d0, d1)), 6), arrivals[i])
                self.depart(sids[i], at=td)
                if lrng.random() < rejoin_frac and td < d1:
                    self.rejoin(sids[i],
                                at=round(float(lrng.uniform(td, d1)), 6))
        return sids

    # ------------------------------------------------------------- build
    def build(self) -> FleetScenario:
        if not self._node_ids:
            raise ScenarioError(f"fleet scenario {self.name!r} has no nodes")
        if not any(e.kind == "stream" for e in self._events):
            raise ScenarioError(f"fleet scenario {self.name!r} has no streams")
        indexed = sorted(enumerate(self._events),
                         key=lambda p: (p[1].t, p[0]))
        events = tuple(e for _, e in indexed)
        joined: set[int] = set()            # temporal consistency check
        #: per-stream lifecycle state: absent -> present -> departed -> ...
        present: set[int] = set()
        departed: set[int] = set()
        for e in events:
            if e.kind == "node_join":
                joined.add(e.payload["node"])
            elif e.kind in ("node_leave", "node_drain"):
                if e.payload["node"] not in joined:
                    raise ScenarioError(
                        f"{e.kind} of node {e.payload['node']} at t={e.t} "
                        "precedes its join")
            elif e.kind == "stream":
                present.add(e.payload["sid"])
            elif e.kind == "depart":
                sid = e.payload["sid"]
                if sid not in present:
                    raise ScenarioError(
                        f"depart of stream {sid} at t={e.t} precedes its "
                        "arrival" if sid not in departed else
                        f"stream {sid} departs twice without a rejoin "
                        f"(second depart at t={e.t})")
                present.discard(sid)
                departed.add(sid)
            elif e.kind == "rejoin":
                sid = e.payload["sid"]
                if sid not in departed:
                    raise ScenarioError(
                        f"rejoin of stream {sid} at t={e.t} has no "
                        "preceding depart")
                departed.discard(sid)
                present.add(sid)
        return FleetScenario(name=self.name, events=events)
