"""Fleet trace: the fleet run's external input + routing decisions, JSONL.

This module owns the on-disk contract of a fleet run.  It layers on
:mod:`repro.scenarios.trace` (same container, same JSONL conventions,
``sort_keys`` bytes-stable lines) with fleet-level event kinds.  A fleet
trace records, in processing order:

    {"type": "meta", "kind": "fleet", "version": 1, "seed": ..., ...}
    {"type": "node_join",  "t": 0.0, "node": 0, "system": "4K_2WS"}
    {"type": "stream",     "t": 0.3, "sid": 4, "entries": [...]}
    {"type": "place",      "t": 0.3, "sid": 4, "node": 2, "gen": 0}
    {"type": "node_drain", "t": 1.0, "node": 1}
    {"type": "migrate",    "t": 1.0, "sid": 3, "from": 1, "to": 0, "gen": 1}
    {"type": "depart",     "t": 1.2, "sid": 4, "purged": 3}
    {"type": "rejoin",     "t": 1.4, "sid": 4}
    {"type": "place",      "t": 1.4, "sid": 4, "node": 0, "gen": 1}
    {"type": "node_leave", "t": 1.5, "node": 3}

Stream lifecycle records: ``depart`` is an *input* (re-applied on replay
— the eviction and backlog purge re-derive identically; the recorded
``purged`` count only documents what the live run discarded), and
``rejoin`` is an input whose re-placement *decisions* follow as ordinary
generation-bumped ``place`` records, so replay bypasses the router for
rejoins exactly as it does for arrivals.

Stage-split runs (``FleetSimulator(split_stages=True)``) additionally carry
a ``"stage"`` index on ``place``/``migrate`` events, and migrations under a
transfer model carry the exact charge the live run paid:

    {"type": "place",   "t": 0.3, "sid": 4, "stage": 1, "node": 5, "gen": 0}
    {"type": "migrate", "t": 1.0, "sid": 3, "stage": 0, "from": 1, "to": 0,
     "gen": 1, "xfer_s": 0.0082, "xfer_j": 3.1e-4}

Fleet phase events (workload mutations, e.g. diurnal load shifts) and
online-tuner decisions are first-class records too:

    {"type": "phase", "t": 1.2, "action": {"kind": "scale_fps",
     "factor": 2.5, "models": null}, "sids": [0, 1, 2]}
    {"type": "tune",  "t": 1.5, "weights": [1.0, 0.62, 0.2, 0.15, 8.0],
     "window_uxcost": 41.2, "probing": true}

Phase events are *inputs* — replay re-applies them to the hosting nodes.
Tune events are recorded *decisions*: replay installs the recorded weight
vector directly and never constructs telemetry or steps the probe, so a
tuned run replays bit-exactly even though the tuner consumed an RNG
stream live (see ``docs/traces.md``).

SLO-subsystem records: tiered streams carry their class on the arrival
record (``"slo"``, omitted for tierless streams — legacy traces stay
byte-stable), and the admission controller's decisions are recorded as
``swap`` (degradation-ladder variant moves) and ``reject`` (refused
placements) so replay bypasses the controller entirely:

    {"type": "stream", "t": 0.3, "sid": 4, "entries": [...],
     "slo": {"tier": 2}}
    {"type": "swap",   "t": 0.9, "sid": 4, "level": 2, "pressure": 0.97}
    {"type": "reject", "t": 1.1, "sid": 7, "tier": 2, "pressure": 1.12}

The meta line carries ``"transfer"`` (the exact TransferModel parameters)
and ``"split"`` when stage splitting was live; replay reconstructs the
model from meta and re-derives every charge through the same code path,
so a trace stays exact even if the *default* transfer constants change
later.  The per-migration ``xfer_s``/``xfer_j`` fields document what the
live run paid (and are asserted in tests); legacy whole-stream traces are
byte-identical to the PR-2 format.

Invariant: because placements *and* migrations are recorded (not just the
inputs), replay bypasses the router entirely — a 16-node/1000-stream run
reproduces bit-exactly (same per-node simulators, same jobs, same fleet
UXCost) regardless of later routing-policy changes.  Cross-node cascade
triggers are deliberately NOT recorded: they are deterministic internal
dynamics (a dedicated fleet trigger RNG + the deterministic interleaved
clock), fully determined by the recorded placements.
"""
from __future__ import annotations

from typing import Optional

from repro.scenarios import trace as base

FLEET_TRACE_VERSION = 1
FLEET_EVENT_KINDS = ("node_join", "node_leave", "node_drain",
                     "stream", "depart", "rejoin",
                     "place", "migrate", "phase", "tune",
                     "swap", "reject")


class FleetTrace(base.Trace):
    """A recorded fleet run (meta + ordered fleet events)."""

    def events_of(self, kind: str) -> list[dict]:
        return [e for e in self.events if e["type"] == kind]

    @property
    def placements(self) -> list[dict]:
        return self.events_of("place")

    @property
    def migrations(self) -> list[dict]:
        return self.events_of("migrate")


class FleetTraceRecorder:
    """Collects fleet events in processing order during a live run."""

    def __init__(self, meta: dict):
        self.meta = dict(meta)
        self.meta.setdefault("version", FLEET_TRACE_VERSION)
        self.meta.setdefault("kind", "fleet")
        self.events: list[dict] = []

    def node_join(self, t: float, node: int, system: str) -> None:
        self.events.append({"type": "node_join", "t": float(t),
                            "node": node, "system": system})

    def node_leave(self, t: float, node: int) -> None:
        self.events.append({"type": "node_leave", "t": float(t),
                            "node": node})

    def node_drain(self, t: float, node: int) -> None:
        self.events.append({"type": "node_drain", "t": float(t),
                            "node": node})

    def stream(self, t: float, sid: int, entries: list[dict],
               slo: Optional[dict] = None) -> None:
        """A stream arrival.  ``slo`` carries the declared SLO class config
        when the stream is tiered; omitted entirely for tierless streams,
        which keeps legacy (pre-SLO) traces byte-stable."""
        ev: dict = {"type": "stream", "t": float(t), "sid": sid,
                    "entries": entries}
        if slo is not None:
            ev["slo"] = dict(slo)
        self.events.append(ev)

    def depart(self, t: float, sid: int, purged: int) -> None:
        """A stream departing (load release).  ``purged`` documents how
        many queued jobs the departure discarded; replay re-derives the
        purge through the same eviction path and ignores the field."""
        self.events.append({"type": "depart", "t": float(t), "sid": sid,
                            "purged": int(purged)})

    def rejoin(self, t: float, sid: int) -> None:
        """A departed stream returning; the re-placement decisions follow
        as ordinary ``place`` records (generation-bumped)."""
        self.events.append({"type": "rejoin", "t": float(t), "sid": sid})

    def place(self, t: float, sid: int, node: int, gen: int,
              stage: Optional[int] = None) -> None:
        ev = {"type": "place", "t": float(t), "sid": sid,
              "node": node, "gen": gen}
        if stage is not None:
            ev["stage"] = stage
        self.events.append(ev)

    def migrate(self, t: float, sid: int, src: int, dst: int, gen: int,
                stage: Optional[int] = None,
                xfer_s: Optional[float] = None,
                xfer_j: Optional[float] = None) -> None:
        ev = {"type": "migrate", "t": float(t), "sid": sid,
              "from": src, "to": dst, "gen": gen}
        if stage is not None:
            ev["stage"] = stage
        if xfer_s is not None:
            ev["xfer_s"] = float(xfer_s)
        if xfer_j is not None:
            ev["xfer_j"] = float(xfer_j)
        self.events.append(ev)

    def phase(self, t: float, action: dict,
              sids: "Optional[list[int]]" = None) -> None:
        """A fleet-level phase event (workload mutation): the serialized
        PhaseAction config plus the targeted stream ids (None = all)."""
        ev: dict = {"type": "phase", "t": float(t), "action": dict(action)}
        if sids is not None:
            ev["sids"] = list(sids)
        self.events.append(ev)

    def tune(self, t: float, weights: "list[float]",
             window_uxcost: float, probing: bool) -> None:
        """A tuner decision: the full weight vector committed for the next
        telemetry window (``repro.cluster.router.WEIGHT_NAMES`` order).
        Replay installs these weights directly, bypassing telemetry and
        probe entirely; ``window_uxcost`` (the measurement that produced
        the decision) and ``probing`` document the tuner state."""
        self.events.append({
            "type": "tune", "t": float(t),
            "weights": [float(w) for w in weights],
            "window_uxcost": float(window_uxcost),
            "probing": bool(probing),
        })

    def swap(self, t: float, sid: int, level: int,
             pressure: Optional[float] = None) -> None:
        """An SLO degradation-ladder decision: stream ``sid`` moves to
        supernet-variant ``level`` (0 = full quality; k = k-th variant,
        heavier->lighter).  Replay applies the recorded level directly and
        never runs the admission controller; ``pressure`` documents the
        admission-law scalar that drove the move."""
        ev: dict = {"type": "swap", "t": float(t), "sid": sid,
                    "level": int(level)}
        if pressure is not None:
            ev["pressure"] = float(pressure)
        self.events.append(ev)

    def reject(self, t: float, sid: int, tier: int,
               pressure: Optional[float] = None) -> None:
        """An admission rejection: stream ``sid`` (service tier ``tier``)
        was refused placement — a first-class outcome that charges the
        stream's expected frames as deadline violations into the fleet
        UXCost.  Replay applies the rejection directly."""
        ev: dict = {"type": "reject", "t": float(t), "sid": sid,
                    "tier": int(tier)}
        if pressure is not None:
            ev["pressure"] = float(pressure)
        self.events.append(ev)

    def trace(self) -> FleetTrace:
        return FleetTrace(meta=dict(self.meta), events=list(self.events))


def dumps(trace: FleetTrace) -> str:
    return base.dumps(trace)


def loads(text: str) -> FleetTrace:
    t = base.loads(text, event_kinds=FLEET_EVENT_KINDS,
                   version=FLEET_TRACE_VERSION)
    if t.meta.get("kind") != "fleet":
        raise ValueError("not a fleet trace (meta.kind != 'fleet')")
    return FleetTrace(meta=t.meta, events=t.events)


def save_trace(trace: FleetTrace, path: str) -> str:
    with open(path, "w") as f:
        f.write(dumps(trace))
    return path


def load_trace(path: str) -> FleetTrace:
    with open(path) as f:
        return loads(f.read())
