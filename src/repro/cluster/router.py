"""Global admission/routing policies: which node serves a new stream, and —
when stage splitting is enabled — which node serves each *stage* of it.

The router sees only aggregated telemetry (:class:`~.node.NodeTelemetry`)
plus per-(stream, node) cost summaries from the memoized offline tables —
never per-job state — so the same policies port to a real deployment where
nodes export a handful of gauges.

Policies:

  * ``round_robin``   — cycle over live nodes; the fleet baseline.
  * ``least_loaded``  — minimize post-placement offered utilization.
  * ``score``         — DREAM-Fleet: a MapScore-analogue at node granularity
    combining load, hardware preference (how well the stream's models suit
    the node's WS/OS accelerator mix, weighted by deadline urgency) and the
    node's recent UXCost-window health.
  * ``tuned_score``   — the same score with weights *learned online*: a
    coordinate probe over weight multipliers, fed by fleet telemetry
    windows (see ``repro.cluster.telemetry``), re-armed on membership
    churn and phase events — the paper's tunable-parameter adaptivity
    lifted to the fleet layer.

Stage-level placement (``place_stages``) splits a cascade pipeline across
nodes: the score policy places stages greedily in pipeline order, charging
a transfer-cost penalty (activation bytes over the inter-node link, from
:class:`repro.core.costmodel.TransferModel`) whenever a cascade edge would
cross nodes.  With zero bandwidth the penalty is infinite and placement
degenerates to whole-pipeline.  Policies without stage awareness co-locate
every stage on the whole-stream choice.

All policies are deterministic: ties break on node id, and the round-robin
cursor is part of the policy state (reconstructed identically on replay —
though replay short-circuits routing entirely via recorded placements).
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

from .node import FleetNode, StreamCost


def argmin_node(nodes: Sequence[FleetNode], score_fn) -> int:
    """Node id minimizing ``score_fn(node)``, ties to the lower node id —
    the one argmin loop every placement path shares."""
    best_id, best_key = nodes[0].node_id, None
    for node in nodes:
        key = (score_fn(node), node.node_id)
        if best_key is None or key < best_key:
            best_id, best_key = node.node_id, key
    return best_id


class _BatchInputs:
    """Per-node cost/telemetry columns for one placement decision, gathered
    in candidate order.  One Python pass over the nodes fills the columns;
    everything downstream (terms, scores, argmin) is a handful of (N,)
    numpy ops regardless of fleet size.  Values are the exact same floats
    the scalar path reads — ``cost_on``/``telemetry`` are memoized, so the
    gather is dict lookups, not recomputation."""

    __slots__ = ("ids", "iso", "offered", "urgency", "offered_util",
                 "n_accs", "backlog", "dlv", "bf")

    def __init__(self, stream, nodes: Sequence[FleetNode],
                 stage: Optional[int] = None):
        self.bf = getattr(stream, "budget_factor", 1.0)
        cols = getattr(nodes, "tel_columns", None)
        if cols is not None:
            # fleet-maintained SoA columns: telemetry rows are already
            # flat arrays (dirty-refreshed from the same memoized
            # telemetry() snapshots), and cost columns fill with ONE
            # cost_on per distinct accelerator mix via the system groups
            c = cols()
            n = len(nodes)
            self.ids = c["ids"]
            self.offered_util = c["offered_util"]
            self.n_accs = c["n_accs"]
            self.backlog = c["backlog"]
            self.dlv = c["dlv"]
            self.iso = np.empty(n)
            self.offered = np.empty(n)
            self.urgency = np.empty(n)
            for node, ix in c["groups"]:
                sc = (stream.cost_on(node) if stage is None
                      else stream.stage_cost_on(node, stage))
                self.iso[ix] = sc.iso_s
                self.offered[ix] = sc.offered_s
                self.urgency[ix] = sc.urgency
            return
        # costs depend only on the node's accelerator mix: resolve each
        # distinct system once, then map nodes onto the shared StreamCost
        # (the exact objects the scalar path's memoized cost_on returns)
        cost_of: dict = {}
        costs = []
        for node in nodes:
            key = (node.system if node.system != "custom"
                   else ("node", node.node_id))
            c = cost_of.get(key)
            if c is None:
                c = (stream.cost_on(node) if stage is None
                     else stream.stage_cost_on(node, stage))
                cost_of[key] = c
            costs.append(c)
        tels = [node.telemetry() for node in nodes]
        self.ids = np.array([node.node_id for node in nodes], dtype=np.int64)
        self.iso = np.array([c.iso_s for c in costs])
        self.offered = np.array([c.offered_s for c in costs])
        self.urgency = np.array([c.urgency for c in costs])
        self.offered_util = np.array([t.offered_util for t in tels])
        self.n_accs = np.array([float(t.n_accs) for t in tels])
        self.backlog = np.array([t.backlog_s for t in tels])
        self.dlv = np.array([t.window_dlv for t in tels])

    def best_iso(self) -> float:
        """``min`` over the iso column — bit-equal to the scalar genexpr
        ``min(stream.cost_on(n).iso_s for n in nodes)`` (min is exact)."""
        return float(self.iso.min())


class RouterPolicy:
    """Placement policy plug-in: pick a node id for a candidate stream."""

    name = "base"
    #: whether place_stages may put stages of one stream on different
    #: nodes; non-splitting policies also migrate and rebalance streams as
    #: co-located units
    splits_stages = False

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        """Return the node_id to host ``stream`` (a StreamView).  ``nodes``
        is the list of live, non-draining nodes, sorted by node_id."""
        raise NotImplementedError

    def place_stages(self, stream, nodes: Sequence[FleetNode],
                     transfer) -> list[int]:
        """Per-stage placement: node_id for each pipeline stage of
        ``stream`` (a StreamView), head first.  The default co-locates all
        stages on the whole-stream ``place`` choice; stage-aware policies
        override to split cascades when the transfer economics justify it."""
        del transfer
        return [self.place(stream, nodes)] * stream.n_stages


class RoundRobinRouter(RouterPolicy):
    name = "round_robin"

    def __init__(self) -> None:
        self._cursor = 0

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        node = nodes[self._cursor % len(nodes)]
        self._cursor += 1
        return node.node_id


class LeastLoadedRouter(RouterPolicy):
    """Minimize the node's offered utilization after placement."""

    name = "least_loaded"

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        best_id, best_key = nodes[0].node_id, None
        for node in nodes:
            tel = node.telemetry()
            cost = stream.cost_on(node)
            after = tel.offered_util + cost.offered_s / tel.n_accs
            key = (after, tel.queue_depth, node.node_id)
            if best_key is None or key < best_key:
                best_id, best_key = node.node_id, key
        return best_id


#: DREAM-Fleet score weights.  Load dominates (an overloaded node violates
#: deadlines no matter how well-matched its dataflows are); the live
#: backlog corrects the static offered-load estimate with what is actually
#: queued; preference is urgency-weighted (tight-deadline streams pay most
#: for a poor hardware match); recent deadline-violation health breaks
#: structural ties toward nodes that are currently delivering.
W_BACKLOG = 0.5
W_PREF = 0.2
W_UX = 0.15
URGENCY_CAP = 4.0
#: weight of the cross-node transfer penalty in stage-level scoring: the
#: per-trigger link time as a fraction of the receiving stage's period,
#: amplified so the router only splits when the hardware-match gain is
#: decisively larger than the wire bill
W_XFER = 8.0

#: the routing weight vector, in canonical order.  ``load`` multiplies the
#: post-placement offered utilization (1.0 statically — the term every
#: other weight is expressed relative to); the rest are the hand-fixed
#: constants above.  ``TunedScoreRouter`` learns multipliers on this
#: vector online from fleet telemetry.
WEIGHT_NAMES = ("load", "backlog", "pref", "ux", "xfer")
STATIC_WEIGHTS = (1.0, W_BACKLOG, W_PREF, W_UX, W_XFER)


class ScoreDrivenRouter(RouterPolicy):
    name = "score"
    splits_stages = True
    #: batched-scoring toggle.  True evaluates all candidate nodes as (N,)
    #: numpy column ops (one gather pass + one argmin); False runs the
    #: original per-node scalar loops, kept alive as the bit-identity
    #: oracle for tests/test_vectorized_equiv.py.  The two paths replicate
    #: each other's float expressions operation-for-operation (the score
    #: is an explicit elementwise weight chain, never a dot product, and
    #: ``np.argmin``'s first-occurrence rule equals the scalar
    #: ``(score, node_id)`` tie-break because candidates arrive sorted by
    #: node id), so flipping the flag never changes a placement.
    vectorized = True
    #: SLO-budget-aware preference weighting.  When on, the urgency that
    #: multiplies the hardware-match penalty is divided by the stream's
    #: declared pipeline-latency budget (in head periods, from its SLO
    #: tier): a best-effort stream with a 4-period budget tolerates a
    #: mediocre hardware match four times as well as a guaranteed-tier
    #: one, so the preference term stops overruling load balance on its
    #: behalf.  Off by default — dividing by the neutral 1.0 factor is
    #: bit-exact, so every recorded trace predating the flag replays
    #: unchanged.
    budget_aware = False

    def __init__(self) -> None:
        (self.w_load, self.w_backlog, self.w_pref, self.w_ux,
         self.w_xfer) = STATIC_WEIGHTS

    @property
    def weights(self) -> tuple[float, ...]:
        """The live weight vector, in ``WEIGHT_NAMES`` order."""
        return (self.w_load, self.w_backlog, self.w_pref, self.w_ux,
                self.w_xfer)

    def set_weights(self, weights: Sequence[float]) -> None:
        """Install a full weight vector (``WEIGHT_NAMES`` order).  Replay
        applies recorded tuner decisions through this, bypassing the tuner."""
        w = [float(x) for x in weights]
        if len(w) != len(WEIGHT_NAMES):
            raise ValueError(f"expected {len(WEIGHT_NAMES)} weights "
                             f"{WEIGHT_NAMES}, got {len(w)}")
        if any(not x >= 0.0 for x in w):
            raise ValueError(f"score weights must be >= 0, got {w}")
        (self.w_load, self.w_backlog, self.w_pref, self.w_ux,
         self.w_xfer) = w

    def _bf(self, stream) -> float:
        """The stream's effective budget divisor: its SLO pipeline budget
        (head periods) when budget-aware routing is on, else the neutral
        1.0 (division by which is an IEEE no-op)."""
        if not self.budget_aware:
            return 1.0
        return getattr(stream, "budget_factor", 1.0)

    def score(self, stream, node: FleetNode,
              best_iso: float) -> float:
        """Lower is better.  ``best_iso`` is the stream's best isolated
        latency across all candidate nodes (preference normalizer)."""
        return self._score(stream.cost_on(node), node, best_iso,
                           bf=self._bf(stream))

    def score_terms(self, cost: StreamCost, node: FleetNode,
                    best_iso: float, tel=None,
                    bf: float = 1.0) -> tuple[float, float, float, float,
                                              float]:
        """The weight-independent factors of the node score, in full
        ``WEIGHT_NAMES`` order: the score is their dot product with the
        live weights, which is what lets the tuner re-score a recorded
        decision under counterfactual weight vectors without re-reading
        any node state.  The transfer column is 0 here — whole-stream
        placements never pay it; stage-level recording fills it with
        :meth:`transfer_term`.  ``tel`` lets a caller that already
        snapshotted the node's telemetry avoid a second walk of its live
        jobs."""
        if tel is None:
            tel = node.telemetry()
        load_after = tel.offered_util + cost.offered_s / tel.n_accs
        pref_penalty = (cost.iso_s / max(best_iso, 1e-12)) - 1.0
        urgency = min(cost.urgency / bf, URGENCY_CAP)
        return (load_after, tel.backlog_s / tel.n_accs,
                pref_penalty * urgency, min(tel.window_dlv, 1.0), 0.0)

    def _score(self, cost: StreamCost, node: FleetNode,
               best_iso: float, bf: float = 1.0) -> float:
        t = self.score_terms(cost, node, best_iso, bf=bf)
        return (self.w_load * t[0] + self.w_backlog * t[1]
                + self.w_pref * t[2] + self.w_ux * t[3])

    # ------------------------------------------------------ batched scoring
    def batch_terms(self, b: _BatchInputs, best_iso: float) -> tuple:
        """The :meth:`score_terms` columns for every candidate at once:
        five (N,) arrays in ``WEIGHT_NAMES`` order plus the marginal
        offered load per node.  Each column replicates the scalar
        expression elementwise — same divisions, same ``min`` clamps
        (``np.minimum``), same subtraction order — so row ``i`` is
        bit-equal to ``score_terms(cost_on(nodes[i]), nodes[i], best_iso)``.
        """
        marginal = b.offered / b.n_accs
        t_load = b.offered_util + marginal
        t_backlog = b.backlog / b.n_accs
        pref_penalty = b.iso / max(best_iso, 1e-12) - 1.0
        bf = b.bf if self.budget_aware else 1.0
        t_pref = pref_penalty * np.minimum(b.urgency / bf, URGENCY_CAP)
        t_ux = np.minimum(b.dlv, 1.0)
        t_xfer = np.zeros(len(b.ids))
        return t_load, t_backlog, t_pref, t_ux, t_xfer, marginal

    def batch_scores(self, b: _BatchInputs, best_iso: float) -> np.ndarray:
        """Scores of one stream (or stage) on every candidate node as an
        (N,) array.  The weight chain is the same explicit elementwise
        expression as :meth:`_score` — deliberately NOT ``terms @ w``,
        whose dot-product reduction may reorder the additions."""
        t_load, t_backlog, t_pref, t_ux, _, _ = self.batch_terms(b, best_iso)
        return (self.w_load * t_load + self.w_backlog * t_backlog
                + self.w_pref * t_pref + self.w_ux * t_ux)

    def score_all(self, stream, nodes: Sequence[FleetNode]) -> np.ndarray:
        """Batched :meth:`score` over ``nodes`` (including the best-iso
        normalizer pass): ``out[i] == self.score(stream, nodes[i],
        best_iso)`` bit-for-bit — the rebalancer's bulk entry point."""
        b = _BatchInputs(stream, nodes)
        return self.batch_scores(b, b.best_iso())

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        if not self.vectorized:
            return self._place_scalar(stream, nodes)
        b = _BatchInputs(stream, nodes)
        s = self.batch_scores(b, b.best_iso())
        # first-occurrence argmin == (score, node_id) tie-break: candidates
        # are sorted by node id
        return int(b.ids[int(np.argmin(s))])

    def _place_scalar(self, stream, nodes: Sequence[FleetNode]) -> int:
        """Scalar reference placement — the oracle for the batched path."""
        best_iso = min(stream.cost_on(n).iso_s for n in nodes)
        return argmin_node(nodes,
                           lambda n: self.score(stream, n, best_iso))

    # ------------------------------------------------------ stage placement
    def transfer_penalty(self, stream, k: int, transfer) -> float:
        """Score penalty for putting stage ``k`` on a different node than
        its parent: the per-trigger transfer latency of the parent's output
        activation, relative to the stage's period (how much of every frame
        interval the wire eats), weighted by ``w_xfer``.  Infinite when the
        transfer model is absent or has zero bandwidth."""
        if transfer is None or not transfer.enabled:
            return float("inf")
        xfer_s = transfer.transfer_s(stream.act_bytes_into(k))
        return self.w_xfer * xfer_s / max(stream.stage_period_s(k), 1e-9)

    def transfer_term(self, stream, k: int, transfer) -> float:
        """The weight-independent factor of the transfer penalty (the
        ``xfer`` column of ``WEIGHT_NAMES``): per-trigger wire time over
        the receiving stage's period.  Infinite when the transfer model is
        absent or has zero bandwidth.  ``transfer_penalty`` is ``w_xfer``
        times this (up to float associativity — live scoring keeps its
        historical expression)."""
        if transfer is None or not transfer.enabled:
            return float("inf")
        xfer_s = transfer.transfer_s(stream.act_bytes_into(k))
        return xfer_s / max(stream.stage_period_s(k), 1e-9)

    def stage_score(self, stream, k: int, node: FleetNode, best_iso: float,
                    parent_nid: Optional[int], transfer) -> float:
        """Score of placing stage ``k`` on ``node`` given the stage's parent
        already landed on ``parent_nid`` (None for heads)."""
        s = self._score(stream.stage_cost_on(node, k), node, best_iso,
                        bf=self._bf(stream))
        if parent_nid is not None and node.node_id != parent_nid:
            s += self.transfer_penalty(stream, k, transfer)
        return s

    def place_stages(self, stream, nodes: Sequence[FleetNode],
                     transfer) -> list[int]:
        """Split-refinement placement: anchor the head on the whole-stream
        ``place`` choice (which prices the full pipeline's load, so heads
        never land somewhere that cannot absorb the children that follow),
        then let each non-head stage peel off to another node only when its
        stage score there beats staying with its parent by more than the
        cascade-edge transfer penalty.  With zero bandwidth the penalty is
        infinite, every stage stays with its parent, and the assignment is
        exactly the whole-pipeline placement."""
        if not self.vectorized:
            return self._place_stages_scalar(stream, nodes, transfer)
        out: list[int] = [self.place(stream, nodes)]
        for k in range(1, stream.n_stages):
            b = _BatchInputs(stream, nodes, stage=k)
            s = self.batch_scores(b, b.best_iso())
            p = stream.parent_of(k)
            parent_nid = out[p] if p is not None else out[0]
            # the penalty is node-independent; adding it to the off-parent
            # rows (a plain elementwise add — inf-safe, nothing multiplies
            # the mask) replicates the scalar `s += transfer_penalty(...)`
            pen = self.transfer_penalty(stream, k, transfer)
            s = np.where(b.ids == parent_nid, s, s + pen)
            out.append(int(b.ids[int(np.argmin(s))]))
        return out

    def _place_stages_scalar(self, stream, nodes: Sequence[FleetNode],
                             transfer) -> list[int]:
        """Scalar reference stage placement — the batched path's oracle."""
        out: list[int] = [self._place_scalar(stream, nodes)]
        for k in range(1, stream.n_stages):
            best_iso = min(stream.stage_cost_on(n, k).iso_s for n in nodes)
            p = stream.parent_of(k)
            parent_nid = out[p] if p is not None else out[0]
            out.append(argmin_node(
                nodes, lambda n: self.stage_score(stream, k, n, best_iso,
                                                  parent_nid, transfer)))
        return out


class WholePipelineScoreRouter(ScoreDrivenRouter):
    """Score-driven placement that never splits: every stage co-locates on
    the whole-stream choice — at admission, at migration, and at
    rebalance (``splits_stages = False`` makes the fleet move and
    rebalance streams as units).  This is the control arm for stage-split
    experiments — identical scoring, telemetry, migration accounting and
    trigger machinery, with placement granularity as the only variable."""

    name = "score_whole"
    splits_stages = False

    def place_stages(self, stream, nodes: Sequence[FleetNode],
                     transfer) -> list[int]:
        return RouterPolicy.place_stages(self, stream, nodes, transfer)


#: multiplier-space bounds of the tuned router's probe: the same
#: constrained [0, 2] box the paper uses for (alpha, beta), applied per
#: weight as a *multiplier* on its static value — so "1.0 everywhere" is
#: exactly the hand-fixed ScoreDrivenRouter, and the tuner can at most
#: double or silence a term.  The load multiplier is floored at 0.25:
#: hindsight scoring rewards routing toward whatever nodes happened to be
#: healthy, and a zero capacity term would let the probe collapse onto
#: them — the floor keeps the static cost model load-bearing.
TUNE_LO = (0.25, 0.0, 0.0, 0.0, 0.0)
TUNE_HI = 2.0
#: coordinate-probe order: the static-estimate term first — under drift
#: the offline offered-load estimate is exactly the signal that goes
#: stale, so rebalancing its weight against the live terms (backlog,
#: health) is where the tuner finds most of its headroom — then hardware
#: preference, the live signals, and the transfer penalty last.
TUNE_AXIS_ORDER = (0, 2, 3, 1, 4)


class TunedScoreRouter(ScoreDrivenRouter):
    """Score-driven routing whose weights are *learned online* from fleet
    telemetry — the fleet-scale analogue of the per-node (alpha, beta)
    probe.

    The weight vector is parameterized as multipliers on
    ``STATIC_WEIGHTS`` searched over a constrained box by a
    :class:`repro.core.adaptivity.CoordinateProbe`.  Candidates are scored
    in *hindsight* against each telemetry window's realized outcomes: the
    router records the weight-independent score terms of every placement
    decision it makes (:meth:`ScoreDrivenRouter.score_terms`), and at each
    window every candidate vector re-picks a node for every recorded
    decision, paying the realized deadline-violation rate
    (``TelemetryWindow.node_dlv`` — the DLV factor of the window's UXCost)
    of the node it would have chosen.  All candidates are judged on the
    *same* window, so cross-window drift cannot bias the comparison, and
    the fleet never deploys an untested candidate — the live router always
    runs the committed center.  The margin-gated best-wins commit
    (``CoordinateProbe.step_batch``) moves the center only on a clear win.

    Windows with zero frames, no recorded decisions, or no violations
    anywhere carry no ranking signal: the router holds its committed
    weights — a fresh tuner therefore behaves exactly like the static
    ``ScoreDrivenRouter`` until telemetry says otherwise.

    The fleet simulator drives the loop (``tune_every_s`` ticks) and
    re-arms the probe on membership churn and phase events
    (:meth:`rearm`), mirroring ``DreamScheduler.retrigger_probe``.  Tuner
    decisions are recorded in the fleet trace so replay bypasses the tuner
    entirely and stays bit-exact.
    """

    name = "tuned_score"
    #: cap on retained decision contexts between windows — far above any
    #: real window's placement count, it only guards the no-tune-ticks
    #: usage from unbounded growth
    MAX_DECISIONS = 4096
    #: optional duck-typed metrics registry (repro.obs.MetricsRegistry),
    #: attached by the fleet when observability is on; publishing is
    #: observation only — nothing the tuner decides reads it back
    metrics = None

    def __init__(self, radius: float = 0.5, r_min: float = 0.08,
                 shrink: float = 0.7, margin: float = 0.3) -> None:
        super().__init__()
        from repro.core.adaptivity import CoordinateProbe
        n = len(STATIC_WEIGHTS)
        self.probe = CoordinateProbe(
            center=np.ones(n), lo=np.asarray(TUNE_LO),
            hi=np.full(n, TUNE_HI), radius=radius, r_min=r_min,
            shrink=shrink, margin=margin, axis_order=TUNE_AXIS_ORDER)
        self.windows_seen = 0
        self.empty_windows = 0
        self.held_windows = 0      # windows with no ranking signal
        #: decision contexts recorded since the last window: (node ids,
        #: terms matrix, marginal offered load per node) per placement
        #: decision, consumed and cleared every window.  Bounded: a tuned
        #: policy driven without tune ticks (tune_every_s unset — legal,
        #: it behaves exactly like the static router) must not accumulate
        #: contexts forever, so only the most recent window-scale batch
        #: is retained.
        self._decisions: "deque[tuple[list[int], np.ndarray, np.ndarray]]" \
            = deque(maxlen=self.MAX_DECISIONS)

    # ------------------------------------------------- decision recording
    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        """Same argmin as the static router, computed from one batched
        pass of score terms — which then double as the recorded decision
        context, so recording costs no extra node scans."""
        if not self.vectorized:
            return self._place_scalar(stream, nodes)
        b = _BatchInputs(stream, nodes)
        (t_load, t_backlog, t_pref, t_ux, t_xfer,
         marginal) = self.batch_terms(b, b.best_iso())
        # same expression order as batch_scores / _score, so the argmin is
        # bit-identical to ScoreDrivenRouter.place
        s = (self.w_load * t_load + self.w_backlog * t_backlog
             + self.w_pref * t_pref + self.w_ux * t_ux)
        self._decisions.append(
            ([int(i) for i in b.ids],
             np.column_stack((t_load, t_backlog, t_pref, t_ux, t_xfer)),
             marginal))
        return int(b.ids[int(np.argmin(s))])

    def _place_scalar(self, stream, nodes: Sequence[FleetNode]) -> int:
        """Scalar reference of the recording placement (test oracle)."""
        best_iso = min(stream.cost_on(n).iso_s for n in nodes)
        bf = self._bf(stream)
        ids: list[int] = []
        rows: list[tuple[float, ...]] = []
        marginal: list[float] = []
        best_nid, best_key = nodes[0].node_id, None
        for n in nodes:
            cost = stream.cost_on(n)
            tel = n.telemetry()
            t = self.score_terms(cost, n, best_iso, tel=tel, bf=bf)
            s = (self.w_load * t[0] + self.w_backlog * t[1]
                 + self.w_pref * t[2] + self.w_ux * t[3])
            key = (s, n.node_id)
            if best_key is None or key < best_key:
                best_nid, best_key = n.node_id, key
            ids.append(n.node_id)
            rows.append(t)
            marginal.append(cost.offered_s / tel.n_accs)
        self._decisions.append((ids, np.asarray(rows),
                                np.asarray(marginal)))
        return best_nid

    #: recorded transfer terms are clamped to this finite cap: a missing /
    #: zero-bandwidth link scores +inf live (the stage stays with its
    #: parent), but an inf left in a recorded context would turn into nan
    #: under a candidate that zeroes the transfer multiplier in hindsight
    XFER_TERM_CAP = 1e9

    def place_stages(self, stream, nodes: Sequence[FleetNode],
                     transfer) -> list[int]:
        """Same split-refinement argmin as the static router, but every
        *stage* decision is recorded too — with the transfer column of the
        terms filled in (:meth:`ScoreDrivenRouter.transfer_term` for
        off-parent nodes, 0 for staying with the parent) — so hindsight
        re-scoring learns ``W_XFER`` from realized outcomes as well, not
        only the whole-stream columns."""
        if not self.vectorized:
            return self._place_stages_scalar(stream, nodes, transfer)
        out: list[int] = [self.place(stream, nodes)]
        for k in range(1, stream.n_stages):
            b = _BatchInputs(stream, nodes, stage=k)
            (t_load, t_backlog, t_pref, t_ux, _,
             marginal) = self.batch_terms(b, b.best_iso())
            s = (self.w_load * t_load + self.w_backlog * t_backlog
                 + self.w_pref * t_pref + self.w_ux * t_ux)
            p = stream.parent_of(k)
            parent_nid = out[p] if p is not None else out[0]
            on_parent = b.ids == parent_nid
            # node-independent penalty/term, added (never multiplied) to
            # the off-parent rows so an infinite penalty stays inf-safe
            pen = self.transfer_penalty(stream, k, transfer)
            s = np.where(on_parent, s, s + pen)
            xfer = min(self.transfer_term(stream, k, transfer),
                       self.XFER_TERM_CAP)
            t_xfer = np.where(on_parent, 0.0, xfer)
            self._decisions.append(
                ([int(i) for i in b.ids],
                 np.column_stack((t_load, t_backlog, t_pref, t_ux, t_xfer)),
                 marginal))
            out.append(int(b.ids[int(np.argmin(s))]))
        return out

    def _place_stages_scalar(self, stream, nodes: Sequence[FleetNode],
                             transfer) -> list[int]:
        """Scalar reference of the recording stage placement (oracle)."""
        out: list[int] = [self._place_scalar(stream, nodes)]
        bf = self._bf(stream)
        for k in range(1, stream.n_stages):
            best_iso = min(stream.stage_cost_on(n, k).iso_s for n in nodes)
            p = stream.parent_of(k)
            parent_nid = out[p] if p is not None else out[0]
            ids: list[int] = []
            rows: list[tuple[float, ...]] = []
            marginal: list[float] = []
            best_nid, best_key = nodes[0].node_id, None
            for n in nodes:
                cost = stream.stage_cost_on(n, k)
                tel = n.telemetry()
                t = self.score_terms(cost, n, best_iso, tel=tel, bf=bf)
                # identical arithmetic to stage_score: 4-term dot product
                # plus the historical transfer_penalty expression
                s = (self.w_load * t[0] + self.w_backlog * t[1]
                     + self.w_pref * t[2] + self.w_ux * t[3])
                xfer = 0.0
                if n.node_id != parent_nid:
                    s += self.transfer_penalty(stream, k, transfer)
                    xfer = min(self.transfer_term(stream, k, transfer),
                               self.XFER_TERM_CAP)
                key = (s, n.node_id)
                if best_key is None or key < best_key:
                    best_nid, best_key = n.node_id, key
                ids.append(n.node_id)
                rows.append(t[:4] + (xfer,))
                marginal.append(cost.offered_s / tel.n_accs)
            self._decisions.append((ids, np.asarray(rows),
                                    np.asarray(marginal)))
            out.append(best_nid)
        return out

    # --------------------------------------------------------- tuner loop
    @property
    def multipliers(self) -> np.ndarray:
        """The live multiplier vector (weights / STATIC_WEIGHTS)."""
        return np.asarray(self.weights) / np.asarray(STATIC_WEIGHTS)

    def _apply(self, mult: np.ndarray) -> None:
        self.set_weights([m * w for m, w in zip(mult, STATIC_WEIGHTS)])

    #: predicted-overload knee of the hindsight cost: counterfactual
    #: placements that push a node's accumulated offered utilization past
    #: this are charged the excess, so a candidate cannot look good by
    #: piling every decision onto whichever node happened to be healthy
    OVERLOAD_KNEE = 1.0

    def _hindsight_cost(self, decisions, node_dlv) -> "Callable":
        """Cost function for the probe: replay the window's recorded
        placement decisions under a candidate weight vector and charge,
        per decision, the realized DLV rate of the node the candidate
        would have picked — plus the predicted overload its *own*
        counterfactual placements would cause.

        The replay is sequential and capacity-aware: each counterfactual
        placement adds the stream's marginal offered load to the chosen
        node's load term for the window's later decisions (the same
        feedback a deployed router would have had), which is what stops
        hindsight-greedy candidates from concentrating on the one node
        that happened to realize zero violations.  Terms matrices are
        5-wide (full ``WEIGHT_NAMES`` order): whole-stream decisions carry
        a zero transfer column, stage-split decisions the real one — so
        ``W_XFER`` is learned from hindsight too."""
        def cost_fn(mult: np.ndarray) -> float:
            w = np.asarray(mult) * np.asarray(STATIC_WEIGHTS)
            extra: dict[int, float] = {}
            total = 0.0
            for ids, terms, marginal in decisions:
                scores = terms @ w
                if extra:
                    scores = scores + w[0] * np.asarray(
                        [extra.get(i, 0.0) for i in ids])
                # ids are ascending, so argmin ties break to lower node id
                k = int(np.argmin(scores))
                nid = ids[k]
                # terms[k,0] is the post-placement estimate (it already
                # includes this decision's own marginal) — add only the
                # load accumulated by *earlier* counterfactual placements
                load_after = float(terms[k, 0]) + extra.get(nid, 0.0)
                extra[nid] = extra.get(nid, 0.0) + float(marginal[k])
                total += (node_dlv.get(nid, 0.0)
                          + max(0.0, load_after - self.OVERLOAD_KNEE))
            return total / len(decisions)
        return cost_fn

    def on_window(self, window, rng) -> "Optional[tuple[float, ...]]":
        """Feed one telemetry window; returns the weight vector now live
        (``None`` when the window carried no signal and weights held)."""
        self.windows_seen += 1
        decisions = list(self._decisions)
        self._decisions.clear()
        if window.empty:
            # zero-length / frame-free window: no feedback signal — fall
            # back to the committed weights rather than score a vacuous 0
            self.empty_windows += 1
            return None
        if not decisions or not any(v > 0.0
                                    for v in window.node_dlv.values()):
            # nothing to re-score, or a violation-free fleet: every
            # candidate would tie at zero — hold the committed weights
            self.held_windows += 1
            return None
        self._apply(self.probe.step_batch(
            self._hindsight_cost(decisions, window.node_dlv), rng))
        if self.metrics is not None:
            g = self.metrics.gauge(
                "router_weight", "live router score weights", ("name",))
            for name, w in zip(WEIGHT_NAMES, self.weights):
                g.set(w, name=name)
            self.metrics.counter(
                "router_tune_commits_total",
                "tuner windows that re-scored weights").inc()
        return self.weights

    def rearm(self) -> None:
        """Membership churn / phase event: the workload changed, so the
        committed weights may be stale — widen and restart the probe."""
        self.probe.retrigger()


POLICIES = {
    "round_robin": RoundRobinRouter,
    "least_loaded": LeastLoadedRouter,
    "score": ScoreDrivenRouter,
    "score_whole": WholePipelineScoreRouter,
    "tuned_score": TunedScoreRouter,
}


def make_policy(policy: "str | RouterPolicy") -> RouterPolicy:
    if isinstance(policy, RouterPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown router policy {policy!r}; "
                         f"choose from {sorted(POLICIES)}") from None
