"""Global admission/routing policies: which node serves a new stream, and —
when stage splitting is enabled — which node serves each *stage* of it.

The router sees only aggregated telemetry (:class:`~.node.NodeTelemetry`)
plus per-(stream, node) cost summaries from the memoized offline tables —
never per-job state — so the same policies port to a real deployment where
nodes export a handful of gauges.

Policies:

  * ``round_robin``   — cycle over live nodes; the fleet baseline.
  * ``least_loaded``  — minimize post-placement offered utilization.
  * ``score``         — DREAM-Fleet: a MapScore-analogue at node granularity
    combining load, hardware preference (how well the stream's models suit
    the node's WS/OS accelerator mix, weighted by deadline urgency) and the
    node's recent UXCost-window health.

Stage-level placement (``place_stages``) splits a cascade pipeline across
nodes: the score policy places stages greedily in pipeline order, charging
a transfer-cost penalty (activation bytes over the inter-node link, from
:class:`repro.core.costmodel.TransferModel`) whenever a cascade edge would
cross nodes.  With zero bandwidth the penalty is infinite and placement
degenerates to whole-pipeline.  Policies without stage awareness co-locate
every stage on the whole-stream choice.

All policies are deterministic: ties break on node id, and the round-robin
cursor is part of the policy state (reconstructed identically on replay —
though replay short-circuits routing entirely via recorded placements).
"""
from __future__ import annotations

from typing import Optional, Sequence

from .node import FleetNode, StreamCost


def argmin_node(nodes: Sequence[FleetNode], score_fn) -> int:
    """Node id minimizing ``score_fn(node)``, ties to the lower node id —
    the one argmin loop every placement path shares."""
    best_id, best_key = nodes[0].node_id, None
    for node in nodes:
        key = (score_fn(node), node.node_id)
        if best_key is None or key < best_key:
            best_id, best_key = node.node_id, key
    return best_id


class RouterPolicy:
    """Placement policy plug-in: pick a node id for a candidate stream."""

    name = "base"
    #: whether place_stages may put stages of one stream on different
    #: nodes; non-splitting policies also migrate and rebalance streams as
    #: co-located units
    splits_stages = False

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        """Return the node_id to host ``stream`` (a StreamView).  ``nodes``
        is the list of live, non-draining nodes, sorted by node_id."""
        raise NotImplementedError

    def place_stages(self, stream, nodes: Sequence[FleetNode],
                     transfer) -> list[int]:
        """Per-stage placement: node_id for each pipeline stage of
        ``stream`` (a StreamView), head first.  The default co-locates all
        stages on the whole-stream ``place`` choice; stage-aware policies
        override to split cascades when the transfer economics justify it."""
        del transfer
        return [self.place(stream, nodes)] * stream.n_stages


class RoundRobinRouter(RouterPolicy):
    name = "round_robin"

    def __init__(self) -> None:
        self._cursor = 0

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        node = nodes[self._cursor % len(nodes)]
        self._cursor += 1
        return node.node_id


class LeastLoadedRouter(RouterPolicy):
    """Minimize the node's offered utilization after placement."""

    name = "least_loaded"

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        best_id, best_key = nodes[0].node_id, None
        for node in nodes:
            tel = node.telemetry()
            cost = stream.cost_on(node)
            after = tel.offered_util + cost.offered_s / tel.n_accs
            key = (after, tel.queue_depth, node.node_id)
            if best_key is None or key < best_key:
                best_id, best_key = node.node_id, key
        return best_id


#: DREAM-Fleet score weights.  Load dominates (an overloaded node violates
#: deadlines no matter how well-matched its dataflows are); the live
#: backlog corrects the static offered-load estimate with what is actually
#: queued; preference is urgency-weighted (tight-deadline streams pay most
#: for a poor hardware match); recent deadline-violation health breaks
#: structural ties toward nodes that are currently delivering.
W_BACKLOG = 0.5
W_PREF = 0.2
W_UX = 0.15
URGENCY_CAP = 4.0
#: weight of the cross-node transfer penalty in stage-level scoring: the
#: per-trigger link time as a fraction of the receiving stage's period,
#: amplified so the router only splits when the hardware-match gain is
#: decisively larger than the wire bill
W_XFER = 8.0


class ScoreDrivenRouter(RouterPolicy):
    name = "score"
    splits_stages = True

    def score(self, stream, node: FleetNode,
              best_iso: float) -> float:
        """Lower is better.  ``best_iso`` is the stream's best isolated
        latency across all candidate nodes (preference normalizer)."""
        return self._score(stream.cost_on(node), node, best_iso)

    def _score(self, cost: StreamCost, node: FleetNode,
               best_iso: float) -> float:
        tel = node.telemetry()
        load_after = tel.offered_util + cost.offered_s / tel.n_accs
        pref_penalty = (cost.iso_s / max(best_iso, 1e-12)) - 1.0
        urgency = min(cost.urgency, URGENCY_CAP)
        return (load_after
                + W_BACKLOG * tel.backlog_s / tel.n_accs
                + W_PREF * pref_penalty * urgency
                + W_UX * min(tel.window_dlv, 1.0))

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        best_iso = min(stream.cost_on(n).iso_s for n in nodes)
        return argmin_node(nodes,
                           lambda n: self.score(stream, n, best_iso))

    # ------------------------------------------------------ stage placement
    def transfer_penalty(self, stream, k: int, transfer) -> float:
        """Score penalty for putting stage ``k`` on a different node than
        its parent: the per-trigger transfer latency of the parent's output
        activation, relative to the stage's period (how much of every frame
        interval the wire eats), weighted by W_XFER.  Infinite when the
        transfer model is absent or has zero bandwidth."""
        if transfer is None or not transfer.enabled:
            return float("inf")
        xfer_s = transfer.transfer_s(stream.act_bytes_into(k))
        return W_XFER * xfer_s / max(stream.stage_period_s(k), 1e-9)

    def stage_score(self, stream, k: int, node: FleetNode, best_iso: float,
                    parent_nid: Optional[int], transfer) -> float:
        """Score of placing stage ``k`` on ``node`` given the stage's parent
        already landed on ``parent_nid`` (None for heads)."""
        s = self._score(stream.stage_cost_on(node, k), node, best_iso)
        if parent_nid is not None and node.node_id != parent_nid:
            s += self.transfer_penalty(stream, k, transfer)
        return s

    def place_stages(self, stream, nodes: Sequence[FleetNode],
                     transfer) -> list[int]:
        """Split-refinement placement: anchor the head on the whole-stream
        ``place`` choice (which prices the full pipeline's load, so heads
        never land somewhere that cannot absorb the children that follow),
        then let each non-head stage peel off to another node only when its
        stage score there beats staying with its parent by more than the
        cascade-edge transfer penalty.  With zero bandwidth the penalty is
        infinite, every stage stays with its parent, and the assignment is
        exactly the whole-pipeline placement."""
        out: list[int] = [self.place(stream, nodes)]
        for k in range(1, stream.n_stages):
            best_iso = min(stream.stage_cost_on(n, k).iso_s for n in nodes)
            p = stream.parent_of(k)
            parent_nid = out[p] if p is not None else out[0]
            out.append(argmin_node(
                nodes, lambda n: self.stage_score(stream, k, n, best_iso,
                                                  parent_nid, transfer)))
        return out


class WholePipelineScoreRouter(ScoreDrivenRouter):
    """Score-driven placement that never splits: every stage co-locates on
    the whole-stream choice — at admission, at migration, and at
    rebalance (``splits_stages = False`` makes the fleet move and
    rebalance streams as units).  This is the control arm for stage-split
    experiments — identical scoring, telemetry, migration accounting and
    trigger machinery, with placement granularity as the only variable."""

    name = "score_whole"
    splits_stages = False

    def place_stages(self, stream, nodes: Sequence[FleetNode],
                     transfer) -> list[int]:
        return RouterPolicy.place_stages(self, stream, nodes, transfer)


POLICIES = {
    "round_robin": RoundRobinRouter,
    "least_loaded": LeastLoadedRouter,
    "score": ScoreDrivenRouter,
    "score_whole": WholePipelineScoreRouter,
}


def make_policy(policy: "str | RouterPolicy") -> RouterPolicy:
    if isinstance(policy, RouterPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown router policy {policy!r}; "
                         f"choose from {sorted(POLICIES)}") from None
