"""Global admission/routing policies: which node serves a new stream.

The router sees only aggregated telemetry (:class:`~.node.NodeTelemetry`)
plus per-(stream, node) cost summaries from the memoized offline tables —
never per-job state — so the same policies port to a real deployment where
nodes export a handful of gauges.

Policies:

  * ``round_robin``   — cycle over live nodes; the fleet baseline.
  * ``least_loaded``  — minimize post-placement offered utilization.
  * ``score``         — DREAM-Fleet: a MapScore-analogue at node granularity
    combining load, hardware preference (how well the stream's models suit
    the node's WS/OS accelerator mix, weighted by deadline urgency) and the
    node's recent UXCost-window health.

All policies are deterministic: ties break on node id, and the round-robin
cursor is part of the policy state (reconstructed identically on replay —
though replay short-circuits routing entirely via recorded placements).
"""
from __future__ import annotations

from typing import Sequence

from .node import FleetNode, StreamCost


class RouterPolicy:
    """Placement policy plug-in: pick a node id for a candidate stream."""

    name = "base"

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        """Return the node_id to host ``stream`` (a StreamView).  ``nodes``
        is the list of live, non-draining nodes, sorted by node_id."""
        raise NotImplementedError


class RoundRobinRouter(RouterPolicy):
    name = "round_robin"

    def __init__(self) -> None:
        self._cursor = 0

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        node = nodes[self._cursor % len(nodes)]
        self._cursor += 1
        return node.node_id


class LeastLoadedRouter(RouterPolicy):
    """Minimize the node's offered utilization after placement."""

    name = "least_loaded"

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        best_id, best_key = nodes[0].node_id, None
        for node in nodes:
            tel = node.telemetry()
            cost = stream.cost_on(node)
            after = tel.offered_util + cost.offered_s / tel.n_accs
            key = (after, tel.queue_depth, node.node_id)
            if best_key is None or key < best_key:
                best_id, best_key = node.node_id, key
        return best_id


#: DREAM-Fleet score weights.  Load dominates (an overloaded node violates
#: deadlines no matter how well-matched its dataflows are); the live
#: backlog corrects the static offered-load estimate with what is actually
#: queued; preference is urgency-weighted (tight-deadline streams pay most
#: for a poor hardware match); recent deadline-violation health breaks
#: structural ties toward nodes that are currently delivering.
W_BACKLOG = 0.5
W_PREF = 0.2
W_UX = 0.15
URGENCY_CAP = 4.0


class ScoreDrivenRouter(RouterPolicy):
    name = "score"

    def score(self, stream, node: FleetNode,
              best_iso: float) -> float:
        """Lower is better.  ``best_iso`` is the stream's best isolated
        latency across all candidate nodes (preference normalizer)."""
        tel = node.telemetry()
        cost: StreamCost = stream.cost_on(node)
        load_after = tel.offered_util + cost.offered_s / tel.n_accs
        pref_penalty = (cost.iso_s / max(best_iso, 1e-12)) - 1.0
        urgency = min(cost.urgency, URGENCY_CAP)
        return (load_after
                + W_BACKLOG * tel.backlog_s / tel.n_accs
                + W_PREF * pref_penalty * urgency
                + W_UX * min(tel.window_dlv, 1.0))

    def place(self, stream, nodes: Sequence[FleetNode]) -> int:
        best_iso = min(stream.cost_on(n).iso_s for n in nodes)
        best_id, best_key = nodes[0].node_id, None
        for node in nodes:
            key = (self.score(stream, node, best_iso), node.node_id)
            if best_key is None or key < best_key:
                best_id, best_key = node.node_id, key
        return best_id


POLICIES = {
    "round_robin": RoundRobinRouter,
    "least_loaded": LeastLoadedRouter,
    "score": ScoreDrivenRouter,
}


def make_policy(policy: "str | RouterPolicy") -> RouterPolicy:
    if isinstance(policy, RouterPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown router policy {policy!r}; "
                         f"choose from {sorted(POLICIES)}") from None
