"""FleetSimulator: N DREAM nodes behind a score-driven global router.

Composes per-node discrete-event Simulators (heterogeneous Table-2 systems
per node) under one fleet clock, using the step/peek API: before each
fleet-level event — a stream arriving, a node joining/leaving/draining, a
rebalance tick — every live node is advanced to the event time, so the
router always reads telemetry that is causally consistent across the fleet.

Elastic membership is first-class:

  * ``node_join``  — a fresh (empty) node starts mid-run; its UXCost window
    clock anchors at the join time.
  * ``node_drain`` — graceful: streams migrate away, the node finishes its
    queue but accepts no new placements.
  * ``node_leave`` — abrupt: streams migrate, jobs in flight are lost.

Every placement-affecting event re-triggers the (alpha, beta) adaptivity
probe on the touched nodes (``DreamScheduler.retrigger_probe``), mirroring
the paper's workload-change response.

With ``record=True`` the run emits a :class:`~.trace.FleetTrace` capturing
inputs *and* routing decisions; constructing a FleetSimulator from that
trace (``replay=...``) bypasses the router and reproduces the run
bit-exactly — same per-node jobs, same fleet UXCost.
"""
from __future__ import annotations

import copy
import re
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.scheduler import dream_full
from repro.core.simulator import SchedulerBase
from repro.core.uxcost import (WindowStats, overall_dlv_rate,
                               overall_norm_energy, uxcost)
from repro.scenarios.builder import ModelEntry

from .builder import FleetScenario
from .node import FleetNode, StreamCost
from .router import RouterPolicy, ScoreDrivenRouter, make_policy
from .trace import FleetTrace, FleetTraceRecorder


def node_seed(fleet_seed: int, node_id: int) -> int:
    """Per-node RNG seed: stable across record and replay."""
    return fleet_seed + 7919 * (node_id + 1)


#: placement-generation suffix in namespaced model names ("s12g2.det")
_GEN_RE = re.compile(r"^(s\d+)g\d+\.")


def canonical_stream_model(name: str) -> str:
    """Collapse placement generations: a stream migrated across nodes is
    one logical model in the fleet UXCost merge ("s12g2.det" -> "s12.det"),
    so migrating does not split its DLV-floor / energy accounting."""
    return _GEN_RE.sub(r"\1.", name)


class StreamView:
    """Router-facing view of one stream.

    Holds the *original* (un-namespaced) pipeline entries so cost estimates
    share memoized tables across streams and placement generations; graphs
    materialize lazily, and per-node costs cache by system type (they
    depend only on the node's accelerator mix, not its live state)."""

    def __init__(self, sid: int, entry_cfgs: list[dict]):
        self.sid = sid
        self.entry_cfgs = entry_cfgs
        self.entries = [ModelEntry.from_config(c) for c in entry_cfgs]
        self._graphs: Optional[list] = None
        self._cost_by_system: dict[object, StreamCost] = {}

    @property
    def head_period_s(self) -> float:
        return 1.0 / self.entries[0].fps

    def _graph_loads(self) -> list:
        if self._graphs is None:
            self._graphs = [
                (e.ref.build(), e.fps,
                 1.0 if e.depends_on is None else e.trigger_prob)
                for e in self.entries
            ]
        return self._graphs

    def cost_on(self, node: FleetNode) -> StreamCost:
        key = node.system if node.system != "custom" else ("node", node.node_id)
        hit = self._cost_by_system.get(key)
        if hit is None:
            hit = node.stream_cost(self._graph_loads(), self.head_period_s)
            self._cost_by_system[key] = hit
        return hit

    def namespaced_specs(self, gen: int) -> tuple[list, list[str]]:
        """Materialize placement-generation-``gen`` ModelSpecs.  Names are
        prefixed per (stream, generation) so re-placements never collide
        with an earlier residency of the same stream on the same node."""
        prefix = f"s{self.sid}." if gen == 0 else f"s{self.sid}g{gen}."
        specs, names = [], []
        for cfg in self.entry_cfgs:
            c = copy.deepcopy(cfg)
            base = c["model"]["name"]
            c["model"]["name"] = prefix + base
            if c.get("depends_on"):
                c["depends_on"] = prefix + c["depends_on"]
            specs.append(ModelEntry.from_config(c).to_spec())
            names.append(prefix + base)
        return specs, names


@dataclass
class FleetResult:
    name: str
    policy: str
    duration_s: float
    n_nodes: int                 # nodes ever joined
    n_streams: int
    stats: WindowStats           # fleet-merged per-model window stats
    uxcost: float                # fleet UXCost (Algorithm 2 on the merge)
    dlv_rate: float
    norm_energy: float
    frames: int
    drops: int
    migrations: int
    probe_retriggers: int
    per_node: list[dict]
    trace: Optional[FleetTrace] = None

    def summary(self) -> str:
        return (f"fleet[{self.policy:>11s}] nodes={self.n_nodes:<3d} "
                f"streams={self.n_streams:<4d} UXCost={self.uxcost:10.4f} "
                f"DLV={self.dlv_rate:6.3f} frames={self.frames} "
                f"drops={self.drops} migr={self.migrations}")


class FleetSimulator:
    """Drive a FleetScenario (or a recorded FleetTrace) to completion."""

    def __init__(
        self,
        scenario: Optional[FleetScenario] = None,
        policy: "str | RouterPolicy" = "score",
        *,
        duration_s: float = 4.0,
        seed: int = 0,
        window_s: float = 0.5,
        scheduler_factory: Optional[Callable[[int], SchedulerBase]] = None,
        record: bool = False,
        replay: Optional[FleetTrace] = None,
        rebalance_every_s: Optional[float] = None,
        rebalance_hysteresis: float = 0.15,
    ):
        if (scenario is None) == (replay is None):
            raise ValueError("pass exactly one of scenario or replay")
        self.replay = replay
        if replay is not None:
            meta = replay.meta
            self.name = meta.get("scenario", "replayed-fleet")
            self.policy = make_policy(meta.get("policy", "score"))
            duration_s = float(meta["duration_s"])
            seed = int(meta["seed"])
            window_s = float(meta["window_s"])
            rebalance_every_s = None    # decisions come from the trace
            self._events = [(e["t"], e["type"], e) for e in replay.events]
        else:
            self.name = scenario.name
            self.policy = make_policy(policy)
            self._events = [(e.t, e.kind, dict(e.payload, t=e.t))
                            for e in scenario.events]
        self.duration_s = duration_s
        self.seed = seed
        self.window_s = window_s
        self.scheduler_factory = (scheduler_factory
                                  or (lambda s: dream_full(seed=s)))
        #: scheduler identity, recorded in traces: replaying with a
        #: different per-node scheduler would silently diverge
        self._scheduler_name = self.scheduler_factory(0).name
        if replay is not None:
            expected = replay.meta.get("scheduler")
            if expected is not None and expected != self._scheduler_name:
                raise ValueError(
                    f"trace was recorded with scheduler {expected!r}; pass a "
                    f"matching scheduler_factory (got "
                    f"{self._scheduler_name!r})")
        if rebalance_every_s is not None and not rebalance_every_s > 0:
            raise ValueError("rebalance_every_s must be positive")
        self.rebalance_every_s = rebalance_every_s
        self.rebalance_hysteresis = rebalance_hysteresis
        self.nodes: dict[int, FleetNode] = {}
        self.streams: dict[int, StreamView] = {}
        self.stream_node: dict[int, int] = {}   # sid -> hosting node id
        self.gen: dict[int, int] = {}           # sid -> placement generation
        self.migrations = 0
        self.recorder = None
        self.trace: Optional[FleetTrace] = None
        if record:
            if replay is not None:
                raise ValueError("record and replay are mutually exclusive")
            self.recorder = FleetTraceRecorder({
                "scenario": self.name, "policy": self.policy.name,
                "scheduler": self._scheduler_name,
                "seed": seed, "duration_s": duration_s,
                "window_s": window_s,
            })

    # ---------------------------------------------------------- plumbing
    def _advance_all(self, t: float) -> None:
        for nid in sorted(self.nodes):
            self.nodes[nid].advance_to(t)

    def _candidates(self, exclude: Optional[int] = None) -> list[FleetNode]:
        return [self.nodes[nid] for nid in sorted(self.nodes)
                if self.nodes[nid].alive and not self.nodes[nid].draining
                and nid != exclude]

    def _place(self, sid: int, nid: int, t: float, gen: int) -> None:
        sv = self.streams[sid]
        specs, names = sv.namespaced_specs(gen)
        self.nodes[nid].place(sid, specs, names, t)
        self.stream_node[sid] = nid
        self.gen[sid] = gen

    def _migrate(self, sid: int, src: int, dst: int, t: float,
                 gen: int) -> None:
        self.nodes[src].evict(sid, t)
        self._place(sid, dst, t, gen)
        self.migrations += 1

    # ------------------------------------------------------ event handlers
    def _on_node_join(self, t: float, ev: dict) -> None:
        nid, system = int(ev["node"]), ev["system"]
        if nid in self.nodes:
            raise ValueError(f"node {nid} joined twice")
        ns = node_seed(self.seed, nid)
        self.nodes[nid] = FleetNode(
            nid, system, self.scheduler_factory(ns),
            duration_s=self.duration_s, seed=ns,
            window_s=self.window_s, at_t=t)
        if self.recorder is not None:
            self.recorder.node_join(t, nid, system)

    def _on_node_leave(self, t: float, ev: dict) -> None:
        node = self.nodes[int(ev["node"])]
        if self.recorder is not None:
            self.recorder.node_leave(t, node.node_id)
        if self.replay is None:
            self._migrate_all_off(node, t)
        node.alive = False

    def _on_node_drain(self, t: float, ev: dict) -> None:
        node = self.nodes[int(ev["node"])]
        if self.recorder is not None:
            self.recorder.node_drain(t, node.node_id)
        node.draining = True
        if self.replay is None:
            self._migrate_all_off(node, t)

    def _migrate_all_off(self, node: FleetNode, t: float) -> None:
        for sid in sorted(node.placements):
            cands = self._candidates(exclude=node.node_id)
            if not cands:
                raise RuntimeError(
                    f"no live nodes left to host stream {sid} at t={t}")
            dst = self.policy.place(self.streams[sid], cands)
            gen = self.gen[sid] + 1
            self._migrate(sid, node.node_id, dst, t, gen)
            if self.recorder is not None:
                self.recorder.migrate(t, sid, node.node_id, dst, gen)

    def _on_stream(self, t: float, ev: dict) -> None:
        sid = int(ev["sid"])
        self.streams[sid] = StreamView(sid, ev["entries"])
        if self.recorder is not None:
            self.recorder.stream(t, sid, ev["entries"])
        if self.replay is not None:
            return                       # a recorded `place` event follows
        cands = self._candidates()
        if not cands:
            raise RuntimeError(f"stream {sid} arrived with no live nodes")
        nid = self.policy.place(self.streams[sid], cands)
        self._place(sid, nid, t, gen=0)
        if self.recorder is not None:
            self.recorder.place(t, sid, nid, 0)

    def _on_place(self, t: float, ev: dict) -> None:       # replay only
        self._place(int(ev["sid"]), int(ev["node"]), t, int(ev["gen"]))

    def _on_migrate(self, t: float, ev: dict) -> None:     # replay only
        self._migrate(int(ev["sid"]), int(ev["from"]), int(ev["to"]), t,
                      int(ev["gen"]))

    def _on_rebalance(self, t: float, ev: dict) -> None:   # live only
        """Optional phase-boundary re-placement: move a stream when the
        score-driven router now prefers another node by a clear margin."""
        if not isinstance(self.policy, ScoreDrivenRouter):
            return
        cands = self._candidates()          # membership is fixed in-tick
        if len(cands) < 2:
            return
        for sid in sorted(self.stream_node):
            cur = self.stream_node[sid]
            if not self.nodes[cur].alive:
                continue
            sv = self.streams[sid]
            best_iso = min(sv.cost_on(n).iso_s for n in cands)
            scores = {n.node_id: self.policy.score(sv, n, best_iso)
                      for n in cands}
            best = min(scores, key=lambda nid: (scores[nid], nid))
            cur_score = scores.get(cur)
            if (best != cur and cur_score is not None
                    and cur_score - scores[best] > self.rebalance_hysteresis):
                gen = self.gen[sid] + 1
                self._migrate(sid, cur, best, t, gen)
                if self.recorder is not None:
                    self.recorder.migrate(t, sid, cur, best, gen)

    # ----------------------------------------------------------------- run
    def _event_stream(self) -> list[tuple[float, str, dict]]:
        events = list(self._events)
        if self.rebalance_every_s is not None:
            k, seq = 1, 0
            while k * self.rebalance_every_s < self.duration_s:
                events.append((k * self.rebalance_every_s,
                               "rebalance", {"k": k}))
                k += 1
        # stable sort keeps same-time events in declaration/record order;
        # synthetic rebalance ticks land after same-time scenario events
        return sorted(events, key=lambda e: e[0])

    def run(self) -> FleetResult:
        handlers = {
            "node_join": self._on_node_join,
            "node_leave": self._on_node_leave,
            "node_drain": self._on_node_drain,
            "stream": self._on_stream,
            "place": self._on_place,
            "migrate": self._on_migrate,
            "rebalance": self._on_rebalance,
        }
        for t, kind, ev in self._event_stream():
            if t > self.duration_s:
                break
            self._advance_all(t)
            handlers[kind](t, ev)
        self._advance_all(self.duration_s)
        return self._finalize()

    def _finalize(self) -> FleetResult:
        fleet_stats = WindowStats()
        per_node: list[dict] = []
        frames = drops = retriggers = 0
        for nid in sorted(self.nodes):
            node = self.nodes[nid]
            r = node.finalize()
            for name, st in r.stats.per_model.items():
                fleet_stats.model(canonical_stream_model(name)).merge(st)
            frames += r.frames
            drops += r.drops
            retriggers += node.probe_retriggers
            # busy fraction since the node's join (SimResult utilization
            # divides by absolute time, understating mid-run joiners);
            # clamped because an abrupt leave can freeze sim.t with a
            # dispatch reservation still counted in busy_time
            span = max(node.sim.t - node.join_t, 1e-9)
            util = min(sum(a.busy_time for a in node.sim.accs)
                       / (len(node.sim.accs) * span), 1.0)
            per_node.append({
                "node": nid, "system": node.system, "alive": node.alive,
                "draining": node.draining, "frames": r.frames,
                "drops": r.drops, "uxcost": r.uxcost,
                "utilization": util, "streams": len(node.placements),
                "probe_retriggers": node.probe_retriggers,
            })
        if self.recorder is not None:
            self.trace = self.recorder.trace()
        return FleetResult(
            name=self.name,
            policy=self.policy.name,
            duration_s=self.duration_s,
            n_nodes=len(self.nodes),
            n_streams=len(self.streams),
            stats=fleet_stats,
            uxcost=uxcost(fleet_stats),
            dlv_rate=overall_dlv_rate(fleet_stats),
            norm_energy=overall_norm_energy(fleet_stats),
            frames=frames,
            drops=drops,
            migrations=self.migrations,
            probe_retriggers=retriggers,
            per_node=per_node,
            trace=self.trace,
        )


def run_fleet(scenario: FleetScenario, policy: "str | RouterPolicy",
              duration_s: float = 4.0, seed: int = 0,
              **kw) -> FleetResult:
    return FleetSimulator(scenario, policy, duration_s=duration_s,
                          seed=seed, **kw).run()
