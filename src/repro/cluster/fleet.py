"""FleetSimulator: N DREAM nodes behind a score-driven global router.

This module owns the fleet clock and every placement-affecting code path:
stream admission, stage-split placement, elastic membership, migration
(and its transfer-cost accounting), rebalance ticks, trace record/replay,
and the fleet-level UXCost merge.

Composes per-node discrete-event Simulators (heterogeneous Table-2 systems
per node) under one fleet clock, using the step/peek API: before each
fleet-level event — a stream arriving, a node joining/leaving/draining, a
rebalance tick — every live node is advanced to the event time, so the
router always reads telemetry that is causally consistent across the fleet.

Two placement granularities:

  * **whole-stream** (default) — a stream (head + cascade children) lands
    on one node; cascades trigger inside that node's simulator.  This is
    the PR-2 behavior, preserved bit-exactly.
  * **stage-split** (``split_stages=True`` + a ``TransferModel``) — the
    router places each pipeline *stage* independently.  Cascade edges that
    cross nodes become fleet-level triggers: the parent node exports the
    completion, the fleet draws the trigger probability from a dedicated
    RNG stream, charges the activation transfer (latency delays the child
    and eats its deadline slack; energy lands in the fleet UXCost merge),
    and injects the frame into the child's node.  Causal consistency is
    kept by an *interleaved* advance: nodes step strictly in global event
    order (ties broken by node id) so a trigger is always injected before
    its target passes the injection time.

Elastic membership is first-class:

  * ``node_join``  — a fresh (empty) node starts mid-run; its UXCost window
    clock anchors at the join time.
  * ``node_drain`` — graceful: streams migrate away, the node finishes its
    queue but accepts no new placements.
  * ``node_leave`` — abrupt: streams migrate, jobs in flight are lost.

And so is the *stream lifecycle* — the load-release half of the paper's
task-level dynamicity:

  * ``depart`` — a stream stops mid-run: it is evicted from its hosting
    node(s), its queued (not-yet-running) frames are purged without
    counting against UXCost, the touched nodes' probes re-arm, and so
    does the fleet weight tuner.  Frames served while the stream was
    present stay in the UXCost merge.
  * ``rejoin`` — a departed stream returns: the router re-places its
    recorded definition under a fresh placement generation, exactly like
    a new arrival.

Overload is a managed regime (the SLO subsystem, :mod:`.slo`): streams
declare service tiers, and with ``slo=True`` (or a config) an
:class:`~.slo.AdmissionController` gates every arrival/rejoin — admit,
admit one supernet-variant level down, or **reject** (a first-class
outcome: the refused head frames accrue as deadline violations in the
fleet UXCost merge, never a silent drop).  ``slo_every_s`` ticks walk the
degradation ladder over placed streams: under sustained pressure the
weakest tiers pin to cheaper supernet variants
(``Simulator.swap_variant``), and they promote back one level per tick
once pressure falls below the hysteresis band.  Tier-0 ("guaranteed")
streams are never degraded or rejected.  Every controller decision is
recorded (``swap`` / ``reject`` trace records), so replay applies them as
inputs and bypasses the controller bit-exactly; runs without a controller
never touch the variant plumbing and stay bit-identical to pre-SLO
builds.

Transfers (migrations *and* cross-node cascade triggers) are realized
over shared per-node-pair links (:class:`repro.core.costmodel.ContendedLinks`):
with a finite ``link_bandwidth_bytes_s`` concurrent transfers on one
node pair queue FIFO for the wire, so ``W_XFER`` penalties and migration
delays reflect load-dependent realized times; the default (infinite link
bandwidth) is uncontended and bit-identical to the historical model.

Under a ``TransferModel``, every migration (drain/leave/rebalance) charges
the moved model state exactly once: the re-placement is delayed by the
state-transfer latency and the link energy is added to the moved model's
fleet UXCost entry.  With ``bandwidth_bytes_s == 0`` there is no usable
inter-node link: stage placement degenerates to whole-pipeline co-location
and migrations fall back to reloading weights from node-local storage
(energy charged, no wire delay).

Every placement-affecting event re-triggers the (alpha, beta) adaptivity
probe on the touched nodes (``DreamScheduler.retrigger_probe``), mirroring
the paper's workload-change response.

Two adaptivity loops close over the fleet clock:

  * **fleet phase events** (``FleetScenarioBuilder.phase``) are
    stream-addressed workload mutations (e.g. diurnal ``scale_fps``
    shifts) forwarded to the hosting nodes as node-local phase actions;
    they re-arm the touched nodes' probes and update the stream's own
    definition so later migrations re-place at the shifted rate.
  * **tune ticks** (``tune_every_s``) close a fleet telemetry window
    (:class:`~.telemetry.FleetTelemetry`) and feed it to the routing
    policy's weight tuner when it has one (``tuned_score``): the
    fleet-scale analogue of the per-node (alpha, beta) probe, re-armed on
    membership churn and phase events.  Tuner decisions are recorded in
    the trace, so replay installs the recorded weights and never
    constructs telemetry or steps the probe.

With ``record=True`` the run emits a :class:`~.trace.FleetTrace` capturing
inputs *and* routing decisions (stage-level when splitting); constructing
a FleetSimulator from that trace (``replay=...``) bypasses the router and
reproduces the run bit-exactly — cross-node triggers are re-derived from
the recorded placements via the deterministic interleaved clock and the
dedicated trigger RNG, so they need no trace records of their own.

Invariants:

  * placement-generation namespacing — a (stream, stage) re-placed after a
    migration gets a fresh ``g<N>`` name prefix, so it can never collide
    with an earlier residency on the same node; UXCost merging collapses
    the generations back to one logical model per stream.
  * stage-split cascade draws are *counter-based*: the n-th completion of
    a cascade edge draws from a generator keyed by (fleet seed, stream,
    edge, n), so trigger realizations are a property of the workload, not
    of placement or interleave order — different placements of one
    scenario face identical cascades, and whole-stream runs (which draw
    triggers inside their node simulators, as in PR 2) are untouched.
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
import re
import warnings
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.costmodel import (ContendedLinks, TransferModel,
                                  activation_bytes, model_state_bytes)
from repro.core.engine import EngineConfig
from repro.core.scheduler import dream_full
from repro.core.simulator import SchedulerBase
from repro.core.uxcost import (WindowStats, overall_dlv_rate,
                               overall_norm_energy,
                               overall_pipeline_latency, uxcost)
from repro.obs import Obs
from repro.scenarios.builder import ModelEntry

from repro.scenarios.phases import PhaseAction

from .builder import FleetScenario
from .node import FleetNode, StreamCost
from .router import (RouterPolicy, ScoreDrivenRouter, argmin_node,
                     make_policy)
from .slo import (DEFAULT_SLO, AdmissionController, StreamState,
                  slo_from_config)
from .telemetry import FleetTelemetry
from .trace import FleetTrace, FleetTraceRecorder

#: domain-separation constant for stage-split cascade trigger draws
_TRIGGER_STREAM = 0x7819
_U64 = (1 << 64) - 1


def _hash_u01(*keys: int) -> float:
    """Deterministic uniform in [0, 1) from integer keys: a boost-style
    hash combine followed by the splitmix64 finalizer.  Used for the
    counter-based cascade trigger draws — constructing a numpy Generator
    per draw would dominate the interleave hot path, and a keyed hash
    gives the same placement-independence at a fraction of the cost."""
    x = 0x9E3779B97F4A7C15
    for k in keys:
        x = (x ^ ((k & _U64) + 0x9E3779B97F4A7C15
                  + ((x << 6) & _U64) + (x >> 2))) & _U64
    x = (x + 0x9E3779B97F4A7C15) & _U64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _U64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _U64
    x ^= x >> 31
    return x / 2.0 ** 64


def node_seed(fleet_seed: int, node_id: int) -> int:
    """Per-node RNG seed: stable across record and replay."""
    return fleet_seed + 7919 * (node_id + 1)


#: placement namespacing in model names: "s<sid>[t<stage>][g<gen>].<base>"
_GEN_RE = re.compile(r"^(s\d+)(?:t\d+)?(?:g\d+)?\.")


def canonical_stream_model(name: str) -> str:
    """Collapse placement generations and stage indices: a stream migrated
    across nodes (or split into stages) is one logical model per base name
    in the fleet UXCost merge ("s12g2.det" -> "s12.det", "s12t1g2.track"
    -> "s12.track"), so moving or splitting does not fragment its
    DLV-floor / energy accounting."""
    return _GEN_RE.sub(r"\1.", name)


class StreamView:
    """Router-facing view of one stream (a pipeline of cascade stages).

    Holds the *original* (un-namespaced) pipeline entries so cost estimates
    share memoized tables across streams and placement generations; graphs
    materialize lazily, and per-node costs cache by system type (they
    depend only on the node's accelerator mix, not its live state).

    The stage surface (``stage_cost_on`` / ``stage_spec`` / ``parent_of`` /
    ``children_of``) exposes each pipeline stage as an independently
    placeable unit; ``stage_weight`` is the cumulative trigger probability
    from the head, so offered-load estimates reflect each stage's true
    arrival rate (head fps x product of trigger probabilities)."""

    def __init__(self, sid: int, entry_cfgs: list[dict]):
        self.sid = sid
        # own the configs: phase events rescale them in place, and the
        # originals belong to the scenario (shared across runs) and to the
        # recorded trace (which must keep the admission-time workload).
        # Only the top-level "fps" key is ever mutated (rescale_fps), so a
        # per-dict shallow copy suffices — nested model/arrival dicts are
        # read-only and may stay shared with the scenario.
        self.entry_cfgs = [dict(c) for c in entry_cfgs]
        self.entries = [ModelEntry.from_config(c) for c in self.entry_cfgs]
        #: SLO pipeline budget in head periods (the stream tier's
        #: ``SLOClass.budget_factor``), installed by the fleet at arrival.
        #: Budget-aware routers divide routing urgency by it; the 1.0
        #: default keeps budget-blind scoring bit-identical
        self.budget_factor = 1.0
        self._graphs: Optional[list] = None
        self._cost_by_system: dict[object, StreamCost] = {}
        self._stage_graphs: Optional[list] = None
        self._stage_cost: dict[object, StreamCost] = {}
        # cascade topology: parent index + children (index, trigger_prob)
        name_to_idx = {e.model_name: i for i, e in enumerate(self.entries)}
        self._parent: list[Optional[int]] = []
        self._children: dict[int, list[tuple[int, float]]] = {}
        self._weight: list[float] = []
        for i, e in enumerate(self.entries):
            if e.depends_on is None:
                self._parent.append(None)
                self._weight.append(1.0)
            else:
                p = name_to_idx[e.depends_on]
                self._parent.append(p)
                self._weight.append(self._weight[p] * e.trigger_prob)
                self._children.setdefault(p, []).append((i, e.trigger_prob))

    @property
    def n_stages(self) -> int:
        return len(self.entries)

    @property
    def head_period_s(self) -> float:
        return 1.0 / self.entries[0].fps

    def rescale_fps(self, factor: float) -> None:
        """Apply a fleet phase event's FPS rescale to the stream's *own*
        definition, so later re-placements (drain/leave/rebalance
        migrations) materialize specs at the shifted rate instead of
        silently reverting to the admission-time load.  Cost caches that
        embed rates are invalidated; cascade topology and per-stage graphs
        (rate-independent) survive."""
        for cfg in self.entry_cfgs:
            cfg["fps"] = float(cfg["fps"]) * factor
        self.entries = [ModelEntry.from_config(c) for c in self.entry_cfgs]
        self._graphs = None
        self._cost_by_system = {}
        self._stage_cost = {}

    # ------------------------------------------------------ whole-stream
    def _graph_loads(self) -> list:
        if self._graphs is None:
            self._graphs = [
                (e.ref.build(), e.fps,
                 1.0 if e.depends_on is None else e.trigger_prob)
                for e in self.entries
            ]
        return self._graphs

    def cost_on(self, node: FleetNode) -> StreamCost:
        key = node.system if node.system != "custom" else ("node", node.node_id)
        hit = self._cost_by_system.get(key)
        if hit is None:
            hit = node.stream_cost(self._graph_loads(), self.head_period_s)
            self._cost_by_system[key] = hit
        return hit

    def namespaced_specs(self, gen: int) -> tuple[list, list[str]]:
        """Materialize placement-generation-``gen`` ModelSpecs for a whole-
        stream placement.  Names are prefixed per (stream, generation) so
        re-placements never collide with an earlier residency of the same
        stream on the same node."""
        prefix = f"s{self.sid}." if gen == 0 else f"s{self.sid}g{gen}."
        specs, names = [], []
        for cfg in self.entry_cfgs:
            # shallow rebuild: only the two renamed keys get fresh dicts
            c = dict(cfg)
            m = dict(c["model"])
            base = m["name"]
            m["name"] = prefix + base
            c["model"] = m
            if c.get("depends_on"):
                c["depends_on"] = prefix + c["depends_on"]
            specs.append(ModelEntry.from_config(c).to_spec())
            names.append(prefix + base)
        return specs, names

    # ------------------------------------------------------- stage surface
    def parent_of(self, k: int) -> Optional[int]:
        """Index of stage ``k``'s cascade parent (None for heads)."""
        return self._parent[k]

    def children_of(self, k: int) -> list[tuple[int, float]]:
        """(stage index, trigger probability) of stage ``k``'s dependents."""
        return self._children.get(k, [])

    def stage_base(self, k: int) -> str:
        return self.entries[k].model_name

    def stage_weight(self, k: int) -> float:
        """Cumulative trigger probability from the head (1.0 for heads)."""
        return self._weight[k]

    def stage_period_s(self, k: int) -> float:
        return 1.0 / self.entries[k].fps

    def stage_graph(self, k: int):
        if self._stage_graphs is None:
            self._stage_graphs = [e.ref.build() for e in self.entries]
        return self._stage_graphs[k]

    def act_bytes_into(self, k: int) -> float:
        """Bytes a cross-node trigger into stage ``k`` ships (the parent's
        final activation); 0.0 for heads."""
        p = self._parent[k]
        return 0.0 if p is None else activation_bytes(self.stage_graph(p))

    def state_bytes(self, k: int) -> float:
        """Bytes a migration of stage ``k`` ships (its weight state)."""
        return model_state_bytes(self.stage_graph(k))

    def stage_cost_on(self, node: FleetNode, k: int) -> StreamCost:
        sys_key = (node.system if node.system != "custom"
                   else ("node", node.node_id))
        key = (sys_key, k)
        hit = self._stage_cost.get(key)
        if hit is None:
            rate = self.entries[0].fps * self.stage_weight(k)
            hit = node.stream_cost([(self.stage_graph(k), rate, 1.0)],
                                   self.stage_period_s(k))
            self._stage_cost[key] = hit
        return hit

    def stage_spec(self, k: int, gen: int):
        """Materialize stage ``k`` at placement generation ``gen`` as a
        standalone ModelSpec.  Non-head stages lose their local cascade
        dependency and get a ``triggered`` arrival process: their frames
        come only from fleet-forwarded triggers (same-node edges included,
        so a stream's dynamics do not change when a stage migrates)."""
        prefix = (f"s{self.sid}t{k}." if gen == 0
                  else f"s{self.sid}t{k}g{gen}.")
        c = dict(self.entry_cfgs[k])
        m = dict(c["model"])
        base = m["name"]
        m["name"] = prefix + base
        c["model"] = m
        if c.get("depends_on") is not None:
            c["depends_on"] = None
            c["arrival"] = {"kind": "triggered"}
        return ModelEntry.from_config(c).to_spec(), prefix + base


@dataclass
class FleetResult:
    name: str
    policy: str
    duration_s: float
    n_nodes: int                 # nodes ever joined
    n_streams: int
    stats: WindowStats           # fleet-merged per-model window stats
    uxcost: float                # fleet UXCost (Algorithm 2 on the merge)
    dlv_rate: float
    norm_energy: float
    frames: int
    drops: int
    migrations: int
    probe_retriggers: int
    per_node: list[dict]
    trace: Optional[FleetTrace] = None
    split: bool = False          # stage-split placement was enabled
    stage_migrations: int = 0    # migrations that moved a single stage
    trigger_transfers: int = 0   # cascade triggers that crossed nodes
    xfer_energy_j: float = 0.0   # total transfer energy charged to UXCost
    weights: Optional[tuple] = None   # final router weights (score family)
    tuner_windows: int = 0       # telemetry windows the tuner consumed
    tuner_commits: int = 0       # probe mini-cycles that moved the center
    tuner_retriggers: int = 0    # tuner re-arms (churn + phase events)
    pipeline_latency_s: float = 0.0  # mean head-to-tail latency, wire incl.
    pipe_frames: int = 0         # pipelines completed head-to-tail
    departures: int = 0          # stream depart events applied
    rejoins: int = 0             # stream rejoin events applied
    jobs_purged: int = 0         # queued jobs discarded by departures
    link_transfers: int = 0      # transfers routed over shared links
    link_queued: int = 0         # of which waited on a busy link
    link_wait_s: float = 0.0     # total link queueing delay experienced
    slo_enabled: bool = False    # an admission controller gated this run
    rejections: int = 0          # streams refused admission
    swaps: int = 0               # SLO variant-level changes applied
    promotions: int = 0          # of which promoted back toward quality
    reject_frames: int = 0       # pseudo-frames charged for rejections
    #: frames / DLV rate per SLO tier (tierless streams count as tier 1)
    tier_frames: dict = field(default_factory=dict)
    tier_dlv: dict = field(default_factory=dict)
    stream_seconds: float = 0.0  # simulated stream-seconds served

    def summary(self) -> str:
        return (f"fleet[{self.policy:>11s}] nodes={self.n_nodes:<3d} "
                f"streams={self.n_streams:<4d} UXCost={self.uxcost:10.4f} "
                f"DLV={self.dlv_rate:6.3f} frames={self.frames} "
                f"drops={self.drops} migr={self.migrations}")


class _CandidateList(list):
    """Sorted live-node candidate list with fleet-backed SoA telemetry
    columns.  Batched routers call :meth:`tel_columns` to read per-node
    telemetry as flat arrays (refreshed via the node dirty hooks) instead
    of 8 attribute reads per node per placement; scalar paths just treat
    it as the plain list it is."""

    _fleet: "FleetSimulator"

    def tel_columns(self) -> dict:
        return self._fleet._tel_columns(self)


class FleetSimulator:
    """Drive a FleetScenario (or a recorded FleetTrace) to completion."""

    def __init__(
        self,
        scenario: Optional[FleetScenario] = None,
        policy: "str | RouterPolicy" = "score",
        *,
        duration_s: float = 4.0,
        seed: int = 0,
        window_s: float = 0.5,
        scheduler_factory: Optional[Callable[[int], SchedulerBase]] = None,
        record: bool = False,
        replay: Optional[FleetTrace] = None,
        rebalance_every_s: Optional[float] = None,
        rebalance_hysteresis: float = 0.15,
        transfer: Optional[TransferModel] = None,
        split_stages: bool = False,
        tune_every_s: Optional[float] = None,
        slo: "bool | dict | AdmissionController | None" = None,
        slo_every_s: Optional[float] = None,
        genai_predictor: bool = True,
        engine: "EngineConfig | str | None" = None,
        obs: "bool | dict | Obs | None" = None,
        lazy_peek: "bool | None" = None,
    ):
        if (scenario is None) == (replay is None):
            raise ValueError("pass exactly one of scenario or replay")
        self.replay = replay
        if replay is not None:
            meta = replay.meta
            self.name = meta.get("scenario", "replayed-fleet")
            self.policy = make_policy(meta.get("policy", "score"))
            duration_s = float(meta["duration_s"])
            seed = int(meta["seed"])
            window_s = float(meta["window_s"])
            rebalance_every_s = None    # decisions come from the trace
            tune_every_s = None         # recorded `tune` events carry them
            transfer = (TransferModel.from_config(meta["transfer"])
                        if "transfer" in meta else None)
            split_stages = bool(meta.get("split", False))
            slo = None              # recorded swap/reject events carry them
            slo_every_s = None
            genai_predictor = bool(meta.get("genai_predictor", True))
            self._events = [(e["t"], e["type"], e) for e in replay.events]
        else:
            self.name = scenario.name
            self.policy = make_policy(policy)
            self._events = [(e.t, e.kind, dict(e.payload, t=e.t))
                            for e in scenario.events]
        if split_stages and transfer is None:
            raise ValueError("split_stages requires a TransferModel: "
                             "stage placement is priced by transfer cost")
        self.transfer = transfer
        self.split = bool(split_stages)
        self.duration_s = duration_s
        self.seed = seed
        self.window_s = window_s
        self.scheduler_factory = (scheduler_factory
                                  or (lambda s: dream_full(seed=s)))
        #: scheduler identity, recorded in traces: replaying with a
        #: different per-node scheduler would silently diverge
        self._scheduler_name = self.scheduler_factory(0).name
        if replay is not None:
            expected = replay.meta.get("scheduler")
            if expected is not None and expected != self._scheduler_name:
                raise ValueError(
                    f"trace was recorded with scheduler {expected!r}; pass a "
                    f"matching scheduler_factory (got "
                    f"{self._scheduler_name!r})")
        if rebalance_every_s is not None and not rebalance_every_s > 0:
            raise ValueError("rebalance_every_s must be positive")
        if tune_every_s is not None and not tune_every_s > 0:
            raise ValueError("tune_every_s must be positive")
        if slo_every_s is not None and not slo_every_s > 0:
            raise ValueError("slo_every_s must be positive")
        self.rebalance_every_s = rebalance_every_s
        self.rebalance_hysteresis = rebalance_hysteresis
        self.tune_every_s = tune_every_s
        #: per-node generation-length predictor toggle (False = blind
        #: ablation: autoregressive jobs priced at their max_new_tokens cap)
        self.genai_predictor = genai_predictor
        if lazy_peek is not None:
            # legacy flag shim: pre-EngineConfig callers toggled the fleet
            # clock arm directly; fold it into the config
            warnings.warn(
                "FleetSimulator(lazy_peek=...) is deprecated; pass "
                "engine=EngineConfig(..., lazy_peek=...) instead",
                DeprecationWarning, stacklevel=2)
            cfg = EngineConfig.make(engine) or EngineConfig()
            engine = dataclasses.replace(cfg, lazy_peek=lazy_peek)
        #: engine arm selection (None = class-attribute behavior); applied
        #: fleet-wide here and per node at FleetNode construction
        self.engine = EngineConfig.make(engine)
        if self.engine is not None:
            self.engine.apply_fleet(self)
        #: SLO admission controller (live runs only — replay applies the
        #: recorded swap/reject decisions and never runs the controller);
        #: ``slo_every_s`` paces the degradation-ladder ticks (None = gate
        #: arrivals only, no periodic ladder)
        self.slo = AdmissionController.make(slo)
        self.slo_every_s = slo_every_s
        if self.slo is None and slo_every_s is not None:
            raise ValueError("slo_every_s requires an admission controller "
                             "(pass slo=True or a config)")
        #: dedicated telemetry aggregator for the controller: windows are
        #: snapshot deltas, so sharing the tuner's instance would perturb
        #: the tuner's feedback whenever the tick cadences differ
        self._slo_tel = (FleetTelemetry(canonical=canonical_stream_model)
                         if self.slo is not None else None)
        #: windowed fleet telemetry, fed at tune ticks (live runs only —
        #: replay bypasses telemetry and tuner entirely)
        self.telemetry = FleetTelemetry(canonical=canonical_stream_model)
        #: dedicated RNG stream for the weight tuner's distant samples;
        #: replay never draws from it (tune decisions come from the trace)
        self._tuner_rng = np.random.default_rng([seed, 0x7D5E])
        self.tuner_retriggers = 0
        #: realized transfer times over shared per-node-pair links —
        #: uncontended (infinite link bandwidth) unless the TransferModel
        #: says otherwise; replay reconstructs it from the trace meta and
        #: re-derives identical queueing because the fleet clock totally
        #: orders transfer requests
        self.links = ContendedLinks(transfer) if transfer is not None else None
        # ------------------------------------------------ observability
        # one Obs bundle is shared fleet-wide: node simulators trace into
        # the same tracer/registry (tagged by node id), the admission
        # controller, links, and tuner publish into the same registry.
        # Every hook below is observation-only behind an ``is not None``
        # guard: obs-off runs take the identical code path as before, and
        # obs-on runs consume no RNG — both stay bit-exact (tests assert).
        self.obs = Obs.make(obs)
        self._tracer = self.obs.tracer if self.obs is not None else None
        self._metrics = self.obs.metrics if self.obs is not None else None
        self._profiler = self.obs.profiler if self.obs is not None else None
        if self._metrics is not None:
            if self.links is not None:
                self.links.metrics = self._metrics
            if self.slo is not None:
                self.slo.metrics = self._metrics
            if hasattr(type(self.policy), "metrics"):
                self.policy.metrics = self._metrics
            self._m_place = self._metrics.counter(
                "fleet_placements_total", "stream/stage placements",
                ("node",))
            self._m_migr = self._metrics.counter(
                "fleet_migrations_total", "stream/stage migrations",
                ("src", "dst"))
            self._m_rej = self._metrics.counter(
                "fleet_rejections_total", "streams refused admission",
                ("tier",))
            self._m_swap = self._metrics.counter(
                "fleet_swaps_total", "SLO degradation-ladder moves",
                ("direction",))
            self._m_trig = self._metrics.counter(
                "fleet_trigger_transfers_total",
                "cascade triggers that crossed nodes")
            self._m_streams = self._metrics.gauge(
                "fleet_streams", "streams currently placed")
        else:
            self._m_place = self._m_migr = self._m_rej = None
            self._m_swap = self._m_trig = self._m_streams = None
        #: simulated stream-seconds served (placement -> departure/end),
        #: accumulated regardless of obs so streams_per_wall_s is always
        #: derivable; rejected streams contribute nothing
        self.stream_seconds = 0.0
        self._stream_t0: dict[int, float] = {}
        self.nodes: dict[int, FleetNode] = {}
        #: _candidates() memo, cleared on any membership change
        self._cands_cache: dict[Optional[int], list[FleetNode]] = {}
        #: SoA telemetry columns over one candidate list (see _tel_columns)
        self._tel_cols: Optional[dict] = None
        self._tel_dirty: set[int] = set()
        #: persistent lazy (peek_t, node_id) min-heap driving the fleet
        #: clock: only nodes with events actually due are advanced, instead
        #: of rescanning every node at every fleet event.  Entries are
        #: lazily stale (a popped entry is re-validated against the node's
        #: true peek); the invariant is one-sided — the heap always holds
        #: an entry at or before each live node's true next-event time, so
        #: every operation that can schedule an *earlier* event on a node
        #: must call :meth:`_touch` (operations that only delay or remove
        #: events need not: early entries refresh themselves on pop)
        self._peek_heap: list[tuple[float, int]] = []
        #: node id -> time of its earliest live heap entry.  Entries a
        #: newer, earlier push superseded are discarded on pop instead of
        #: recycling forever, so the heap stays O(nodes), not O(touches)
        self._peek_at: dict[int, float] = {}
        #: node ids stepped by the current interleave pass (split mode),
        #: pending their recent-DLV refresh
        self._stepped: set[int] = set()
        self.streams: dict[int, StreamView] = {}
        self.stream_node: dict[int, int] = {}   # sid -> hosting node id
        self.gen: dict[int, int] = {}           # sid -> placement generation
        #: streams currently departed (lifecycle released); a rejoin
        #: removes the sid again.  Departed streams keep their StreamView
        #: (the rejoin re-places from it) but hold no placements.
        self.departed: set[int] = set()
        self.departures = 0
        self.rejoins = 0
        self.jobs_purged = 0
        # ---- SLO state, maintained identically live and in replay (live
        # decisions come from the controller, replayed ones from the trace)
        #: sid -> declared SLO class (absent = legacy tierless stream)
        self.stream_slo: dict[int, "object"] = {}
        #: sid -> current degradation-ladder level; presence (even at level
        #: 0) marks a stream the controller has touched — never-touched
        #: streams skip the variant plumbing entirely, which is what keeps
        #: a controller-free run bit-identical to the pre-SLO simulator
        self.slo_level: dict[int, int] = {}
        #: streams refused admission (cleared again by a depart)
        self.rejected: set[int] = set()
        #: sid -> (reject time, head fps) while the rejection span is open
        self._reject_open: dict[int, tuple[float, float]] = {}
        #: sid -> refused head frames accumulated over closed spans
        self._reject_frames: dict[int, float] = {}
        #: sid -> variant-ladder depth (max over stages), memoized
        self._ladder_cache: dict[int, int] = {}
        self.rejections = 0
        self.swaps = 0
        self.promotions = 0
        # stage-split bookkeeping, keyed by (sid, stage)
        self.stage_node: dict[tuple[int, int], int] = {}
        self.stage_gen: dict[tuple[int, int], int] = {}
        self.stage_name: dict[tuple[int, int], str] = {}
        #: when each stage's state is resident on its current node — a
        #: migrated stage cannot serve triggers while its weights are
        #: still on the wire
        self.stage_ready: dict[tuple[int, int], float] = {}
        #: namespaced name -> (sid, stage); grows only — in-flight jobs of a
        #: migrated-away residency still resolve their logical stage
        self._name_stage: dict[str, tuple[int, int]] = {}
        #: canonical model name -> transfer energy charged (J)
        self.xfer_energy: dict[str, float] = {}
        #: per-edge completion counters for counter-based trigger draws
        self._trigger_counts: dict[tuple[int, int], int] = {}
        self.migrations = 0
        self.stage_migrations = 0
        self.trigger_transfers = 0
        self.recorder = None
        self.trace: Optional[FleetTrace] = None
        if record:
            if replay is not None:
                raise ValueError("record and replay are mutually exclusive")
            meta = {
                "scenario": self.name, "policy": self.policy.name,
                "scheduler": self._scheduler_name,
                "seed": seed, "duration_s": duration_s,
                "window_s": window_s,
            }
            if self.transfer is not None:
                meta["transfer"] = self.transfer.to_config()
            if self.split:
                meta["split"] = True
            if self.tune_every_s is not None:
                # documentation only: replay takes weights from the
                # recorded `tune` events, never from a live tuner
                meta["tune_every_s"] = self.tune_every_s
            if self.slo is not None:
                # documentation only, like tune_every_s: replay applies the
                # recorded swap/reject decisions, never the controller —
                # and SLO-free runs keep their meta byte-identical
                meta["slo"] = self.slo.to_config()
                if self.slo_every_s is not None:
                    meta["slo_every_s"] = self.slo_every_s
            if not self.genai_predictor:
                # non-default only: legacy traces keep identical headers
                meta["genai_predictor"] = False
            self.recorder = FleetTraceRecorder(meta)

    # ---------------------------------------------------------- plumbing
    #: fleet-clock toggle: True drives advancement from the persistent
    #: lazy peek heap (only nodes with due events pay anything per fleet
    #: event); False rescans every node per event — the original O(N)
    #: path, kept alive as the equivalence-test oracle.  Both paths step
    #: each node's events in the identical (event time, node id) order,
    #: and skipping a node with nothing due is a pure no-op, so the flag
    #: never changes results.
    lazy_peek = True

    def _advance_all(self, t: float) -> None:
        """Advance every live node with due events to fleet time ``t``.
        Whole-stream mode advances node by node (cascades are node-local,
        so cross-node order is irrelevant — and this is the bit-exact PR-2
        path).  Stage-split mode interleaves nodes in global event order
        so cross-node triggers inject causally."""
        if not self.lazy_peek:
            self._advance_all_scan(t)
            return
        if self.split:
            self._interleave_to(t)
            # only stepped nodes can have moved their frame counters; the
            # scan path's post-sweep touched every node, but a no-step
            # refresh never changes recent_dlv or telemetry
            for nid in self._stepped:
                node = self.nodes[nid]
                if node.alive:
                    node._update_recent_dlv()
                    node._invalidate_telemetry()
            self._stepped.clear()
            return
        heap = self._peek_heap
        while heap and heap[0][0] <= t:
            pt, nid = heapq.heappop(heap)
            if self._peek_at.get(nid) != pt:
                continue            # superseded by an earlier push
            del self._peek_at[nid]
            node = self.nodes.get(nid)
            if node is None or not node.alive:
                continue            # departed member; entry is garbage
            cur = node.sim.peek_t()
            if cur is None:
                continue
            if cur > self._node_lim(node, t):
                if cur > t:
                    # nothing due yet — keep tracking the future event
                    self._push_peek(nid, cur)
                # else: past the node's own horizon, unreachable — drop
                continue
            node.advance_to(t)
            nxt = node.sim.peek_t()
            if nxt is not None:
                self._push_peek(nid, nxt)

    def _advance_all_scan(self, t: float) -> None:
        """Reference fleet clock: full rescan of every node per event."""
        if self.split:
            self._interleave_to_scan(t)
        for nid in sorted(self.nodes):
            self.nodes[nid].advance_to(t)

    def _push_peek(self, nid: int, pt: float) -> None:
        cur = self._peek_at.get(nid)
        if cur is not None and cur <= pt:
            return                  # an entry at/before pt already lives
        self._peek_at[nid] = pt
        heapq.heappush(self._peek_heap, (pt, nid))

    def _touch(self, nid: int) -> None:
        """Re-arm the peek heap after an operation that may have scheduled
        an earlier event on node ``nid``'s simulator (placement, phase
        action, cascade injection, join)."""
        node = self.nodes.get(nid)
        if node is None or not node.alive:
            return
        pt = node.sim.peek_t()
        if pt is not None:
            self._push_peek(nid, pt)

    def _node_lim(self, node: FleetNode, t: float) -> float:
        return min(t, node.sim.duration_s)

    def _interleave_to(self, t: float) -> None:
        """Step all live nodes' simulators in global event-time order
        (ties: lowest node id first) off the persistent peek heap, draining
        exported cascade completions after every step and injecting the
        resulting triggers — possibly into other nodes, whose heap entries
        are refreshed lazily.  A node is only stepped when its popped entry
        matches its true peek, so the realized step order is the same
        (time, node id) sequence the scan-based oracle produces."""
        heap = self._peek_heap
        stepped = self._stepped
        while heap and heap[0][0] <= t:
            pt, nid = heapq.heappop(heap)
            if self._peek_at.get(nid) != pt:
                continue            # superseded by an earlier push
            del self._peek_at[nid]
            node = self.nodes.get(nid)
            if node is None or not node.alive:
                continue
            cur = node.sim.peek_t()
            if cur is None:
                continue
            if cur > self._node_lim(node, t):
                if cur > t:
                    self._push_peek(nid, cur)
                continue            # stale entry; node has nothing due
            if cur != pt:
                self._push_peek(nid, cur)
                continue            # refresh stale entry, keep ordering
            node.sim.step()
            stepped.add(nid)
            for t_inj, dst in self._drain_triggers(node):
                dnode = self.nodes[dst]
                if dst != nid and dnode.alive:
                    self._push_peek(dst, t_inj)
            nxt = node.sim.peek_t()
            if nxt is not None:
                self._push_peek(nid, nxt)

    def _interleave_to_scan(self, t: float) -> None:
        """Reference interleave: rebuild a fresh heap from a full node scan
        (the pre-lazy-peek path, kept as the equivalence-test oracle)."""
        heap: list[tuple[float, int]] = []
        for nid in sorted(self.nodes):
            node = self.nodes[nid]
            if not node.alive:
                continue
            pt = node.sim.peek_t()
            if pt is not None and pt <= self._node_lim(node, t):
                heapq.heappush(heap, (pt, nid))
        while heap:
            pt, nid = heapq.heappop(heap)
            node = self.nodes[nid]
            if not node.alive:
                continue
            cur = node.sim.peek_t()
            if cur is None or cur > self._node_lim(node, t):
                continue            # stale entry; node has nothing due
            if cur != pt:
                heapq.heappush(heap, (cur, nid))
                continue            # refresh stale entry, keep ordering
            node.sim.step()
            for t_inj, dst in self._drain_triggers(node):
                dnode = self.nodes[dst]
                if (dst != nid and dnode.alive
                        and t_inj <= self._node_lim(dnode, t)):
                    heapq.heappush(heap, (t_inj, dst))
            nxt = node.sim.peek_t()
            if nxt is not None and nxt <= self._node_lim(node, t):
                heapq.heappush(heap, (nxt, nid))

    def _drain_triggers(self, node: FleetNode) -> list[tuple[float, int]]:
        """Forward the node's exported cascade completions to the current
        hosts of their dependent stages.  Cross-node edges pay the
        activation transfer: the child frame arrives ``transfer_s`` later
        (deadline still anchored at the parent's completion, so the wire
        eats real slack) and the link energy is charged to the child's
        fleet UXCost entry.  Returns (injection time, node id) pairs for
        the interleave heap."""
        if not node.sim.pending_completions:
            return []
        pend = node.sim.pending_completions
        node.sim.pending_completions = []
        pushes: list[tuple[float, int]] = []
        for name, tc, origin, parent_uid in pend:
            key = self._name_stage.get(name)
            if key is None:
                continue
            sid, k = key
            sv = self.streams[sid]
            for ck, prob in sv.children_of(k):
                if not self._trigger_fires(sid, ck, prob):
                    continue
                dst = self.stage_node.get((sid, ck))
                if dst is None or not self.nodes[dst].alive:
                    continue
                t_inj = tc
                wire_s = 0.0
                if dst != node.node_id:
                    nbytes = sv.act_bytes_into(ck)
                    # shared-link realization: a trigger behind another
                    # transfer on the same node pair queues for the wire
                    xfer_s, xfer_j = self.links.transfer(
                        node.node_id, dst, nbytes, tc)
                    t_inj = tc + xfer_s
                    wire_s = xfer_s
                    self._charge(f"s{sid}." + sv.stage_base(ck), xfer_j)
                    self.trigger_transfers += 1
                    if self._tracer is not None:
                        self._tracer.span(
                            "xfer", tc, t_inj, stream=sid, stage=ck,
                            src=node.node_id, dst=dst, nbytes=nbytes,
                            xfer_s=xfer_s, xfer_j=xfer_j)
                    if self._metrics is not None:
                        self._m_trig.inc()
                # a freshly-migrated child serves nothing until its weight
                # state lands; early triggers queue until residency (the
                # deadline anchor stays at the parent completion, so the
                # wait eats real slack)
                t_inj = max(t_inj, self.stage_ready.get((sid, ck), t_inj))
                self.nodes[dst].sim.inject_arrival(
                    self.stage_name[(sid, ck)], t_inj, deadline_anchor=tc,
                    origin=origin, parent_uid=parent_uid, xfer_s=wire_s)
                pushes.append((t_inj, dst))
        return pushes

    def _trigger_fires(self, sid: int, ck: int, prob: float) -> bool:
        """Counter-based Bernoulli draw for cascade edge (sid -> stage ck):
        the n-th parent completion of an edge draws a keyed hash of
        (fleet seed, stream, edge, n), so the realized trigger sequence
        is a property of the *workload*, not of placement or event
        interleaving — whole-pipeline and stage-split runs of one scenario
        face identical cascade realizations, and replay needs no trace
        records for triggers."""
        n = self._trigger_counts.get((sid, ck), 0)
        self._trigger_counts[(sid, ck)] = n + 1
        return _hash_u01(self.seed, _TRIGGER_STREAM, sid, ck, n) < prob

    def _charge(self, canonical: str, joules: float) -> None:
        self.xfer_energy[canonical] = (self.xfer_energy.get(canonical, 0.0)
                                       + joules)

    def _candidates(self, exclude: Optional[int] = None) -> list[FleetNode]:
        # memoized per `exclude`: membership state only changes at
        # node_join/node_leave/node_drain, each of which clears the cache
        cands = self._cands_cache.get(exclude)
        if cands is None:
            cands = _CandidateList(
                self.nodes[nid] for nid in sorted(self.nodes)
                if self.nodes[nid].alive and not self.nodes[nid].draining
                and nid != exclude)
            cands._fleet = self
            self._cands_cache[exclude] = cands
        return cands

    def _tel_columns(self, cands: "_CandidateList") -> dict:
        """SoA telemetry columns for one candidate list: per-node arrays of
        the four fields batched placement scoring reads, plus the
        per-system node groups used to fill cost columns with one
        ``cost_on`` per distinct accelerator mix.  Values are copied out of
        the same memoized ``telemetry()`` snapshots the scalar path reads;
        only rows whose node fired the telemetry dirty hook are re-read."""
        cols = self._tel_cols
        if cols is None or cols["cands"] is not cands:
            groups: dict = {}
            for i, node in enumerate(cands):
                key = (node.system if node.system != "custom"
                       else ("node", node.node_id))
                groups.setdefault(key, (node, []))[1].append(i)
            n = len(cands)
            cols = {
                "cands": cands,
                "ids": np.array([nd.node_id for nd in cands],
                                dtype=np.int64),
                "row_of": {nd.node_id: i for i, nd in enumerate(cands)},
                "groups": [(nd, np.array(ix, dtype=np.intp))
                           for nd, ix in groups.values()],
                "offered_util": np.empty(n), "n_accs": np.empty(n),
                "backlog": np.empty(n), "dlv": np.empty(n),
            }
            for i, node in enumerate(cands):
                tel = node.telemetry()
                cols["offered_util"][i] = tel.offered_util
                cols["n_accs"][i] = tel.n_accs
                cols["backlog"][i] = tel.backlog_s
                cols["dlv"][i] = tel.window_dlv
            self._tel_cols = cols
            self._tel_dirty.clear()
            return cols
        if self._tel_dirty:
            row_of = cols["row_of"]
            for nid in self._tel_dirty:
                i = row_of.get(nid)
                if i is None:
                    continue
                tel = self.nodes[nid].telemetry()
                cols["offered_util"][i] = tel.offered_util
                cols["n_accs"][i] = tel.n_accs
                cols["backlog"][i] = tel.backlog_s
                cols["dlv"][i] = tel.window_dlv
            self._tel_dirty.clear()
        return cols

    # ------------------------------------------------ whole-stream placement
    def _place(self, sid: int, nid: int, t: float, gen: int) -> None:
        sv = self.streams[sid]
        specs, names = sv.namespaced_specs(gen)
        self.nodes[nid].place(sid, specs, names, t)
        self.stream_node[sid] = nid
        self.gen[sid] = gen
        self._stream_t0.setdefault(sid, t)
        if self._tracer is not None:
            self._tracer.event("place", t, stream=sid, node=nid, gen=gen)
        if self._metrics is not None:
            self._m_place.inc(node=nid)
            self._m_streams.set(len(self._stream_t0))
        # re-materialize the stream's SLO ladder level on the (possibly
        # new) host: every re-placement mints generation-fresh names, so
        # the variant pin must follow the stream.  No-op for streams the
        # controller never touched (the bit-identical inert path).
        level = self.slo_level.get(sid)
        if level is not None:
            self.nodes[nid].swap_level(names, level, t)
        self._touch(nid)

    def _migrate(self, sid: int, src: int, dst: int, t: float,
                 gen: int) -> tuple[Optional[float], Optional[float]]:
        """Move a whole stream; returns the (latency, energy) charged, or
        (None, None) when no transfer model is active."""
        self.nodes[src].evict(sid, t)
        xfer_s = xfer_j = None
        t_place = t
        if self.transfer is not None:
            sv = self.streams[sid]
            total = sum(sv.state_bytes(k) for k in range(sv.n_stages))
            if self.transfer.enabled:
                xfer_s, xfer_j = self.links.transfer(src, dst, total, t)
            else:
                # air-gapped: weights reload from node-local storage
                xfer_s, xfer_j = 0.0, self.transfer.transfer_j(total)
            t_place = t + xfer_s
            for k in range(sv.n_stages):
                self._charge(f"s{sid}." + sv.stage_base(k),
                             self.transfer.transfer_j(sv.state_bytes(k)))
        self._place(sid, dst, t_place, gen)
        self.migrations += 1
        if self._tracer is not None:
            self._tracer.span("migrate", t, t_place, stream=sid, src=src,
                              dst=dst, gen=gen, xfer_s=xfer_s,
                              xfer_j=xfer_j)
        if self._metrics is not None:
            self._m_migr.inc(src=src, dst=dst)
        return xfer_s, xfer_j

    # ------------------------------------------------ stage-split placement
    def _place_stage(self, sid: int, k: int, nid: int, t: float,
                     gen: int) -> None:
        sv = self.streams[sid]
        spec, name = sv.stage_spec(k, gen)
        node = self.nodes[nid]
        w = (1.0 if sv.parent_of(k) is None
             else sv.entries[k].trigger_prob)
        node.place((sid, k), [spec], [name], t, weights=[w])
        if sv.children_of(k):
            # parent stages report completions so the fleet can forward
            # cascade triggers (same-node edges included)
            node.sim.export_completions.add(name)
        self.stage_node[(sid, k)] = nid
        self.stage_gen[(sid, k)] = gen
        self.stage_name[(sid, k)] = name
        self.stage_ready[(sid, k)] = t   # migrations pass t + transfer_s
        self._name_stage[name] = (sid, k)
        self._stream_t0.setdefault(sid, t)
        if self._tracer is not None:
            self._tracer.event("place", t, stream=sid, stage=k, node=nid,
                               gen=gen)
        if self._metrics is not None:
            self._m_place.inc(node=nid)
            self._m_streams.set(len(self._stream_t0))
        # the SLO variant pin follows the stage across re-placements (see
        # _place); stage granularity, so sibling stages are untouched
        level = self.slo_level.get(sid)
        if level is not None:
            node.swap_level([name], level, t)
        self._touch(nid)

    def _migrate_stage(self, sid: int, k: int, src: int, dst: int, t: float,
                       gen: int) -> tuple[float, float]:
        """Move one stage; returns the (latency, energy) charged.  The
        re-placement is delayed by the state-transfer latency; with a
        zero-bandwidth link the state reloads from node-local storage
        instead (energy only, no wire delay)."""
        self.nodes[src].evict((sid, k), t)
        sv = self.streams[sid]
        nbytes = sv.state_bytes(k)
        if self.transfer.enabled:
            xfer_s, xfer_j = self.links.transfer(src, dst, nbytes, t)
        else:
            xfer_s, xfer_j = 0.0, self.transfer.transfer_j(nbytes)
        self._charge(f"s{sid}." + sv.stage_base(k), xfer_j)
        self._place_stage(sid, k, dst, t + xfer_s, gen)
        self.migrations += 1
        self.stage_migrations += 1
        if self._tracer is not None:
            self._tracer.span("migrate", t, t + xfer_s, stream=sid,
                              stage=k, src=src, dst=dst, gen=gen,
                              xfer_s=xfer_s, xfer_j=xfer_j)
        if self._metrics is not None:
            self._m_migr.inc(src=src, dst=dst)
        return xfer_s, xfer_j

    def _stage_score_full(self, sid: int, k: int, node: FleetNode,
                          best_iso: float) -> float:
        """Stage score including *all* cascade edges the placement would
        cut: the parent edge (via the router) plus edges to already-placed
        children — so a head cannot drift away from its children for free
        during drains and rebalances.  Edges to stages on draining or dead
        nodes are ignored: those stages must move regardless, and pricing
        them (infinitely, under zero bandwidth) would otherwise make every
        candidate look equally bad and collapse the argmin onto the lowest
        node id."""
        sv = self.streams[sid]
        p = sv.parent_of(k)
        parent_nid = self.stage_node.get((sid, p)) if p is not None else None
        if parent_nid is not None:
            pn = self.nodes[parent_nid]
            if not pn.alive or pn.draining:
                parent_nid = None
        s = self.policy.stage_score(sv, k, node, best_iso, parent_nid,
                                    self.transfer)
        for ck, _prob in sv.children_of(k):
            cn = self.stage_node.get((sid, ck))
            if cn is None or cn == node.node_id:
                continue
            cnode = self.nodes[cn]
            if not cnode.alive or cnode.draining:
                continue
            s += self.policy.transfer_penalty(sv, ck, self.transfer)
        return s

    def _pick_stage_dst(self, sid: int, k: int,
                        cands: list[FleetNode]) -> int:
        """Destination for one migrating stage.  Non-splitting policies
        keep streams co-located: a stage follows its (already re-placed)
        parent, and heads re-run whole-stream placement — so the
        ``score_whole`` control arm and round-robin/least-loaded fleets
        never split a pipeline through churn.  Splitting policies re-score
        the stage with all its cascade edges."""
        sv = self.streams[sid]
        if not getattr(self.policy, "splits_stages", False):
            p = sv.parent_of(k)
            if p is not None:
                pn = self.stage_node.get((sid, p))
                if pn is not None and any(n.node_id == pn for n in cands):
                    return pn
            return self.policy.place(sv, cands)
        best_iso = min(sv.stage_cost_on(n, k).iso_s for n in cands)
        return argmin_node(
            cands, lambda n: self._stage_score_full(sid, k, n, best_iso))

    # ------------------------------------------------------ event handlers
    def _rearm_tuner(self) -> None:
        """Membership churn / phase events re-arm the fleet weight tuner
        (live runs only: replay installs recorded weights instead) — the
        fleet-level mirror of each node's ``retrigger_probe``."""
        rearm = getattr(self.policy, "rearm", None)
        if self.replay is None and rearm is not None:
            rearm()
            self.tuner_retriggers += 1

    def _on_node_join(self, t: float, ev: dict) -> None:
        nid, system = int(ev["node"]), ev["system"]
        if nid in self.nodes:
            raise ValueError(f"node {nid} joined twice")
        ns = node_seed(self.seed, nid)
        self.nodes[nid] = FleetNode(
            nid, system, self.scheduler_factory(ns),
            duration_s=self.duration_s, seed=ns,
            window_s=self.window_s, at_t=t,
            genai_predictor=self.genai_predictor, engine=self.engine,
            obs=self.obs)
        self.nodes[nid].tel_dirty_hook = self._tel_dirty.add
        self._cands_cache.clear()
        if self.recorder is not None:
            self.recorder.node_join(t, nid, system)
        self._touch(nid)
        if self._tracer is not None:
            self._tracer.event("node_join", t, node=nid, system=str(system))
        self._rearm_tuner()

    def _on_node_leave(self, t: float, ev: dict) -> None:
        node = self.nodes[int(ev["node"])]
        if self.recorder is not None:
            self.recorder.node_leave(t, node.node_id)
        if self.replay is None:
            self._migrate_all_off(node, t)
        node.alive = False
        self._cands_cache.clear()
        if self._tracer is not None:
            self._tracer.event("node_leave", t, node=node.node_id)
        self._rearm_tuner()

    def _on_node_drain(self, t: float, ev: dict) -> None:
        node = self.nodes[int(ev["node"])]
        if self.recorder is not None:
            self.recorder.node_drain(t, node.node_id)
        node.draining = True
        self._cands_cache.clear()
        node._invalidate_telemetry()
        if self.replay is None:
            self._migrate_all_off(node, t)
        if self._tracer is not None:
            self._tracer.event("node_drain", t, node=node.node_id)
        self._rearm_tuner()

    def _on_phase(self, t: float, ev: dict) -> None:
        """Fleet-level phase event: forward the (stream-addressed) action
        to every targeted stream's hosting node(s) as a node-local phase
        action on its namespaced model names.  Runs identically live and
        in replay — placements at time ``t`` are identical, so the
        forwarded node-local actions are too.  Streams that have not
        arrived yet are skipped (a phase cannot retarget the future); the
        touched nodes' (alpha, beta) probes re-arm, and so does the fleet
        weight tuner."""
        action_cfg = dict(ev["action"])
        sids = ev.get("sids")
        targets = (sorted(self.streams) if sids is None
                   else [int(s) for s in sids])
        for sid in targets:
            sv = self.streams.get(sid)
            if sv is None or sid in self.departed or sid in self.rejected:
                # a phase cannot retarget the future (stream not arrived)
                # or the absent (departed; it rejoins at its last-seen
                # definition — and a rejected stream is not serving, so
                # there is nothing to mutate) — identical live and in
                # replay, since rejections are replayed as inputs
                continue
            by_node: dict[int, list[str]] = {}
            if self.split:
                for k in range(sv.n_stages):
                    nid = self.stage_node.get((sid, k))
                    if nid is not None:
                        by_node.setdefault(nid, []).append(
                            self.stage_name[(sid, k)])
            else:
                nid = self.stream_node.get(sid)
                if nid is not None:
                    by_node[nid] = list(self.nodes[nid].placements.get(
                        sid, ()))
            for nid in sorted(by_node):
                node = self.nodes[nid]
                if not node.alive or not by_node[nid]:
                    continue
                node.sim.apply_action(
                    PhaseAction.from_config(
                        dict(action_cfg, models=by_node[nid])), t)
                node._recompute_offered()
                node.retrigger_probe()
                self._touch(nid)
            if action_cfg["kind"] == "scale_fps":
                # keep the stream's own definition in sync so later
                # migrations re-place at the shifted rate
                sv.rescale_fps(float(action_cfg["factor"]))
        if self.recorder is not None:
            self.recorder.phase(t, action_cfg, sids)
        self._rearm_tuner()

    def _on_tune(self, t: float, ev: dict) -> None:
        """Live: a synthetic tune tick — close a telemetry window and feed
        it to the weight tuner, recording the committed weights.  Replay: a
        recorded tuner decision — install the weights directly, bypassing
        telemetry and probe entirely."""
        if self.replay is not None:
            set_weights = getattr(self.policy, "set_weights", None)
            if set_weights is not None:
                set_weights(ev["weights"])
            return
        win = self.telemetry.observe(t, self.nodes, self.migrations,
                                     sum(self.xfer_energy.values()),
                                     departures=self.departures,
                                     rejections=self.rejections,
                                     swaps=self.swaps)
        if self._tracer is not None:
            self._tracer.event("tune", t, uxcost=win.uxcost,
                               frames=win.frames, dlv=win.dlv_rate,
                               backlog_p90=win.backlog_p90)
        if self._metrics is not None:
            g = self._metrics.gauge(
                "fleet_window_uxcost", "UXCost of the last tuner window")
            g.set(win.uxcost)
            self._metrics.gauge(
                "fleet_window_dlv_rate",
                "DLV rate of the last tuner window").set(win.dlv_rate)
        on_window = getattr(self.policy, "on_window", None)
        if on_window is None:
            return                      # telemetry-only tick
        weights = on_window(win, self._tuner_rng)
        if weights is not None and self.recorder is not None:
            self.recorder.tune(t, list(weights), window_uxcost=win.uxcost,
                               probing=self.policy.probe.probing)

    def _migrate_all_off(self, node: FleetNode, t: float) -> None:
        for key in sorted(node.placements):
            cands = self._candidates(exclude=node.node_id)
            if not cands:
                raise RuntimeError(
                    f"no live nodes left to host {key} at t={t}")
            if self.split:
                sid, k = key
                dst = self._pick_stage_dst(sid, k, cands)
                gen = self.stage_gen[(sid, k)] + 1
                xfer_s, xfer_j = self._migrate_stage(
                    sid, k, node.node_id, dst, t, gen)
                if self.recorder is not None:
                    self.recorder.migrate(t, sid, node.node_id, dst, gen,
                                          stage=k, xfer_s=xfer_s,
                                          xfer_j=xfer_j)
            else:
                sid = key
                dst = self.policy.place(self.streams[sid], cands)
                gen = self.gen[sid] + 1
                xfer_s, xfer_j = self._migrate(sid, node.node_id, dst, t,
                                               gen)
                if self.recorder is not None:
                    self.recorder.migrate(t, sid, node.node_id, dst, gen,
                                          xfer_s=xfer_s, xfer_j=xfer_j)

    # ------------------------------------------------------ SLO subsystem
    def _ladder_depth(self, sid: int) -> int:
        """Degradation-ladder depth of a stream: the deepest supernet
        variant ladder over its stages (0 = no variants, nothing to swap)."""
        d = self._ladder_cache.get(sid)
        if d is None:
            sv = self.streams[sid]
            d = max((len(sv.stage_graph(k).variants)
                     for k in range(sv.n_stages)), default=0)
            self._ladder_cache[sid] = d
        return d

    def _live_utils(self, cands: list[FleetNode]) -> list[float]:
        """Per-candidate offered utilization right now — the U(t) input of
        the admission law."""
        return [n.offered_s / len(n.sim.accs) for n in cands]

    def _apply_level(self, sid: int, t: float) -> None:
        """Materialize stream ``sid``'s current ladder level on its hosting
        node(s).  Streams the controller never touched return immediately,
        keeping the controller-free path bit-identical to pre-SLO runs."""
        level = self.slo_level.get(sid)
        if level is None:
            return
        sv = self.streams[sid]
        if self.split:
            for k in range(sv.n_stages):
                nid = self.stage_node.get((sid, k))
                if nid is not None and self.nodes[nid].alive:
                    self.nodes[nid].swap_level(
                        [self.stage_name[(sid, k)]], level, t)
        else:
            nid = self.stream_node.get(sid)
            if nid is not None and self.nodes[nid].alive:
                names = list(self.nodes[nid].placements.get(sid, ()))
                if names:
                    self.nodes[nid].swap_level(names, level, t)

    def _apply_level_change(self, sid: int, level: int, t: float) -> None:
        """One degradation-ladder move (live decision or replayed ``swap``
        record): update the level, swap the hosted variants, re-arm the
        fleet tuner — a quality change shifts offered load, which is as
        much a workload change as churn is."""
        prev = self.slo_level.get(sid, 0)
        if level == prev:
            return
        self.swaps += 1
        if level < prev:
            self.promotions += 1
        self.slo_level[sid] = level
        self._apply_level(sid, t)
        if self._tracer is not None:
            self._tracer.event(
                "swap", t, stream=sid, level=level, prev=prev,
                pressure=(self.slo.last_pressure
                          if self.slo is not None else None),
                terms=(dict(self.slo.last_terms)
                       if self.slo is not None else None))
        if self._metrics is not None:
            self._m_swap.inc(
                direction="promote" if level < prev else "degrade")
        self._rearm_tuner()

    def _reject_stream(self, t: float, sid: int) -> None:
        """Refuse a stream admission (live verdict or replayed ``reject``
        record): no placement happens; the refused head frames accrue as
        deadline violations until the stream departs (or the run ends), so
        a rejection is a first-class UXCost outcome, never a silent drop."""
        sv = self.streams[sid]
        self.rejected.add(sid)
        self._reject_open[sid] = (t, sv.entries[0].fps)
        self.rejections += 1
        tier = self.stream_slo.get(sid, DEFAULT_SLO).tier
        if self.recorder is not None:
            self.recorder.reject(t, sid, tier,
                                 pressure=self.slo.last_pressure
                                 if self.slo is not None else None)
        if self._tracer is not None:
            self._tracer.event(
                "reject", t, stream=sid, tier=tier,
                pressure=(self.slo.last_pressure
                          if self.slo is not None else None),
                terms=(dict(self.slo.last_terms)
                       if self.slo is not None else None))
        if self._metrics is not None:
            self._m_rej.inc(tier=tier)

    def _close_reject(self, sid: int, t: float) -> None:
        t0_fps = self._reject_open.pop(sid, None)
        if t0_fps is None:
            return
        t0, fps = t0_fps
        t1 = min(t, self.duration_s)
        if t1 > t0:
            self._reject_frames[sid] = (self._reject_frames.get(sid, 0.0)
                                        + (t1 - t0) * fps)

    def _on_swap(self, t: float, ev: dict) -> None:      # replay only
        self._apply_level_change(int(ev["sid"]), int(ev["level"]), t)

    def _on_reject(self, t: float, ev: dict) -> None:    # replay only
        self._reject_stream(t, int(ev["sid"]))

    def _on_slo_tick(self, t: float, ev: dict) -> None:  # live only
        """Controller tick: close an SLO telemetry window, update the
        pressure, and walk the degradation ladder — degrade the weakest
        placed streams under sustained pressure, promote them back (one
        level per tick) once pressure clears the hysteresis band."""
        cands = self._candidates()
        win = self._slo_tel.observe(t, self.nodes, self.migrations,
                                    sum(self.xfer_energy.values()),
                                    departures=self.departures,
                                    rejections=self.rejections,
                                    swaps=self.swaps)
        self.slo.on_window(win, self._live_utils(cands))
        if self._tracer is not None:
            self._tracer.event("slo_tick", t,
                               pressure=self.slo.last_pressure,
                               terms=dict(self.slo.last_terms),
                               streams=len(self.streams)
                               - len(self.departed) - len(self.rejected))
        states = []
        for sid in sorted(self.streams):
            if sid in self.departed or sid in self.rejected:
                continue
            depth = self._ladder_depth(sid)
            if depth == 0:
                continue
            slo = self.stream_slo.get(sid, DEFAULT_SLO)
            # local pressure: the hosting node's window DLV (max across
            # stages for split placements) — the ladder degrades victims
            # on the hottest nodes first, where the swap relieves the
            # pressured tier-0 neighbours
            if self.split:
                nids = [self.stage_node.get((sid, k))
                        for k in range(self.streams[sid].n_stages)]
            else:
                nids = [self.stream_node.get(sid)]
            load = max((win.node_dlv.get(nid, 0.0)
                        for nid in nids if nid is not None), default=0.0)
            states.append(StreamState(
                sid=sid, tier=slo.tier, priority=slo.priority,
                level=self.slo_level.get(sid, 0), max_level=depth,
                load=load))
        for sid, level in self.slo.plan(states):
            self._apply_level_change(sid, level, t)
            if self.recorder is not None:
                self.recorder.swap(t, sid, level,
                                   pressure=self.slo.last_pressure)

    def _on_stream(self, t: float, ev: dict) -> None:
        sid = int(ev["sid"])
        self.streams[sid] = StreamView(sid, ev["entries"])
        slo_cfg = ev.get("slo")
        if slo_cfg is not None:
            self.stream_slo[sid] = slo_from_config(slo_cfg)
            self.streams[sid].budget_factor = \
                self.stream_slo[sid].budget_factor
        if self._tracer is not None:
            self._tracer.event("stream", t, stream=sid,
                               stages=self.streams[sid].n_stages)
        if self.recorder is not None:
            self.recorder.stream(t, sid, ev["entries"], slo=slo_cfg)
        if self.replay is not None:
            return                       # recorded `place` events follow
        cands = self._candidates()
        if not cands:
            raise RuntimeError(f"stream {sid} arrived with no live nodes")
        sv = self.streams[sid]
        level = 0
        if self.slo is not None:
            slo = self.stream_slo.get(sid, DEFAULT_SLO)
            self.slo.register(sid, slo, sv.head_period_s)
            verdict, level = self.slo.admit(
                slo, self._ladder_depth(sid), self._live_utils(cands))
            if self._tracer is not None:
                self._tracer.event("admit", t, stream=sid, tier=slo.tier,
                                   verdict=verdict, level=level,
                                   pressure=self.slo.last_pressure,
                                   terms=dict(self.slo.last_terms))
            if verdict == "reject":
                self._reject_stream(t, sid)
                return
        if level > 0:
            # degraded admission: the level is set (and the swap recorded)
            # BEFORE placement so the trailing re-pin in _place applies the
            # variant ahead of the stream's first frame — replay interleaves
            # a node advance between the place and any later record, so a
            # swap recorded after placement would miss same-time arrivals
            self._apply_level_change(sid, level, t)
            if self.recorder is not None:
                self.recorder.swap(t, sid, level,
                                   pressure=self.slo.last_pressure)
        if self.split:
            nids = self.policy.place_stages(sv, cands, self.transfer)
            for k, nid in enumerate(nids):
                self._place_stage(sid, k, nid, t, gen=0)
                if self.recorder is not None:
                    self.recorder.place(t, sid, nid, 0, stage=k)
        else:
            nid = self.policy.place(sv, cands)
            self._place(sid, nid, t, gen=0)
            if self.recorder is not None:
                self.recorder.place(t, sid, nid, 0)

    def _on_depart(self, t: float, ev: dict) -> None:
        """Stream departure — the load-release half of task dynamicity.
        Runs identically live and in replay (placements at ``t`` are
        identical, so the eviction and purge are too): the stream is
        evicted from its hosting node(s), its queued-but-not-running
        frames are purged without counting against UXCost (the user
        walked away; jobs already executing finish and count), the
        touched nodes' (alpha, beta) probes re-arm via the eviction path,
        and the fleet weight tuner re-arms — less offered load is as much
        a workload change as more."""
        sid = int(ev["sid"])
        sv = self.streams.get(sid)
        if sv is None or sid in self.departed:
            raise ValueError(f"depart of stream {sid} at t={t}: stream "
                             "is not present (bad scenario or trace)")
        if sid in self.rejected:
            # a refused stream departing closes its rejection span: frames
            # it would have offered stop accruing as violations
            self.rejected.discard(sid)
            self._close_reject(sid, t)
        if self.slo is not None:
            self.slo.forget(sid)
        purged = 0
        if self.split:
            for k in range(sv.n_stages):
                nid = self.stage_node.pop((sid, k), None)
                if nid is not None and self.nodes[nid].alive:
                    purged += self.nodes[nid].release((sid, k), t)
                self.stage_ready.pop((sid, k), None)
        else:
            nid = self.stream_node.pop(sid, None)
            if nid is not None and self.nodes[nid].alive:
                purged += self.nodes[nid].release(sid, t)
        self.departed.add(sid)
        self.departures += 1
        self.jobs_purged += purged
        # stream-seconds accounting is obs-independent: the benchmark's
        # streams_per_wall_s throughput figure needs it with obs disabled
        t0 = self._stream_t0.pop(sid, None)
        if t0 is not None:
            self.stream_seconds += max(0.0, min(t, self.duration_s) - t0)
        if self._tracer is not None:
            self._tracer.event("depart", t, stream=sid, purged=purged)
        if self._m_streams is not None:
            self._m_streams.set(len(self._stream_t0))
        if self.recorder is not None:
            self.recorder.depart(t, sid, purged)
        self._rearm_tuner()

    def _on_rejoin(self, t: float, ev: dict) -> None:
        """A departed stream returns: the router re-places its recorded
        pipeline definition under a fresh placement generation, exactly
        like a new arrival (replay: the recorded ``place`` events
        follow).  The sudden load is a workload change, so the fleet
        tuner re-arms here too."""
        sid = int(ev["sid"])
        if sid not in self.departed:
            raise ValueError(f"rejoin of stream {sid} at t={t} without a "
                             "preceding depart (bad scenario or trace)")
        self.departed.discard(sid)
        self.rejoins += 1
        if self._tracer is not None:
            self._tracer.event("rejoin", t, stream=sid)
        if self.recorder is not None:
            self.recorder.rejoin(t, sid)
        self._rearm_tuner()
        if self.replay is not None:
            return                       # recorded `place` events follow
        cands = self._candidates()
        if not cands:
            raise RuntimeError(f"stream {sid} rejoined with no live nodes")
        sv = self.streams[sid]
        level = 0
        if self.slo is not None:
            # a rejoin is an arrival for admission purposes: the returning
            # load faces the same gate (and may be refused again)
            slo = self.stream_slo.get(sid, DEFAULT_SLO)
            self.slo.register(sid, slo, sv.head_period_s)
            verdict, level = self.slo.admit(
                slo, self._ladder_depth(sid), self._live_utils(cands))
            if self._tracer is not None:
                self._tracer.event("admit", t, stream=sid, tier=slo.tier,
                                   verdict=verdict, level=level,
                                   pressure=self.slo.last_pressure,
                                   terms=dict(self.slo.last_terms))
            if verdict == "reject":
                self._reject_stream(t, sid)
                return
        if level > 0:
            # swap-before-place, for the same replay-ordering reason as at
            # first arrival (see _on_stream)
            self._apply_level_change(sid, level, t)
            if self.recorder is not None:
                self.recorder.swap(t, sid, level,
                                   pressure=self.slo.last_pressure)
        if self.split:
            nids = self.policy.place_stages(sv, cands, self.transfer)
            for k, nid in enumerate(nids):
                gen = self.stage_gen.get((sid, k), -1) + 1
                self._place_stage(sid, k, nid, t, gen=gen)
                if self.recorder is not None:
                    self.recorder.place(t, sid, nid, gen, stage=k)
        else:
            nid = self.policy.place(sv, cands)
            gen = self.gen.get(sid, -1) + 1
            self._place(sid, nid, t, gen=gen)
            if self.recorder is not None:
                self.recorder.place(t, sid, nid, gen)

    def _on_place(self, t: float, ev: dict) -> None:       # replay only
        if "stage" in ev:
            self._place_stage(int(ev["sid"]), int(ev["stage"]),
                              int(ev["node"]), t, int(ev["gen"]))
        else:
            self._place(int(ev["sid"]), int(ev["node"]), t, int(ev["gen"]))

    def _on_migrate(self, t: float, ev: dict) -> None:     # replay only
        if "stage" in ev:
            self._migrate_stage(int(ev["sid"]), int(ev["stage"]),
                                int(ev["from"]), int(ev["to"]), t,
                                int(ev["gen"]))
        else:
            self._migrate(int(ev["sid"]), int(ev["from"]), int(ev["to"]), t,
                          int(ev["gen"]))

    def _on_rebalance(self, t: float, ev: dict) -> None:   # live only
        """Optional phase-boundary re-placement: move a stream (or, in
        stage-split mode, a single stage) when the score-driven router now
        prefers another node by a clear margin."""
        if not isinstance(self.policy, ScoreDrivenRouter):
            return
        cands = self._candidates()          # membership is fixed in-tick
        if len(cands) < 2:
            return
        if self.split:
            # each policy rebalances at its own placement granularity:
            # splitting policies move single stages, non-splitting ones
            # move whole co-located streams — so control arms correct
            # placement mistakes too, just never by splitting a pipeline
            if getattr(self.policy, "splits_stages", False):
                self._rebalance_stages(t, cands)
            else:
                self._rebalance_streams_whole(t, cands)
            return
        for sid in sorted(self.stream_node):
            cur = self.stream_node[sid]
            if not self.nodes[cur].alive:
                continue
            sv = self.streams[sid]
            scores = self._score_map(sv, cands)
            best = min(scores, key=lambda nid: (scores[nid], nid))
            cur_score = scores.get(cur)
            if (best != cur and cur_score is not None
                    and cur_score - scores[best] > self.rebalance_hysteresis):
                gen = self.gen[sid] + 1
                xfer_s, xfer_j = self._migrate(sid, cur, best, t, gen)
                if self.recorder is not None:
                    self.recorder.migrate(t, sid, cur, best, gen,
                                          xfer_s=xfer_s, xfer_j=xfer_j)

    def _score_map(self, sv, cands: list[FleetNode]) -> dict[int, float]:
        """Whole-stream rebalance scores per candidate node — batched
        through :meth:`ScoreDrivenRouter.score_all` when the policy runs
        vectorized, per-node :meth:`~ScoreDrivenRouter.score` calls
        otherwise; both produce bit-identical values."""
        if getattr(self.policy, "vectorized", False):
            svec = self.policy.score_all(sv, cands)
            return {n.node_id: float(s) for n, s in zip(cands, svec)}
        best_iso = min(sv.cost_on(n).iso_s for n in cands)
        return {n.node_id: self.policy.score(sv, n, best_iso)
                for n in cands}

    def _rebalance_streams_whole(self, t: float,
                                 cands: list[FleetNode]) -> None:
        """Stage-mode rebalance for non-splitting policies: score whole
        streams and move every stage of a winner together (stages of such
        streams are co-located by invariant, so one source node hosts
        them all)."""
        for sid in sorted(self.streams):
            if (sid, 0) not in self.stage_node:
                continue
            cur = self.stage_node[(sid, 0)]
            if not self.nodes[cur].alive or self.nodes[cur].draining:
                continue
            sv = self.streams[sid]
            scores = self._score_map(sv, cands)
            best = min(scores, key=lambda nid: (scores[nid], nid))
            cur_score = scores.get(cur)
            if (best == cur or cur_score is None
                    or cur_score - scores[best] <= self.rebalance_hysteresis):
                continue
            for k in range(sv.n_stages):
                gen = self.stage_gen[(sid, k)] + 1
                xfer_s, xfer_j = self._migrate_stage(sid, k, cur, best, t,
                                                     gen)
                if self.recorder is not None:
                    self.recorder.migrate(t, sid, cur, best, gen, stage=k,
                                          xfer_s=xfer_s, xfer_j=xfer_j)

    def _rebalance_stages(self, t: float, cands: list[FleetNode]) -> None:
        for (sid, k) in sorted(self.stage_node):
            cur = self.stage_node[(sid, k)]
            if not self.nodes[cur].alive or self.nodes[cur].draining:
                continue
            sv = self.streams[sid]
            best_iso = min(sv.stage_cost_on(n, k).iso_s for n in cands)
            scores: dict[int, float] = {
                n.node_id: self._stage_score_full(sid, k, n, best_iso)
                for n in cands}
            best = min(scores, key=lambda nid: (scores[nid], nid))
            cur_score = scores.get(cur)
            if (best != cur and cur_score is not None
                    and cur_score - scores[best] > self.rebalance_hysteresis):
                gen = self.stage_gen[(sid, k)] + 1
                xfer_s, xfer_j = self._migrate_stage(sid, k, cur, best, t,
                                                     gen)
                if self.recorder is not None:
                    self.recorder.migrate(t, sid, cur, best, gen, stage=k,
                                          xfer_s=xfer_s, xfer_j=xfer_j)

    # ----------------------------------------------------------------- run
    def _event_stream(self) -> list[tuple[float, str, dict]]:
        events = list(self._events)
        # synthetic tune ticks precede same-time rebalance ticks (appended
        # first; the sort below is stable), so a rebalance always runs
        # under the weights the tuner just committed
        if self.tune_every_s is not None:
            k = 1
            while k * self.tune_every_s < self.duration_s:
                events.append((k * self.tune_every_s, "tune", {"k": k}))
                k += 1
        # SLO controller ticks follow same-time tune ticks (fresh tuner
        # weights first) and precede same-time rebalance ticks (a stream
        # degrades before it is considered for migration)
        if self.slo is not None and self.slo_every_s is not None:
            k = 1
            while k * self.slo_every_s < self.duration_s:
                events.append((k * self.slo_every_s, "slo", {"k": k}))
                k += 1
        if self.rebalance_every_s is not None:
            k = 1
            while k * self.rebalance_every_s < self.duration_s:
                events.append((k * self.rebalance_every_s,
                               "rebalance", {"k": k}))
                k += 1
        # stable sort keeps same-time events in declaration/record order;
        # synthetic ticks land after same-time scenario events
        return sorted(events, key=lambda e: e[0])

    def run(self) -> FleetResult:
        handlers = {
            "node_join": self._on_node_join,
            "node_leave": self._on_node_leave,
            "node_drain": self._on_node_drain,
            "stream": self._on_stream,
            "depart": self._on_depart,
            "rejoin": self._on_rejoin,
            "place": self._on_place,
            "migrate": self._on_migrate,
            "rebalance": self._on_rebalance,
            "phase": self._on_phase,
            "tune": self._on_tune,
            "slo": self._on_slo_tick,
            "swap": self._on_swap,
            "reject": self._on_reject,
        }
        prof = self._profiler
        if prof is not None:
            prof.start_run()
        try:
            for t, kind, ev in self._event_stream():
                if t > self.duration_s:
                    break
                self._advance_all(t)
                if prof is None:
                    handlers[kind](t, ev)
                else:
                    w0 = prof.t0()
                    handlers[kind](t, ev)
                    prof.add("fleet." + kind, w0)
            self._advance_all(self.duration_s)
        finally:
            if prof is not None:
                prof.stop_run()
        return self._finalize()

    def _finalize(self) -> FleetResult:
        fleet_stats = WindowStats()
        per_node: list[dict] = []
        frames = drops = retriggers = 0
        for nid in sorted(self.nodes):
            node = self.nodes[nid]
            r = node.finalize()
            for name, st in r.stats.per_model.items():
                fleet_stats.model(canonical_stream_model(name)).merge(st)
            frames += r.frames
            drops += r.drops
            retriggers += node.probe_retriggers
            # busy fraction since the node's join (SimResult utilization
            # divides by absolute time, understating mid-run joiners);
            # clamped because an abrupt leave can freeze sim.t with a
            # dispatch reservation still counted in busy_time
            span = max(node.sim.t - node.join_t, 1e-9)
            util = min(sum(a.busy_time for a in node.sim.accs)
                       / (len(node.sim.accs) * span), 1.0)
            per_node.append({
                "node": nid, "system": node.system, "alive": node.alive,
                "draining": node.draining, "frames": r.frames,
                "drops": r.drops, "uxcost": r.uxcost,
                "utilization": util, "streams": len(node.placements),
                "probe_retriggers": node.probe_retriggers,
            })
        # transfer energy (cross-node triggers + migrations) joins the moved
        # model's UXCost entry: NormEnergy rises, so moving state is never
        # free — charged exactly once per transfer, at transfer time.  A
        # model that completed zero frames has no worst-case normalizer
        # (NormEnergy ratio would discard the charge), so its charges
        # redirect to a same-stream entry that did complete frames; only a
        # stream with no completed frames at all leaves its (reported, but
        # unnormalizable) transfer energy out of the UXCost product
        for name in sorted(self.xfer_energy):
            st = fleet_stats.per_model.get(name)
            target = name
            if st is None or st.worst_energy_j <= 0.0:
                prefix = name.split(".", 1)[0] + "."
                cands = sorted(
                    n for n, s2 in fleet_stats.per_model.items()
                    if n.startswith(prefix) and s2.worst_energy_j > 0.0)
                if cands:
                    target = cands[0]
            fleet_stats.model(target).energy_j += self.xfer_energy[name]
        # rejection accounting: every head frame a refused stream would
        # have offered while rejected counts as a deadline violation (a
        # pseudo model entry with zero energy: RateDLV contributes 1.0,
        # NormEnergy nothing) — overload is *managed*, never free
        for sid in sorted(self._reject_open):
            self._close_reject(sid, self.duration_s)
        self._reject_open.clear()
        reject_frames = 0
        for sid in sorted(self._reject_frames):
            sv = self.streams[sid]
            n = max(1, int(round(self._reject_frames[sid])))
            st = fleet_stats.model(f"s{sid}." + sv.stage_base(0))
            st.frames += n
            st.violated += n
            reject_frames += n
        # per-tier breakdown (tierless streams are tier-1 "standard"):
        # the overload gate asserts tier-0 stays flat while lower tiers
        # absorb the degradation
        tier_frames: dict[int, int] = {}
        tier_viol: dict[int, int] = {}
        for name, st in fleet_stats.per_model.items():
            dot = name.find(".")
            if not name.startswith("s") or dot < 2:
                continue
            try:
                sid = int(name[1:dot])
            except ValueError:
                continue
            slo = self.stream_slo.get(sid, DEFAULT_SLO)
            tier_frames[slo.tier] = tier_frames.get(slo.tier, 0) + st.frames
            tier_viol[slo.tier] = tier_viol.get(slo.tier, 0) + st.violated
        tier_dlv = {tr: (tier_viol[tr] / tier_frames[tr]
                         if tier_frames[tr] else 0.0)
                    for tr in sorted(tier_frames)}
        # streams still placed at the horizon served until duration_s
        for sid in sorted(self._stream_t0):
            self.stream_seconds += max(
                0.0, self.duration_s - self._stream_t0[sid])
        self._stream_t0.clear()
        if self._tracer is not None:
            self._tracer.finish(self.duration_s)
        if self._metrics is not None:
            ux = uxcost(fleet_stats)
            self._metrics.gauge(
                "fleet_uxcost", "fleet UXCost at run end").set(ux)
            self._metrics.gauge(
                "fleet_dlv_rate", "fleet DLV rate at run end").set(
                overall_dlv_rate(fleet_stats))
            tf = self._metrics.gauge(
                "fleet_tier_frames_total", "frames per SLO tier", ("tier",))
            td = self._metrics.gauge(
                "fleet_tier_dlv_rate", "DLV rate per SLO tier", ("tier",))
            for tr in sorted(tier_frames):
                tf.set(tier_frames[tr], tier=tr)
                td.set(tier_dlv[tr], tier=tr)
        if self.recorder is not None:
            self.trace = self.recorder.trace()
        return FleetResult(
            name=self.name,
            policy=self.policy.name,
            duration_s=self.duration_s,
            n_nodes=len(self.nodes),
            n_streams=len(self.streams),
            stats=fleet_stats,
            uxcost=uxcost(fleet_stats),
            dlv_rate=overall_dlv_rate(fleet_stats),
            norm_energy=overall_norm_energy(fleet_stats),
            frames=frames,
            drops=drops,
            migrations=self.migrations,
            probe_retriggers=retriggers,
            per_node=per_node,
            trace=self.trace,
            split=self.split,
            stage_migrations=self.stage_migrations,
            trigger_transfers=self.trigger_transfers,
            xfer_energy_j=sum(self.xfer_energy.values()),
            weights=getattr(self.policy, "weights", None),
            tuner_windows=getattr(self.policy, "windows_seen", 0),
            tuner_commits=getattr(
                getattr(self.policy, "probe", None), "commits", 0),
            tuner_retriggers=self.tuner_retriggers,
            pipeline_latency_s=overall_pipeline_latency(fleet_stats),
            pipe_frames=sum(st.pipe_frames
                            for st in fleet_stats.per_model.values()),
            departures=self.departures,
            rejoins=self.rejoins,
            jobs_purged=self.jobs_purged,
            link_transfers=(self.links.n_transfers if self.links else 0),
            link_queued=(self.links.n_queued if self.links else 0),
            link_wait_s=(self.links.queued_s if self.links else 0.0),
            slo_enabled=(self.slo is not None
                         or (self.replay is not None
                             and "slo" in self.replay.meta)),
            rejections=self.rejections,
            swaps=self.swaps,
            promotions=self.promotions,
            reject_frames=reject_frames,
            tier_frames=dict(sorted(tier_frames.items())),
            tier_dlv=tier_dlv,
            stream_seconds=self.stream_seconds,
        )


def run_fleet(scenario: FleetScenario, policy: "str | RouterPolicy",
              duration_s: float = 4.0, seed: int = 0,
              **kw) -> FleetResult:
    return FleetSimulator(scenario, policy, duration_s=duration_s,
                          seed=seed, **kw).run()
