"""Figures 10 + 11: (alpha, beta) search trajectories and convergence.

The offline radius-shrinking search (Section 3.6) on four workload-change
cases vs a 9x9 grid-search global optimum over [0,2]^2. Paper claims:
converges within ~2% of the global optimum; >=25% UXCost improvement in
two steps; within 2% by five steps.
"""
from __future__ import annotations

from repro.core import build_scenario, grid_search, optimize_params, run_sim
from repro.core.scheduler import DreamScheduler

from .common import save_artifact

SYSTEM = "4K_1OS2WS"
CASES = (
    ("IDLE->VR_Gaming", "VR_Gaming", None),
    ("IDLE->AR_Call", "AR_Call", None),
    ("IDLE->AR_Social", "AR_Social", None),
    ("VR_Gaming->AR_Social", "AR_Social", "VR_Gaming"),
)
EVAL_DURATION = 2.0   # short window per evaluation (the paper's T_exec)


def _eval_fn(scenario: str, seed: int = 0):
    scn = build_scenario(scenario, 0.5)

    def ev(alpha: float, beta: float) -> float:
        r = run_sim(
            scn, SYSTEM,
            lambda: DreamScheduler(alpha=alpha, beta=beta, adaptivity=False,
                                   frame_drop=False, supernet=False),
            duration_s=EVAL_DURATION, seed=seed)
        return r.uxcost

    return ev


def run(seed: int = 0) -> dict:
    cases_out = []
    locked: dict[str, tuple[float, float]] = {}
    for name, scenario, warm_from in CASES:
        ev = _eval_fn(scenario, seed)
        best_p, best_c, grid = grid_search(ev, n=7)
        init = locked.get(warm_from) if warm_from else None
        trace = optimize_params(ev, init=init, seed=seed)
        found_p, found_c = trace.best
        locked[scenario] = found_p
        # convergence profile: best-so-far after each step
        best_so_far = []
        cur = float("inf")
        for c in trace.costs:
            cur = min(cur, c)
            best_so_far.append(cur)
        cases_out.append({
            "case": name,
            "global_opt": {"params": best_p, "uxcost": best_c},
            "found": {"params": found_p, "uxcost": found_c},
            "gap": (found_c - best_c) / best_c if best_c > 0 else 0.0,
            "steps": len(trace.costs),
            "evals": trace.evals,
            "best_so_far": best_so_far,
            "grid_min": float(grid.min()),
            "grid_max": float(grid.max()),
        })
    out = {"cases": cases_out,
           "mean_gap": sum(c["gap"] for c in cases_out) / len(cases_out)}
    save_artifact("fig10_param_search", out)
    return out


def main() -> None:
    out = run()
    print("fig10/11: (alpha, beta) search vs grid-search global optimum")
    for c in out["cases"]:
        print(f"  {c['case']:>22s} found={c['found']['uxcost']:8.4f} "
              f"opt={c['global_opt']['uxcost']:8.4f} "
              f"gap={c['gap']*100:5.1f}% steps={c['steps']}")
    print(f"  mean gap to global optimum: {out['mean_gap']*100:.1f}% "
          f"(paper: ~2%)")


if __name__ == "__main__":
    main()
