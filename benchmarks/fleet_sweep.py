"""Fleet policy shootout + cascade stage-split + drift-tuner sweeps.

Exercises the cluster subsystem at production shape: a ≥16-node fleet of
mixed 4K/8K Table-2 systems serving ≥200 fuzzer-sampled streams, with
elastic membership churn (a node joins mid-run, another drains) layered on
top.  Three routing policies run on the identical fleet scenario —
round-robin, least-loaded, and the score-driven DREAM-Fleet router — and
the score-driven run is recorded and replayed as a determinism self-check
(the replayed fleet UXCost must equal the live one exactly).

The cascade section then runs a cascade-heavy population (every stream a
2-3 stage pipeline) on a dataflow-polarized fleet twice under the same
transfer model: whole-pipeline placement vs stage-split routing
(``split_stages=True``), where each stage lands on the node whose WS/OS
mix suits it and cross-node triggers pay explicit activation-transfer
latency + energy.

The drift section runs a *drifting* workload — diurnal anti-phase load
swings (phase-scripted ``scale_fps`` on half-populations) plus a mid-run
drain — twice: under the hand-fixed ``score`` router and under the
online-learned ``tuned_score`` router (telemetry-fed weight tuner, see
``repro.cluster.telemetry`` / ``TunedScoreRouter``).  Static weights go
stale when the load regime shifts; the tuner must recover at least that
headroom, aggregated over ≥3 scenario seeds, and every tuned run must
replay bit-exactly with the tuner bypassed.

The lifecycle section exercises the *load-release* half of task-level
dynamicity: streams arrive AND depart (half the population departs
mid-run, some rejoin later) on top of a node drain, over
contention-aware transfer links (finite shared per-node-pair bandwidth:
concurrent migrations queue for the wire) — least-loaded vs score vs
online-tuned routing on identical scenarios, with head-to-tail pipeline
latency reported next to UXCost and an uncontended control run
isolating the realized link-queueing cost.

The headline claims, asserted by ``main()`` and the CI gate:
  * score-driven routing achieves lower fleet UXCost than round-robin;
  * stage-split routing achieves no worse fleet UXCost than whole-pipeline
    placement under the same (migration-inclusive) transfer model;
  * tuned routing achieves no worse fleet UXCost than static score
    routing on the drifting workload (tuned_over_static >= 1.0);
  * score and tuned routing achieve no worse fleet UXCost than
    least-loaded on the lifecycle-churn fleet (ll_over_score >= 1.0,
    ll_over_tuned >= 1.0);
  * all recorded fleet traces replay bit-exactly (departures, purges and
    pipeline latencies included).
"""
from __future__ import annotations

from repro.cluster import (CascadeFuzz, FleetScenario,
                           FleetScenarioBuilder, FleetSimulator, FuzzSpec,
                           GenAIFuzz, LifecycleFuzz, SLOFuzz, TransferModel)
from repro.cluster import trace as ftrace
from repro.cluster.router import ScoreDrivenRouter
from repro.scenarios.phases import scale_fps

from .common import save_artifact

#: node hardware mix: capacity heterogeneity (4K vs 8K PEs) is what makes
#: capacity-blind round-robin pay, dataflow heterogeneity (WS vs OS mixes)
#: is what the preference term exploits.  4K and 8K systems interleave so
#: every fleet-size prefix (the CI smoke uses 4 nodes) stays heterogeneous.
SYSTEMS_MIX = ("4K_2WS", "8K_2OS", "4K_1WS2OS", "8K_1OS2WS",
               "8K_2WS", "4K_2OS", "8K_1WS2OS", "4K_1OS2WS")
POLICIES = ("round_robin", "least_loaded", "score")
#: fuzzer pipelines are sized to fill a whole node; a fleet serves many
#: light streams per node, so FPS targets are scaled down to put the
#: default 16-node/200-stream population near 50% offered utilization
FPS_SCALE = 0.25


def build_fleet(seed: int, n_nodes: int, n_streams: int,
                duration_s: float, churn: bool = True) -> FleetScenario:
    b = FleetScenarioBuilder(f"fleet_sweep_{seed}")
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
            for i in range(n_nodes)]
    if churn:
        # elastic membership: a node joins mid-run, an initial node drains
        b.node(SYSTEMS_MIX[n_nodes % len(SYSTEMS_MIX)],
               at=round(0.4 * duration_s, 6))
        b.node_drain(nids[0], at=round(0.5 * duration_s, 6))
    b.fuzz_streams(FuzzSpec(n_streams=n_streams, seed=seed, t0=0.0,
                            t1=round(0.5 * duration_s, 6),
                            fps_scale=FPS_SCALE))
    return b.build()


#: cascade fleet: mixed-capacity, mixed-dataflow node pool.  The cascade
#: population is *heavy* (full fuzzer FPS targets): a 2-3 stage pipeline
#:  approaches a whole node's capacity, so whole-pipeline placement is
#: lumpy bin-packing with big items while stage-split placement packs at
#: stage granularity — the load-shape gap the sweep measures
CASCADE_SYSTEMS = ("4K_2WS", "8K_2OS", "4K_2OS", "8K_2WS",
                   "8K_2WS", "4K_2OS", "8K_2OS", "4K_2WS")
#: cascade streams keep full FPS (heavy pipelines) — contrast FPS_SCALE
CASCADE_FPS_SCALE = 1.0


def build_cascade_fleet(seed: int, n_nodes: int, n_streams: int,
                        duration_s: float, churn: bool = True) -> FleetScenario:
    b = FleetScenarioBuilder(f"cascade_sweep_{seed}")
    nids = [b.node(CASCADE_SYSTEMS[i % len(CASCADE_SYSTEMS)])
            for i in range(n_nodes)]
    if churn:
        b.node_drain(nids[0], at=round(0.5 * duration_s, 6))
    # deterministic arrivals pin the offered workload so the whole-vs-split
    # comparison (and the counter-based cascade draws) see identical load
    # regardless of placement
    b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0,
        t1=round(0.5 * duration_s, 6), fps_scale=CASCADE_FPS_SCALE,
        deterministic_arrivals=True,
        cascade=CascadeFuzz(prob=1.0, max_depth=3, only=True)))
    return b.build()


def run_cascade(duration_s: float, seed: int, n_nodes: int,
                n_streams: int, churn: bool = True,
                n_seeds: int = 3) -> dict:
    """Whole-pipeline vs stage-split placement on cascade-heavy fleets —
    identical scenarios, score policy, transfer model and trigger
    realizations per seed; only the placement granularity differs (the
    ``score_whole`` control co-locates every stage on the whole-stream
    choice).  Aggregated over ``n_seeds`` scenario seeds because online
    greedy placement is high-variance at heavy per-stream load — per-seed
    rows are reported so individual losses stay visible.  Every split run
    is recorded and replayed as a determinism self-check."""
    transfer = TransferModel()
    rows = []
    for s in range(seed, seed + n_seeds):
        fscn = build_cascade_fleet(s, n_nodes, n_streams, duration_s,
                                   churn=churn)
        whole = FleetSimulator(fscn, "score_whole", duration_s=duration_s,
                               seed=s, transfer=transfer,
                               split_stages=True).run()
        fs = FleetSimulator(fscn, "score", duration_s=duration_s, seed=s,
                            transfer=transfer, split_stages=True,
                            record=True)
        split = fs.run()
        replayed = FleetSimulator(
            replay=ftrace.loads(ftrace.dumps(split.trace))).run()
        rows.append({
            "seed": s,
            "whole": {"uxcost": whole.uxcost, "dlv_rate": whole.dlv_rate,
                      "norm_energy": whole.norm_energy,
                      "frames": whole.frames,
                      "migrations": whole.migrations,
                      "xfer_energy_j": whole.xfer_energy_j},
            "split": {"uxcost": split.uxcost, "dlv_rate": split.dlv_rate,
                      "norm_energy": split.norm_energy,
                      "frames": split.frames,
                      "migrations": split.migrations,
                      "stage_migrations": split.stage_migrations,
                      "trigger_transfers": split.trigger_transfers,
                      "xfer_energy_j": split.xfer_energy_j},
            "split_streams": sum(
                1 for sid, sv in fs.streams.items()
                if len({fs.stage_node[(sid, k)]
                        for k in range(sv.n_stages)}) > 1),
            "whole_over_split": whole.uxcost / max(split.uxcost, 1e-12),
            "replay_exact": (replayed.uxcost == split.uxcost
                             and replayed.frames == split.frames
                             and replayed.xfer_energy_j
                             == split.xfer_energy_j),
        })
    whole_total = sum(r["whole"]["uxcost"] for r in rows)
    split_total = sum(r["split"]["uxcost"] for r in rows)
    return {
        "n_nodes": n_nodes, "n_streams": n_streams, "churn": churn,
        "n_seeds": n_seeds, "transfer": transfer.to_config(),
        "rows": rows,
        "whole_uxcost_total": whole_total,
        "split_uxcost_total": split_total,
        "split_streams": sum(r["split_streams"] for r in rows),
        "trigger_transfers": sum(r["split"]["trigger_transfers"]
                                 for r in rows),
        "whole_over_split": whole_total / max(split_total, 1e-12),
        "split_beats_whole": split_total < whole_total,
        "replay_exact": all(r["replay_exact"] for r in rows),
    }


#: drift fleet: the same interleaved capacity/dataflow mix as the policy
#: shootout, at a size where a half-population load swing saturates part
#: of the fleet (weight choice matters) without stalling it outright
DRIFT_FPS_SCALE = 0.4
#: diurnal peak factor: half the streams scale up by this mid-run, then
#: recede while the other half peaks (anti-phase) — the regime shift that
#: makes hand-fixed score weights stale
DRIFT_PEAK = 2.5


def build_drift_fleet(seed: int, n_nodes: int, n_streams: int,
                      duration_s: float, churn: bool = True) -> FleetScenario:
    b = FleetScenarioBuilder(f"drift_sweep_{seed}")
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
            for i in range(n_nodes)]
    if churn:
        b.node_drain(nids[0], at=round(0.5 * duration_s, 6))
    # arrivals keep coming for most of the run (placement decisions are
    # the tuner's lever) and are deterministic, so both router arms face
    # an identical offered workload regardless of placement
    sids = b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0,
        t1=round(0.85 * duration_s, 6), fps_scale=DRIFT_FPS_SCALE,
        deterministic_arrivals=True))
    # diurnal half-populations in anti-phase: the first half peaks early
    # and recedes, the second half ramps late — two regime shifts, each
    # re-arming the tuner probe through the fleet phase events
    half = sids[:len(sids) // 2]
    rest = sids[len(sids) // 2:]
    b.phase(scale_fps(DRIFT_PEAK), at=round(0.3 * duration_s, 6), sids=half)
    b.phase(scale_fps(round(1.0 / DRIFT_PEAK, 6)),
            at=round(0.75 * duration_s, 6), sids=half)
    b.phase(scale_fps(DRIFT_PEAK), at=round(0.75 * duration_s, 6),
            sids=rest)
    return b.build()


def run_drift(duration_s: float, seed: int, n_nodes: int = 8,
              n_streams: int = 64, churn: bool = True, n_seeds: int = 3,
              tune_every_s: float = 0.2,
              rebalance_every_s: float = 0.4) -> dict:
    """Static vs online-tuned score routing on drifting-workload fleets —
    identical scenarios per seed, placement-granularity and machinery
    identical; the only variable is whether the score weights are the
    hand-fixed constants or learned online from fleet telemetry.
    Aggregated over ``n_seeds`` scenario seeds with per-seed rows
    reported; every tuned run is recorded and replayed (tuner bypassed,
    weights from the trace) as a determinism self-check."""
    rows = []
    for s in range(seed, seed + n_seeds):
        fscn = build_drift_fleet(s, n_nodes, n_streams, duration_s,
                                 churn=churn)
        static = FleetSimulator(fscn, "score", duration_s=duration_s,
                                seed=s,
                                rebalance_every_s=rebalance_every_s).run()
        fs = FleetSimulator(fscn, "tuned_score", duration_s=duration_s,
                            seed=s, rebalance_every_s=rebalance_every_s,
                            tune_every_s=tune_every_s, record=True)
        tuned = fs.run()
        replayed = FleetSimulator(
            replay=ftrace.loads(ftrace.dumps(tuned.trace))).run()
        rows.append({
            "seed": s,
            "static": {"uxcost": static.uxcost,
                       "dlv_rate": static.dlv_rate,
                       "norm_energy": static.norm_energy,
                       "frames": static.frames,
                       "migrations": static.migrations},
            "tuned": {"uxcost": tuned.uxcost, "dlv_rate": tuned.dlv_rate,
                      "norm_energy": tuned.norm_energy,
                      "frames": tuned.frames,
                      "migrations": tuned.migrations,
                      "weights": list(tuned.weights),
                      "tuner_windows": tuned.tuner_windows,
                      "tuner_commits": tuned.tuner_commits,
                      "tuner_retriggers": tuned.tuner_retriggers},
            "static_over_tuned": static.uxcost / max(tuned.uxcost, 1e-12),
            "replay_exact": (replayed.uxcost == tuned.uxcost
                             and replayed.frames == tuned.frames
                             and tuple(replayed.weights)
                             == tuple(tuned.weights)),
        })
    static_total = sum(r["static"]["uxcost"] for r in rows)
    tuned_total = sum(r["tuned"]["uxcost"] for r in rows)
    return {
        "n_nodes": n_nodes, "n_streams": n_streams, "churn": churn,
        "n_seeds": n_seeds, "tune_every_s": tune_every_s,
        "rebalance_every_s": rebalance_every_s,
        "fps_scale": DRIFT_FPS_SCALE, "peak": DRIFT_PEAK,
        "rows": rows,
        "static_uxcost_total": static_total,
        "tuned_uxcost_total": tuned_total,
        "tuner_commits": sum(r["tuned"]["tuner_commits"] for r in rows),
        "tuned_over_static": static_total / max(tuned_total, 1e-12),
        "tuned_beats_static": tuned_total <= static_total,
        "replay_exact": all(r["replay_exact"] for r in rows),
    }


#: lifecycle fleet: same interleaved capacity/dataflow mix as the policy
#: shootout at the ~50% utilization the score router is designed for —
#: the variable under test is the *stream lifecycle* (arrivals AND
#: departures/rejoins), not saturation
LIFECYCLE_FPS_SCALE = 0.25
#: half the streams depart mid-run; 40% of the departed rejoin later
LIFECYCLE_DEPART_FRAC = 0.5
LIFECYCLE_REJOIN_FRAC = 0.4
#: finite shared per-node-pair link capacity: migration waves (the drain)
#: and any concurrent transfers on one node pair queue for the wire
LIFECYCLE_LINK_BW = 1.25e9


def build_lifecycle_fleet(seed: int, n_nodes: int, n_streams: int,
                          duration_s: float,
                          churn: bool = True) -> FleetScenario:
    b = FleetScenarioBuilder(f"lifecycle_sweep_{seed}")
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
            for i in range(n_nodes)]
    if churn:
        # membership churn on top of lifecycle churn: the drain fires a
        # migration wave into the contended links mid-departure-window
        b.node_drain(nids[0], at=round(0.55 * duration_s, 6))
    b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0,
        t1=round(0.5 * duration_s, 6), fps_scale=LIFECYCLE_FPS_SCALE,
        lifecycle=LifecycleFuzz(depart_frac=LIFECYCLE_DEPART_FRAC,
                                rejoin_frac=LIFECYCLE_REJOIN_FRAC,
                                t0=round(0.35 * duration_s, 6),
                                t1=round(0.9 * duration_s, 6))))
    return b.build()


def run_lifecycle(duration_s: float, seed: int, n_nodes: int = 16,
                  n_streams: int = 128, churn: bool = True,
                  n_seeds: int = 3, tune_every_s: float = 0.2,
                  rebalance_every_s: float = 0.4) -> dict:
    """Full-lifecycle churn (streams arrive *and* depart/rejoin) over
    contention-aware transfer links: least-loaded vs score vs online-tuned
    score routing on identical scenarios — placement policy is the only
    variable; the load *releases* (departures purge backlogs, re-arm
    probes and the fleet tuner) are what PR-2..4's accumulate-only sweeps
    never exercised.  The score run repeats under an uncontended
    (infinite link bandwidth) transfer model to isolate what realized
    link queueing cost; score and tuned runs are recorded and replayed
    as determinism self-checks.  Head-to-tail pipeline latency is
    reported per policy next to UXCost/DLV."""
    transfer = TransferModel(link_bandwidth_bytes_s=LIFECYCLE_LINK_BW)
    uncontended = TransferModel()
    rows = []
    for s in range(seed, seed + n_seeds):
        fscn = build_lifecycle_fleet(s, n_nodes, n_streams, duration_s,
                                     churn=churn)
        per_policy = {}
        replays = {}
        for policy in ("least_loaded", "score", "tuned_score"):
            kw = dict(duration_s=duration_s, seed=s, transfer=transfer,
                      rebalance_every_s=rebalance_every_s,
                      record=policy != "least_loaded")
            if policy == "tuned_score":
                kw["tune_every_s"] = tune_every_s
            r = FleetSimulator(fscn, policy, **kw).run()
            per_policy[policy] = {
                "uxcost": r.uxcost, "dlv_rate": r.dlv_rate,
                "norm_energy": r.norm_energy, "frames": r.frames,
                "migrations": r.migrations,
                "departures": r.departures, "rejoins": r.rejoins,
                "jobs_purged": r.jobs_purged,
                "pipeline_latency_s": r.pipeline_latency_s,
                "pipe_frames": r.pipe_frames,
                "link_transfers": r.link_transfers,
                "link_queued": r.link_queued,
                "link_wait_s": r.link_wait_s,
            }
            if r.trace is not None:
                rp = FleetSimulator(
                    replay=ftrace.loads(ftrace.dumps(r.trace))).run()
                replays[policy] = (rp.uxcost == r.uxcost
                                   and rp.frames == r.frames
                                   and rp.departures == r.departures
                                   and rp.jobs_purged == r.jobs_purged
                                   and rp.pipeline_latency_s
                                   == r.pipeline_latency_s)
        unc = FleetSimulator(fscn, "score", duration_s=duration_s, seed=s,
                             transfer=uncontended,
                             rebalance_every_s=rebalance_every_s).run()
        per_policy["score_uncontended"] = {
            "uxcost": unc.uxcost, "dlv_rate": unc.dlv_rate,
            "frames": unc.frames,
            "pipeline_latency_s": unc.pipeline_latency_s,
        }
        rows.append({
            "seed": s,
            "policies": per_policy,
            "ll_over_score": (per_policy["least_loaded"]["uxcost"]
                              / max(per_policy["score"]["uxcost"], 1e-12)),
            "ll_over_tuned": (per_policy["least_loaded"]["uxcost"]
                              / max(per_policy["tuned_score"]["uxcost"],
                                    1e-12)),
            "contended_over_uncontended": (
                per_policy["score"]["uxcost"]
                / max(per_policy["score_uncontended"]["uxcost"], 1e-12)),
            "replay_exact": all(replays.values()) and len(replays) == 2,
        })
    ll_total = sum(r["policies"]["least_loaded"]["uxcost"] for r in rows)
    score_total = sum(r["policies"]["score"]["uxcost"] for r in rows)
    tuned_total = sum(r["policies"]["tuned_score"]["uxcost"] for r in rows)
    unc_total = sum(r["policies"]["score_uncontended"]["uxcost"]
                    for r in rows)
    return {
        "n_nodes": n_nodes, "n_streams": n_streams, "churn": churn,
        "n_seeds": n_seeds, "fps_scale": LIFECYCLE_FPS_SCALE,
        "depart_frac": LIFECYCLE_DEPART_FRAC,
        "rejoin_frac": LIFECYCLE_REJOIN_FRAC,
        "transfer": transfer.to_config(),
        "rows": rows,
        "ll_uxcost_total": ll_total,
        "score_uxcost_total": score_total,
        "tuned_uxcost_total": tuned_total,
        "uncontended_uxcost_total": unc_total,
        "departures": sum(r["policies"]["score"]["departures"]
                          for r in rows),
        "rejoins": sum(r["policies"]["score"]["rejoins"] for r in rows),
        "link_queued": sum(r["policies"]["score"]["link_queued"]
                           for r in rows),
        "ll_over_score": ll_total / max(score_total, 1e-12),
        "ll_over_tuned": ll_total / max(tuned_total, 1e-12),
        "contended_over_uncontended": score_total / max(unc_total, 1e-12),
        "score_beats_ll": score_total <= ll_total,
        "tuned_beats_ll": tuned_total <= ll_total,
        "replay_exact": all(r["replay_exact"] for r in rows),
    }


#: overload fleet: the SLO subsystem's proving ground.  A base wave puts
#: the fleet near its comfortable operating point; a second, equal-sized
#: wave then arrives mid-run and departs again late — a fleet-level
#: two-regime (MMPP-style) load burst that roughly DOUBLES offered load
#: while it lasts.  Arrivals are deterministic so the SLO-aware and
#: SLO-unaware arms face an identical offered workload.
OVERLOAD_FPS_SCALE = 0.55
#: tier mix of the population: 20% guaranteed / 40% standard / 40%
#: best-effort — enough tier-0 mass to measure flatness, enough
#: best-effort mass for the ladder and the reject gate to act on
OVERLOAD_TIER_MIX = (1.0, 2.0, 2.0)
#: every 2nd stream head is re-headed onto the OFA supernet, so the
#: degradation ladder has variant rungs across most of the population
OVERLOAD_SUPERNET_FRAC = 0.5
#: the benchmark's deployment-tuned admission thresholds: degrade early
#: and widely (the fleet's mean utilization understates per-node hotspots
#: at this scale), shed best-effort arrivals well before saturation
OVERLOAD_SLO = {"t_degrade": 0.50, "t_promote": 0.35, "t_reject": 0.62,
                "max_actions": 6, "admit_level": 2}
#: tier-0 flatness slack: the guaranteed tier's DLV under the 2x burst may
#: exceed its calm-reference DLV by at most this much per seed.  The
#: per-node scheduler is tier-blind (tiers act at admission / ladder
#: granularity), so a guaranteed stream sharing a briefly-saturated node
#: still pays a bounded residual before the ladder relieves its hosts
OVERLOAD_TIER0_EPS = 0.12


def build_overload_fleet(seed: int, n_nodes: int, n_streams: int,
                         duration_s: float, burst: bool = True
                         ) -> FleetScenario:
    b = FleetScenarioBuilder(f"overload_sweep_{seed}")
    for i in range(n_nodes):
        b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
    tiered = SLOFuzz(tier_mix=OVERLOAD_TIER_MIX,
                     supernet_frac=OVERLOAD_SUPERNET_FRAC)
    b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0,
        t1=round(0.35 * duration_s, 6), fps_scale=OVERLOAD_FPS_SCALE,
        deterministic_arrivals=True, slo=tiered))
    if burst:
        # the burst wave: a second full population arrives mid-run and
        # departs entirely before the end — offered load doubles, then
        # releases (the promote-back half of the ladder's hysteresis)
        b.fuzz_streams(FuzzSpec(
            n_streams=n_streams, seed=seed + 50_021,
            t0=round(0.45 * duration_s, 6),
            t1=round(0.7 * duration_s, 6), fps_scale=OVERLOAD_FPS_SCALE,
            deterministic_arrivals=True, slo=tiered,
            lifecycle=LifecycleFuzz(depart_frac=1.0,
                                    t0=round(0.72 * duration_s, 6),
                                    t1=round(0.9 * duration_s, 6))))
    return b.build()


def run_overload(duration_s: float, seed: int, n_nodes: int = 8,
                 n_streams: int = 40, n_seeds: int = 3,
                 slo_every_s: float = 0.15) -> dict:
    """SLO-aware vs SLO-unaware routing under a 2x load burst — identical
    tiered scenarios per seed (deterministic arrivals), score policy; the
    only variable is whether the admission controller + degradation
    ladder are live.  A calm reference (base wave only, controller live)
    anchors the tier-0 flatness gate: the guaranteed tier's violation
    rate under the burst must stay within ``OVERLOAD_TIER0_EPS`` of its
    calm value while the lower tiers absorb the degradation.  Every
    SLO-aware run is recorded and replayed (controller bypassed, swap/
    reject records applied as inputs) as a determinism self-check."""
    rows = []
    for s in range(seed, seed + n_seeds):
        burst_scn = build_overload_fleet(s, n_nodes, n_streams, duration_s,
                                         burst=True)
        calm_scn = build_overload_fleet(s, n_nodes, n_streams, duration_s,
                                        burst=False)
        unaware = FleetSimulator(burst_scn, "score", duration_s=duration_s,
                                 seed=s).run()
        aware = FleetSimulator(burst_scn, "score", duration_s=duration_s,
                               seed=s, slo=OVERLOAD_SLO,
                               slo_every_s=slo_every_s, record=True).run()
        replayed = FleetSimulator(
            replay=ftrace.loads(ftrace.dumps(aware.trace))).run()
        calm = FleetSimulator(calm_scn, "score", duration_s=duration_s,
                              seed=s, slo=OVERLOAD_SLO,
                              slo_every_s=slo_every_s).run()
        t0_burst = aware.tier_dlv.get(0, 0.0)
        t0_calm = calm.tier_dlv.get(0, 0.0)
        rows.append({
            "seed": s,
            "unaware": {"uxcost": unaware.uxcost,
                        "dlv_rate": unaware.dlv_rate,
                        "frames": unaware.frames,
                        "tier_dlv": unaware.tier_dlv},
            "aware": {"uxcost": aware.uxcost, "dlv_rate": aware.dlv_rate,
                      "frames": aware.frames,
                      "tier_frames": aware.tier_frames,
                      "tier_dlv": aware.tier_dlv,
                      "swaps": aware.swaps,
                      "promotions": aware.promotions,
                      "rejections": aware.rejections,
                      "reject_frames": aware.reject_frames},
            "calm_tier0_dlv": t0_calm,
            "tier0_dlv": t0_burst,
            "tier0_flat": t0_burst <= t0_calm + OVERLOAD_TIER0_EPS,
            "slo_over_unaware": unaware.uxcost / max(aware.uxcost, 1e-12),
            "replay_exact": (replayed.uxcost == aware.uxcost
                             and replayed.frames == aware.frames
                             and replayed.swaps == aware.swaps
                             and replayed.rejections == aware.rejections
                             and replayed.reject_frames
                             == aware.reject_frames
                             and replayed.tier_dlv == aware.tier_dlv),
        })
    unaware_total = sum(r["unaware"]["uxcost"] for r in rows)
    aware_total = sum(r["aware"]["uxcost"] for r in rows)
    t0_frames = sum(r["aware"]["tier_frames"].get(0, 0) for r in rows)
    t0_viol = sum(round(r["aware"]["tier_dlv"].get(0, 0.0)
                        * r["aware"]["tier_frames"].get(0, 0))
                  for r in rows)
    return {
        "n_nodes": n_nodes, "n_streams": n_streams, "n_seeds": n_seeds,
        "fps_scale": OVERLOAD_FPS_SCALE, "tier_mix": OVERLOAD_TIER_MIX,
        "supernet_frac": OVERLOAD_SUPERNET_FRAC,
        "slo_every_s": slo_every_s, "tier0_eps": OVERLOAD_TIER0_EPS,
        "rows": rows,
        "unaware_uxcost_total": unaware_total,
        "aware_uxcost_total": aware_total,
        "swaps": sum(r["aware"]["swaps"] for r in rows),
        "promotions": sum(r["aware"]["promotions"] for r in rows),
        "rejections": sum(r["aware"]["rejections"] for r in rows),
        #: aggregate tier-0 (guaranteed) DLV across the SLO-aware burst
        #: runs — the two-sided stability metric of the CI gate
        "tier0_dlv_overload": t0_viol / t0_frames if t0_frames else 0.0,
        "slo_over_unaware": unaware_total / max(aware_total, 1e-12),
        "slo_over_unaware_min": min(r["slo_over_unaware"] for r in rows),
        "tier0_flat": all(r["tier0_flat"] for r in rows),
        "slo_beats_unaware": aware_total <= unaware_total,
        "replay_exact": all(r["replay_exact"] for r in rows),
    }


def run_budget(duration_s: float, seed: int, n_nodes: int = 8,
               n_streams: int = 40, n_seeds: int = 3) -> dict:
    """SLO-budget-aware routing vs budget-blind routing on the tiered
    burst population — identical scenarios per seed, no admission
    controller (isolating the routing change).  The budget-aware router
    divides placement urgency by each stream's declared pipeline budget
    (``SLOClass.budget_factor``), so relaxed-budget best-effort streams
    stop spending the hardware-preference term as if they were
    guaranteed-tier.  Gated as a *two-sided stability* metric
    (``budget_over_flat`` in ci_baseline.json): the refactor folds the
    tier budget into the score without destabilizing fleet UXCost in
    either direction."""
    rows = []
    for s in range(seed, seed + n_seeds):
        scn = build_overload_fleet(s, n_nodes, n_streams, duration_s,
                                   burst=True)
        flat = FleetSimulator(scn, "score", duration_s=duration_s,
                              seed=s).run()
        pol = ScoreDrivenRouter()
        pol.budget_aware = True
        budget = FleetSimulator(scn, pol, duration_s=duration_s, seed=s,
                                record=True).run()
        replayed = FleetSimulator(
            replay=ftrace.loads(ftrace.dumps(budget.trace))).run()
        rows.append({
            "seed": s,
            "flat": {"uxcost": flat.uxcost, "dlv_rate": flat.dlv_rate,
                     "frames": flat.frames, "tier_dlv": flat.tier_dlv},
            "budget": {"uxcost": budget.uxcost,
                       "dlv_rate": budget.dlv_rate,
                       "frames": budget.frames,
                       "tier_dlv": budget.tier_dlv},
            "budget_over_flat": flat.uxcost / max(budget.uxcost, 1e-12),
            "replay_exact": (replayed.uxcost == budget.uxcost
                             and replayed.frames == budget.frames),
        })
    flat_total = sum(r["flat"]["uxcost"] for r in rows)
    budget_total = sum(r["budget"]["uxcost"] for r in rows)
    return {
        "n_nodes": n_nodes, "n_streams": n_streams, "n_seeds": n_seeds,
        "tier_mix": OVERLOAD_TIER_MIX,
        "rows": rows,
        "flat_uxcost_total": flat_total,
        "budget_uxcost_total": budget_total,
        "budget_over_flat": flat_total / max(budget_total, 1e-12),
        "replay_exact": all(r["replay_exact"] for r in rows),
    }


#: genai fleet: mixed autoregressive + vision population.  Roughly every
#: third fuzzed stream head is re-headed onto the chat_llm autoregressive
#: family (compute-bound prefill + memory-bound decode loop with
#: stochastic per-job token counts), sharing nodes with fixed-deadline
#: vision pipelines — the tension token-level preemption and the length
#: predictor exist for
GENAI_FRAC = 0.34
#: hot enough that ToGo mispricing costs real deadline misses, but not
#: so saturated that every arm drowns identically
GENAI_FPS_SCALE = 0.5
#: the ablation gate is pinned — fixed duration/seeds/fleet shape — so
#: the predictor-vs-blind comparison is one reproducible measurement
#: rather than a function of whatever sweep arguments CI happens to pass
GENAI_DURATION_S = 2.0


def build_genai_fleet(seed: int, n_nodes: int, n_streams: int,
                      duration_s: float) -> FleetScenario:
    b = FleetScenarioBuilder(f"genai_sweep_{seed}")
    for i in range(n_nodes):
        b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
    # deterministic arrivals pin the offered workload; token-count draws
    # come from the per-node token RNG stream, identical across arms
    b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0,
        t1=round(0.5 * duration_s, 6), fps_scale=GENAI_FPS_SCALE,
        deterministic_arrivals=True, genai=GenAIFuzz(frac=GENAI_FRAC)))
    return b.build()


def run_genai(duration_s: float = GENAI_DURATION_S, seed: int = 0,
              n_nodes: int = 3, n_streams: int = 28,
              n_seeds: int = 3) -> dict:
    """Length-predictor ablation on mixed chat+vision fleets — identical
    scenarios and token draws per seed, score policy; the only variable
    is whether autoregressive jobs are priced by the per-model EWMA
    length predictor (Sparse-DySta style) or *blind* at their
    ``max_new_tokens`` cap.  Blind pricing overstates decode ToGo, so
    urgency and smart-drop decisions fire on phantom load.  The
    predictor arm is recorded and (a) replayed bit-exactly — token
    counts and preemption points come from the trace, consuming no RNG —
    and (b) re-run on the scalar oracle engine, whose trace must be
    byte-identical to the SoA engine's (token-level preemption takes
    the same slab/heap machinery as everything else)."""
    rows = []
    for s in range(seed, seed + n_seeds):
        fscn = build_genai_fleet(s, n_nodes, n_streams, duration_s)
        blind = FleetSimulator(fscn, "score", duration_s=duration_s,
                               seed=s, genai_predictor=False).run()
        pred = FleetSimulator(fscn, "score", duration_s=duration_s,
                              seed=s, record=True).run()
        scal = FleetSimulator(fscn, "score", duration_s=duration_s,
                              seed=s, record=True, engine="scalar").run()
        pred_bytes = ftrace.dumps(pred.trace)
        replayed = FleetSimulator(
            replay=ftrace.loads(pred_bytes)).run()
        rows.append({
            "seed": s,
            "blind": {"uxcost": blind.uxcost, "dlv_rate": blind.dlv_rate,
                      "frames": blind.frames, "drops": blind.drops},
            "predictor": {"uxcost": pred.uxcost,
                          "dlv_rate": pred.dlv_rate,
                          "frames": pred.frames, "drops": pred.drops},
            "predictor_over_blind": (blind.uxcost
                                     / max(pred.uxcost, 1e-12)),
            "engine_equal": pred_bytes == ftrace.dumps(scal.trace),
            "replay_exact": (replayed.uxcost == pred.uxcost
                             and replayed.frames == pred.frames
                             and replayed.drops == pred.drops),
        })
    blind_total = sum(r["blind"]["uxcost"] for r in rows)
    pred_total = sum(r["predictor"]["uxcost"] for r in rows)
    return {
        "n_nodes": n_nodes, "n_streams": n_streams, "n_seeds": n_seeds,
        "fps_scale": GENAI_FPS_SCALE, "genai_frac": GENAI_FRAC,
        "rows": rows,
        "blind_uxcost_total": blind_total,
        "predictor_uxcost_total": pred_total,
        "predictor_over_blind": blind_total / max(pred_total, 1e-12),
        "predictor_over_blind_min": min(r["predictor_over_blind"]
                                        for r in rows),
        "predictor_beats_blind": all(r["predictor_over_blind"] >= 1.0
                                     for r in rows),
        "engine_equal": all(r["engine_equal"] for r in rows),
        "replay_exact": all(r["replay_exact"] for r in rows),
    }


#: scale arm: the vectorized fast path's proving ground — a fleet more
#: than an order of magnitude past the default sweep in both dimensions.
#: Before the batched router/scheduler/event-heap fast paths this
#: configuration could not complete in a nightly budget (per-placement
#: scoring alone was a Python loop over 256 nodes x 10k placements, and
#: every fleet event rescanned all 256 per-node event queues); it now
#: runs as a single score-policy arm whose ``streams_per_wall_s`` the
#: nightly lane uploads into the BENCH trajectory
SCALE_N_NODES = 256
SCALE_N_STREAMS = 10_000
SCALE_DURATION_S = 0.6
#: per-stream FPS scale keeping 10k streams near the ~50% fleet
#: utilization the default sweep targets (39 streams/node vs 12.5)
SCALE_FPS_SCALE = 0.08


def build_scale_fleet(seed: int, n_nodes: int, n_streams: int,
                      duration_s: float) -> FleetScenario:
    b = FleetScenarioBuilder(f"scale_sweep_{seed}")
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
            for i in range(n_nodes)]
    # membership churn at scale: one drain mid-run fires a migration wave
    # of an entire node's streams through the batched rebalance path
    b.node_drain(nids[0], at=round(0.5 * duration_s, 6))
    b.fuzz_streams(FuzzSpec(n_streams=n_streams, seed=seed, t0=0.0,
                            t1=round(0.6 * duration_s, 6),
                            fps_scale=SCALE_FPS_SCALE))
    return b.build()


def run_scale(duration_s: float = SCALE_DURATION_S, seed: int = 0,
              n_nodes: int = SCALE_N_NODES,
              n_streams: int = SCALE_N_STREAMS) -> dict:
    """256-node / 10k-stream score-routing throughput arm.  Periodic
    whole-fleet rebalance is disabled (a full 10k x 256 re-score pass is
    a different workload than event-driven routing; the drain still
    exercises the batched rebalance path on one node's population) so
    ``streams_per_wall_s`` measures the steady-state event loop."""
    import time
    fscn = build_scale_fleet(seed, n_nodes, n_streams, duration_s)
    fs = FleetSimulator(fscn, "score", duration_s=duration_s, seed=seed,
                        rebalance_every_s=10.0 * duration_s)
    w0 = time.perf_counter()
    r = fs.run()
    wall = time.perf_counter() - w0
    out = {
        "n_nodes": n_nodes, "n_streams": n_streams,
        "duration_s": duration_s, "seed": seed,
        "fps_scale": SCALE_FPS_SCALE,
        "uxcost": r.uxcost, "dlv_rate": r.dlv_rate, "frames": r.frames,
        "migrations": r.migrations, "departures": r.departures,
        "stream_seconds": r.stream_seconds,
        "wall_s": round(wall, 4),
        "streams_per_wall_s": r.stream_seconds / max(wall, 1e-9),
    }
    save_artifact("fleet_scale", out)
    return out


def main_scale(duration_s: float = SCALE_DURATION_S, seed: int = 0) -> None:
    out = run_scale(duration_s=duration_s, seed=seed)
    print(f"fleet_scale: {out['n_nodes']} nodes, {out['n_streams']} "
          f"streams, {out['duration_s']}s sim in {out['wall_s']:.1f}s wall")
    print(f"  UXCost={out['uxcost']:.2f} DLV={out['dlv_rate']:.3f} "
          f"frames={out['frames']} migr={out['migrations']}")
    print(f"  throughput: {out['streams_per_wall_s']:.1f} stream-seconds "
          f"simulated per wall-second")
    if out["frames"] <= 0:
        raise SystemExit("scale arm served no frames")


def run(duration_s: float = 2.5, seed: int = 0, n_nodes: int = 16,
        n_streams: int = 200, churn: bool = True,
        obs_dir: "str | None" = None) -> dict:
    import time
    fscn = build_fleet(seed, n_nodes, n_streams, duration_s, churn=churn)
    rows = {}
    score_trace = None
    score_result = None
    wall_score = 0.0
    for policy in POLICIES:
        fs = FleetSimulator(fscn, policy, duration_s=duration_s, seed=seed,
                            record=(policy == "score"))
        w0 = time.perf_counter()
        r = fs.run()
        wall = time.perf_counter() - w0
        rows[policy] = {
            "uxcost": r.uxcost, "dlv_rate": r.dlv_rate,
            "norm_energy": r.norm_energy, "frames": r.frames,
            "drops": r.drops, "migrations": r.migrations,
            "probe_retriggers": r.probe_retriggers,
            "n_nodes": r.n_nodes, "n_streams": r.n_streams,
            "pipeline_latency_s": r.pipeline_latency_s,
            "pipe_frames": r.pipe_frames,
        }
        if policy == "score":
            score_trace = r.trace
            score_result = r
            wall_score = wall
    replayed = FleetSimulator(
        replay=ftrace.loads(ftrace.dumps(score_trace))).run()
    obs_out = None
    if obs_dir is not None:
        # obs-enabled control run of the score arm: exports spans/metrics/
        # profile, measures instrumentation wall overhead, and asserts the
        # traced run stays bit-identical to the untraced one
        fs_obs = FleetSimulator(fscn, "score", duration_s=duration_s,
                                seed=seed, obs=True)
        w0 = time.perf_counter()
        r_obs = fs_obs.run()
        wall_obs = time.perf_counter() - w0
        paths = fs_obs.obs.export(obs_dir)
        obs_out = {
            "dir": obs_dir,
            "files": sorted(paths),
            "wall_s": round(wall_obs, 4),
            "wall_overhead": wall_obs / max(wall_score, 1e-9),
            "uxcost_match": r_obs.uxcost == score_result.uxcost,
            "spans": len(fs_obs.obs.tracer.to_records()),
            "streams_per_wall_s_traced":
                r_obs.stream_seconds / max(wall_obs, 1e-9),
        }
        if not obs_out["uxcost_match"]:
            raise SystemExit("obs-enabled fleet run diverged from the "
                             "untraced control — instrumentation leaked "
                             "into scheduling")
    out = {
        "n_nodes": n_nodes, "n_streams": n_streams,
        "duration_s": duration_s, "seed": seed, "churn": churn,
        "fps_scale": FPS_SCALE,
        "policies": rows,
        # simulated stream-seconds served per wall-clock second on the
        # score arm: the simulator-throughput figure the BENCH trajectory
        # tracks (machine-dependent, so trend-only — never gated)
        "wall_s_score": round(wall_score, 4),
        "stream_seconds": score_result.stream_seconds,
        "streams_per_wall_s":
            score_result.stream_seconds / max(wall_score, 1e-9),
        "obs": obs_out,
        "rr_over_score": (rows["round_robin"]["uxcost"]
                          / max(rows["score"]["uxcost"], 1e-12)),
        "score_beats_round_robin": (rows["score"]["uxcost"]
                                    < rows["round_robin"]["uxcost"]),
        "replay_exact": (replayed.uxcost == rows["score"]["uxcost"]
                         and replayed.frames == rows["score"]["frames"]),
        # floors keep the derived config in the regime stage-splitting is
        # for: >=8 nodes (placement diversity) serving >=10 heavy cascades
        "cascade": run_cascade(duration_s, seed, max(n_nodes // 2, 8),
                               max(n_streams // 16, 10), churn=churn),
        # the drift arm needs enough run time for telemetry windows: short
        # (CI-smoke) durations use the tighter validated configuration
        "drift": (run_drift(duration_s, seed, churn=churn)
                  if duration_s >= 2.0 else
                  run_drift(duration_s, seed, n_nodes=8, n_streams=48,
                            churn=churn, tune_every_s=0.15,
                            rebalance_every_s=0.3)),
        # full stream lifecycle: arrivals AND departures/rejoins over
        # contention-aware links (validated at both CI and full durations)
        "lifecycle": run_lifecycle(duration_s, seed, churn=churn),
        # SLO subsystem under a 2x burst: tiered admission + variant
        # degradation vs an SLO-unaware control on identical arrivals
        "overload": run_overload(duration_s, seed),
        # SLO-budget-aware routing vs budget-blind on the same tiered
        # population: a two-sided stability gate, not a headline claim
        "budget": run_budget(duration_s, seed),
        # autoregressive chat+vision mix: EWMA length predictor vs blind
        # cap pricing; always at the pinned configuration (see
        # GENAI_DURATION_S) so the per-seed gate means the same thing in
        # every invocation
        "genai": run_genai(),
    }
    save_artifact("fleet_sweep", out)
    return out


def main(duration_s: float = 2.5, seed: int = 0, n_nodes: int = 16,
         n_streams: int = 200, churn: bool = True,
         obs_dir: "str | None" = None) -> None:
    out = run(duration_s=duration_s, seed=seed, n_nodes=n_nodes,
              n_streams=n_streams, churn=churn, obs_dir=obs_dir)
    print(f"fleet_sweep: {out['n_nodes']} nodes (+churn={out['churn']}), "
          f"{out['n_streams']} streams, {out['duration_s']}s")
    for policy, r in out["policies"].items():
        print(f"  {policy:>12s} UXCost={r['uxcost']:10.2f} "
              f"DLV={r['dlv_rate']:6.3f} E={r['norm_energy']:6.3f} "
              f"frames={r['frames']:<6d} migr={r['migrations']}")
    print(f"  UXCost(round_robin)/UXCost(score) = {out['rr_over_score']:.3f}"
          f"   replay_exact={out['replay_exact']}")
    print(f"  throughput: {out['streams_per_wall_s']:.1f} stream-seconds "
          f"simulated per wall-second (score arm, "
          f"{out['wall_s_score']:.2f}s wall)")
    if out["obs"] is not None:
        o = out["obs"]
        print(f"  obs: {o['spans']} spans -> {o['dir']}  "
              f"wall_overhead={o['wall_overhead']:.3f}  "
              f"uxcost_match={o['uxcost_match']}")
    c = out["cascade"]
    print(f"cascade sweep: {c['n_nodes']} nodes x {c['n_seeds']} seeds, "
          f"{c['n_streams']} heavy cascade streams each "
          f"({c['split_streams']} split across nodes, "
          f"{c['trigger_transfers']} cross-node triggers)")
    for r in c["rows"]:
        print(f"  seed {r['seed']}: whole={r['whole']['uxcost']:9.2f} "
              f"(DLV={r['whole']['dlv_rate']:5.3f})  "
              f"split={r['split']['uxcost']:9.2f} "
              f"(DLV={r['split']['dlv_rate']:5.3f})  "
              f"ratio={r['whole_over_split']:5.3f} "
              f"replay={r['replay_exact']}")
    print(f"  aggregate UXCost(whole)/UXCost(split) = "
          f"{c['whole_over_split']:.3f}   replay_exact={c['replay_exact']}")
    d = out["drift"]
    print(f"drift sweep: {d['n_nodes']} nodes x {d['n_seeds']} seeds, "
          f"{d['n_streams']} streams, diurnal anti-phase swings + drain, "
          f"tune_every={d['tune_every_s']}s")
    for r in d["rows"]:
        tw = r["tuned"]
        print(f"  seed {r['seed']}: static={r['static']['uxcost']:9.2f} "
              f"(DLV={r['static']['dlv_rate']:5.3f})  "
              f"tuned={tw['uxcost']:9.2f} (DLV={tw['dlv_rate']:5.3f})  "
              f"ratio={r['static_over_tuned']:5.3f} "
              f"commits={tw['tuner_commits']} replay={r['replay_exact']}")
    print(f"  aggregate UXCost(static)/UXCost(tuned) = "
          f"{d['tuned_over_static']:.3f}   replay_exact={d['replay_exact']}")
    lf = out["lifecycle"]
    print(f"lifecycle sweep: {lf['n_nodes']} nodes x {lf['n_seeds']} seeds, "
          f"{lf['n_streams']} streams arriving AND departing "
          f"({lf['departures']} departures, {lf['rejoins']} rejoins), "
          f"contended links ({lf['link_queued']} queued transfers)")
    for r in lf["rows"]:
        p = r["policies"]
        print(f"  seed {r['seed']}: ll={p['least_loaded']['uxcost']:9.2f}  "
              f"score={p['score']['uxcost']:9.2f}  "
              f"tuned={p['tuned_score']['uxcost']:9.2f}  "
              f"ll/score={r['ll_over_score']:5.3f} "
              f"ll/tuned={r['ll_over_tuned']:5.3f} "
              f"pipe_lat={p['score']['pipeline_latency_s']*1e3:6.2f}ms "
              f"replay={r['replay_exact']}")
    print(f"  aggregate UXCost(ll)/UXCost(score) = {lf['ll_over_score']:.3f}"
          f"  UXCost(ll)/UXCost(tuned) = {lf['ll_over_tuned']:.3f}"
          f"  contended/uncontended = "
          f"{lf['contended_over_uncontended']:.3f}"
          f"  replay_exact={lf['replay_exact']}")
    ov = out["overload"]
    print(f"overload sweep: {ov['n_nodes']} nodes x {ov['n_seeds']} seeds, "
          f"{ov['n_streams']}-stream base wave + equal 2x burst wave, "
          f"tiers {ov['tier_mix']}, slo_every={ov['slo_every_s']}s")
    for r in ov["rows"]:
        a = r["aware"]
        print(f"  seed {r['seed']}: unaware={r['unaware']['uxcost']:9.2f} "
              f"(DLV={r['unaware']['dlv_rate']:5.3f})  "
              f"aware={a['uxcost']:9.2f} (DLV={a['dlv_rate']:5.3f})  "
              f"ratio={r['slo_over_unaware']:5.3f} "
              f"swaps={a['swaps']} rej={a['rejections']} "
              f"promo={a['promotions']} "
              f"t0={r['tier0_dlv']:5.3f}/calm={r['calm_tier0_dlv']:5.3f} "
              f"replay={r['replay_exact']}")
    print(f"  aggregate UXCost(unaware)/UXCost(aware) = "
          f"{ov['slo_over_unaware']:.3f}  tier0_dlv={ov['tier0_dlv_overload']:.3f}"
          f"  tier0_flat={ov['tier0_flat']}"
          f"  replay_exact={ov['replay_exact']}")
    bu = out["budget"]
    print(f"budget sweep: {bu['n_nodes']} nodes x {bu['n_seeds']} seeds, "
          f"{bu['n_streams']}-stream tiered burst, SLO-budget-aware vs "
          f"budget-blind routing")
    for r in bu["rows"]:
        print(f"  seed {r['seed']}: flat={r['flat']['uxcost']:9.2f} "
              f"(DLV={r['flat']['dlv_rate']:5.3f})  "
              f"budget={r['budget']['uxcost']:9.2f} "
              f"(DLV={r['budget']['dlv_rate']:5.3f})  "
              f"ratio={r['budget_over_flat']:5.3f} "
              f"replay={r['replay_exact']}")
    print(f"  aggregate UXCost(flat)/UXCost(budget) = "
          f"{bu['budget_over_flat']:.3f}   replay_exact="
          f"{bu['replay_exact']}")
    g = out["genai"]
    print(f"genai sweep: {g['n_nodes']} nodes x {g['n_seeds']} seeds, "
          f"{g['n_streams']} streams (genai_frac={g['genai_frac']}, "
          f"fps_scale={g['fps_scale']}), EWMA length predictor vs blind "
          f"cap pricing")
    for r in g["rows"]:
        p = r["predictor"]
        print(f"  seed {r['seed']}: blind={r['blind']['uxcost']:9.2f} "
              f"(DLV={r['blind']['dlv_rate']:5.3f})  "
              f"predictor={p['uxcost']:9.2f} (DLV={p['dlv_rate']:5.3f})  "
              f"ratio={r['predictor_over_blind']:5.3f} "
              f"engines={r['engine_equal']} replay={r['replay_exact']}")
    print(f"  aggregate UXCost(blind)/UXCost(predictor) = "
          f"{g['predictor_over_blind']:.3f}  "
          f"min={g['predictor_over_blind_min']:.3f}  "
          f"engine_equal={g['engine_equal']}  "
          f"replay_exact={g['replay_exact']}")
    if not out["score_beats_round_robin"]:
        raise SystemExit("score-driven routing did not beat round-robin")
    if not out["replay_exact"]:
        raise SystemExit("fleet trace replay mismatch — determinism broken")
    if not c["split_beats_whole"]:
        raise SystemExit("stage-split routing did not beat whole-pipeline "
                         "placement on the cascade fleet")
    if not c["replay_exact"]:
        raise SystemExit("cascade fleet trace replay mismatch — "
                         "determinism broken")
    if not d["tuned_beats_static"]:
        raise SystemExit("online-tuned routing did worse than static score "
                         "weights on the drifting-workload fleet")
    if not d["replay_exact"]:
        raise SystemExit("tuned fleet trace replay mismatch — "
                         "determinism broken")
    if not lf["score_beats_ll"]:
        raise SystemExit("score routing did worse than least-loaded on the "
                         "lifecycle-churn fleet")
    if not lf["tuned_beats_ll"]:
        raise SystemExit("tuned routing did worse than least-loaded on the "
                         "lifecycle-churn fleet")
    if not lf["replay_exact"]:
        raise SystemExit("lifecycle fleet trace replay mismatch — "
                         "determinism broken")
    if ov["slo_over_unaware_min"] < 1.0:
        raise SystemExit("SLO-aware admission did worse than the unaware "
                         "control on at least one overload seed")
    if not ov["tier0_flat"]:
        raise SystemExit("tier-0 violation rate was not flat under the 2x "
                         "burst — guaranteed tier leaked degradation")
    if ov["swaps"] + ov["rejections"] == 0:
        raise SystemExit("overload arm exercised neither the degradation "
                         "ladder nor the reject gate — scenario too calm")
    if not ov["replay_exact"]:
        raise SystemExit("SLO fleet trace replay mismatch — recorded "
                         "swap/reject decisions did not reproduce the run")
    if not bu["replay_exact"]:
        raise SystemExit("budget-aware fleet trace replay mismatch — "
                         "determinism broken")
    if not g["predictor_beats_blind"]:
        raise SystemExit("EWMA length predictor did worse than blind cap "
                         "pricing on at least one genai seed")
    if not g["engine_equal"]:
        raise SystemExit("scalar and SoA engines diverged on the genai "
                         "fleet — token-level preemption broke engine "
                         "equivalence")
    if not g["replay_exact"]:
        raise SystemExit("genai fleet trace replay mismatch — recorded "
                         "token counts did not reproduce the run")


if __name__ == "__main__":
    import sys as _sys
    if "--scale" in _sys.argv:
        main_scale()
    else:
        main()
