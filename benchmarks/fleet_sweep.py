"""Fleet sweep: multi-node DREAM behind the global router, policy shootout.

Exercises the cluster subsystem at production shape: a ≥16-node fleet of
mixed 4K/8K Table-2 systems serving ≥200 fuzzer-sampled streams, with
elastic membership churn (a node joins mid-run, another drains) layered on
top.  Three routing policies run on the identical fleet scenario —
round-robin, least-loaded, and the score-driven DREAM-Fleet router — and
the score-driven run is recorded and replayed as a determinism self-check
(the replayed fleet UXCost must equal the live one exactly).

The headline claims, asserted by ``main()`` and the CI gate:
  * score-driven routing achieves lower fleet UXCost than round-robin;
  * the recorded fleet trace replays bit-exactly.
"""
from __future__ import annotations

from repro.cluster import FleetScenario, FleetScenarioBuilder, FleetSimulator
from repro.cluster import trace as ftrace

from .common import save_artifact

#: node hardware mix: capacity heterogeneity (4K vs 8K PEs) is what makes
#: capacity-blind round-robin pay, dataflow heterogeneity (WS vs OS mixes)
#: is what the preference term exploits.  4K and 8K systems interleave so
#: every fleet-size prefix (the CI smoke uses 4 nodes) stays heterogeneous.
SYSTEMS_MIX = ("4K_2WS", "8K_2OS", "4K_1WS2OS", "8K_1OS2WS",
               "8K_2WS", "4K_2OS", "8K_1WS2OS", "4K_1OS2WS")
POLICIES = ("round_robin", "least_loaded", "score")
#: fuzzer pipelines are sized to fill a whole node; a fleet serves many
#: light streams per node, so FPS targets are scaled down to put the
#: default 16-node/200-stream population near 50% offered utilization
FPS_SCALE = 0.25


def build_fleet(seed: int, n_nodes: int, n_streams: int,
                duration_s: float, churn: bool = True) -> FleetScenario:
    b = FleetScenarioBuilder(f"fleet_sweep_{seed}")
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
            for i in range(n_nodes)]
    if churn:
        # elastic membership: a node joins mid-run, an initial node drains
        b.node(SYSTEMS_MIX[n_nodes % len(SYSTEMS_MIX)],
               at=round(0.4 * duration_s, 6))
        b.node_drain(nids[0], at=round(0.5 * duration_s, 6))
    b.fuzz_streams(n_streams, seed=seed, t0=0.0,
                   t1=round(0.5 * duration_s, 6), fps_scale=FPS_SCALE)
    return b.build()


def run(duration_s: float = 2.5, seed: int = 0, n_nodes: int = 16,
        n_streams: int = 200, churn: bool = True) -> dict:
    fscn = build_fleet(seed, n_nodes, n_streams, duration_s, churn=churn)
    rows = {}
    score_trace = None
    for policy in POLICIES:
        fs = FleetSimulator(fscn, policy, duration_s=duration_s, seed=seed,
                            record=(policy == "score"))
        r = fs.run()
        rows[policy] = {
            "uxcost": r.uxcost, "dlv_rate": r.dlv_rate,
            "norm_energy": r.norm_energy, "frames": r.frames,
            "drops": r.drops, "migrations": r.migrations,
            "probe_retriggers": r.probe_retriggers,
            "n_nodes": r.n_nodes, "n_streams": r.n_streams,
        }
        if policy == "score":
            score_trace = r.trace
    replayed = FleetSimulator(
        replay=ftrace.loads(ftrace.dumps(score_trace))).run()
    out = {
        "n_nodes": n_nodes, "n_streams": n_streams,
        "duration_s": duration_s, "seed": seed, "churn": churn,
        "fps_scale": FPS_SCALE,
        "policies": rows,
        "rr_over_score": (rows["round_robin"]["uxcost"]
                          / max(rows["score"]["uxcost"], 1e-12)),
        "score_beats_round_robin": (rows["score"]["uxcost"]
                                    < rows["round_robin"]["uxcost"]),
        "replay_exact": (replayed.uxcost == rows["score"]["uxcost"]
                         and replayed.frames == rows["score"]["frames"]),
    }
    save_artifact("fleet_sweep", out)
    return out


def main(duration_s: float = 2.5, seed: int = 0, n_nodes: int = 16,
         n_streams: int = 200, churn: bool = True) -> None:
    out = run(duration_s=duration_s, seed=seed, n_nodes=n_nodes,
              n_streams=n_streams, churn=churn)
    print(f"fleet_sweep: {out['n_nodes']} nodes (+churn={out['churn']}), "
          f"{out['n_streams']} streams, {out['duration_s']}s")
    for policy, r in out["policies"].items():
        print(f"  {policy:>12s} UXCost={r['uxcost']:10.2f} "
              f"DLV={r['dlv_rate']:6.3f} E={r['norm_energy']:6.3f} "
              f"frames={r['frames']:<6d} migr={r['migrations']}")
    print(f"  UXCost(round_robin)/UXCost(score) = {out['rr_over_score']:.3f}"
          f"   replay_exact={out['replay_exact']}")
    if not out["score_beats_round_robin"]:
        raise SystemExit("score-driven routing did not beat round-robin")
    if not out["replay_exact"]:
        raise SystemExit("fleet trace replay mismatch — determinism broken")


if __name__ == "__main__":
    main()
