"""Figure 8: UXCost on homogeneous hardware.

Paper observation: the DREAM advantage shrinks when compute is abundant
(8K homogeneous) — scheduling matters most under constrained resources —
and the heterogeneous-hardware gap (fig7) exceeds the homogeneous one.
"""
from __future__ import annotations

from repro.core import HOMO_SYSTEMS

from . import fig7_heterogeneous as f7
from .common import DURATION_S


def run(duration_s: float = DURATION_S, seed: int = 0) -> dict:
    out = f7.run(systems=HOMO_SYSTEMS, duration_s=duration_s, seed=seed,
                 tag="fig8_homogeneous")
    return out


def main() -> None:
    out = run()
    print("fig8: UXCost on homogeneous hardware")
    for c in out["cells"]:
        vals = " ".join(f"{s}={c[s]['uxcost']:8.3f}"
                        for s in f7.SCHEDULERS)
        print(f"  {c['scenario']:>14s} {c['system']:>10s} {vals}")
    gm = out["geomean_uxcost"]
    print("  geomean:", {k: round(v, 4) for k, v in gm.items()})
    red = out["dream_reduction"]
    print(f"  DREAM vs Planaria: {red['vs_planaria']*100:.1f}% | "
          f"vs Veltair: {red['vs_veltair']*100:.1f}%")


if __name__ == "__main__":
    main()
