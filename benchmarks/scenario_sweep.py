"""Scenario-engine sweep: fuzzer-sampled dynamic workloads, DREAM vs FCFS.

Exercises the scenario subsystem end-to-end: seeded random scenarios with
mixed arrival processes (periodic / jitter / Poisson / bursty / diurnal),
a random mid-run phase shift layered on half of them, and a record/replay
self-check per cell (the replayed UXCost must equal the live one exactly).
Reports DREAM's UXCost advantage over FCFS across the sampled population —
the paper's robustness claim, measured on workloads nobody hand-tuned.
"""
from __future__ import annotations

from repro.core import dream_full, run_sim
from repro.core.baselines import FCFSScheduler
from repro.core.simulator import Simulator
from repro.scenarios import fuzz_phase_script, fuzz_scenario
from repro.scenarios import trace as trace_mod

from .common import geomean, save_artifact

SYSTEM = "4K_1WS2OS"


def run(duration_s: float = 3.0, seed: int = 0, n_scenarios: int = 8) -> dict:
    rows = []
    for k in range(n_scenarios):
        fuzz_seed = seed * 1000 + k
        builder = fuzz_scenario(fuzz_seed)
        script = (fuzz_phase_script(fuzz_seed, builder, duration_s)
                  if k % 2 else None)
        scn = builder.build()

        sim = Simulator(scn, SYSTEM, dream_full(seed=seed),
                        duration_s=duration_s, seed=seed,
                        phase_script=script, record=True)
        r_dream = sim.run()
        replayed = Simulator(builder.build(), SYSTEM, dream_full(seed=seed),
                             duration_s=duration_s, seed=seed,
                             replay=trace_mod.loads(
                                 trace_mod.dumps(sim.trace))).run()
        r_fcfs = run_sim(builder.build(), SYSTEM, FCFSScheduler,
                         duration_s=duration_s, seed=seed,
                         phase_script=script)
        rows.append({
            "fuzz_seed": fuzz_seed,
            "models": [s.model.name for s in scn.models],
            "phase_shift": script is not None and len(script) > 0,
            "frames": r_dream.frames,
            "FCFS": r_fcfs.uxcost,
            "DREAM": r_dream.uxcost,
            "replay_exact": replayed.uxcost == r_dream.uxcost,
        })
    # UXCost ratio over the sampled population (higher = DREAM better)
    ratios = [max(r["FCFS"], 1e-9) / max(r["DREAM"], 1e-9) for r in rows]
    out = {"system": SYSTEM, "duration_s": duration_s, "seed": seed,
           "rows": rows, "geomean_fcfs_over_dream": geomean(ratios),
           "all_replays_exact": all(r["replay_exact"] for r in rows)}
    save_artifact("scenario_sweep", out)
    return out


def main(duration_s: float = 3.0, seed: int = 0) -> None:
    out = run(duration_s=duration_s, seed=seed)
    print(f"scenario_sweep: {len(out['rows'])} fuzzed scenarios on "
          f"{out['system']}")
    for r in out["rows"]:
        tag = "shift" if r["phase_shift"] else "     "
        print(f"  seed={r['fuzz_seed']:<6d} {tag} frames={r['frames']:<5d} "
              f"FCFS={r['FCFS']:8.3f} DREAM={r['DREAM']:8.3f} "
              f"replay_exact={r['replay_exact']}")
    print(f"  geomean UXCost(FCFS)/UXCost(DREAM) = "
          f"{out['geomean_fcfs_over_dream']:.3f}")
    if not out["all_replays_exact"]:
        raise SystemExit("trace replay mismatch — determinism broken")


if __name__ == "__main__":
    main()
