"""Figure 2: deadline violation rate, static vs dynamic FCFS on AR_Call.

Paper claim: dynamic scheduling decreases the violation rate by 52.9% on
average across the four 4K/8K accelerator styles (the scenario has an audio
pipeline at 50% trigger probability and SkipNet at 50% skip probability —
static scheduling must reserve worst-case slots).
"""
from __future__ import annotations

from repro.core import build_scenario, run_sim
from repro.core.baselines import FCFSScheduler, StaticFCFSScheduler

from .common import DURATION_S, save_artifact

SYSTEMS_FIG2 = ("4K_2WS", "4K_1WS2OS", "8K_2WS", "8K_1WS2OS")


def run(duration_s: float = DURATION_S, seed: int = 0) -> dict:
    rows = []
    for system in SYSTEMS_FIG2:
        scn = build_scenario("AR_Call", 0.5)
        static = run_sim(scn, system, StaticFCFSScheduler,
                         duration_s=duration_s, seed=seed)
        dyn = run_sim(scn, system, FCFSScheduler,
                      duration_s=duration_s, seed=seed)
        rows.append({
            "system": system,
            "static_dlv": static.dlv_rate,
            "dynamic_dlv": dyn.dlv_rate,
            "reduction": (1 - dyn.dlv_rate / static.dlv_rate
                          if static.dlv_rate > 0 else 0.0),
        })
    mean_red = sum(r["reduction"] for r in rows) / len(rows)
    out = {"rows": rows, "mean_reduction": mean_red,
           "paper_claim": 0.529}
    save_artifact("fig2_static_vs_dynamic", out)
    return out


def main() -> None:
    out = run()
    print("fig2: static vs dynamic FCFS deadline-violation rate (AR_Call)")
    for r in out["rows"]:
        print(f"  {r['system']:>10s} static={r['static_dlv']:.3f} "
              f"dynamic={r['dynamic_dlv']:.3f} "
              f"reduction={r['reduction']*100:5.1f}%")
    print(f"  mean reduction {out['mean_reduction']*100:.1f}% "
          f"(paper: 52.9%)")


if __name__ == "__main__":
    main()
