"""Figure 13: optimizing DLV-only or energy-only vs UXCost.

The (alpha, beta) search is repeated with three objectives on VR_Gaming and
AR_Social; optimizing either single metric degrades the other (paper: up to
+41.9% DLV when optimizing energy; UXCost balances both).
"""
from __future__ import annotations

from repro.core import build_scenario, optimize_params, run_sim
from repro.core.scheduler import DreamScheduler

from .common import save_artifact

SYSTEM = "4K_1WS2OS"
SCENARIOS = ("VR_Gaming", "AR_Social")
EVAL_DURATION = 2.0


def _measure(scenario: str, alpha: float, beta: float, seed: int = 0):
    scn = build_scenario(scenario, 0.5)
    r = run_sim(
        scn, SYSTEM,
        lambda: DreamScheduler(alpha=alpha, beta=beta, adaptivity=False,
                               frame_drop=False, supernet=False),
        duration_s=EVAL_DURATION, seed=seed)
    return r


def run(seed: int = 0) -> dict:
    rows = []
    for scenario in SCENARIOS:
        per_obj = {}
        for objective in ("uxcost", "dlv", "energy"):
            def ev(a: float, b: float) -> float:
                r = _measure(scenario, a, b, seed)
                if objective == "dlv":
                    return r.dlv_rate + 1e-6
                if objective == "energy":
                    return r.norm_energy + 1e-6
                return r.uxcost
            trace = optimize_params(ev, seed=seed)
            (a, b), _ = trace.best
            r = _measure(scenario, a, b, seed)
            per_obj[objective] = {"alpha": a, "beta": b,
                                  "uxcost": r.uxcost, "dlv": r.dlv_rate,
                                  "energy": r.norm_energy}
        base = per_obj["uxcost"]
        rows.append({
            "scenario": scenario,
            "objectives": per_obj,
            "dlv_opt_energy_increase":
                per_obj["dlv"]["energy"] / max(base["energy"], 1e-9) - 1,
            "energy_opt_dlv_increase":
                per_obj["energy"]["dlv"] / max(base["dlv"], 1e-9) - 1,
        })
    out = {"rows": rows}
    save_artifact("fig13_metric_ablation", out)
    return out


def main() -> None:
    out = run()
    print("fig13: single-metric optimization vs UXCost optimization")
    for r in out["rows"]:
        print(f"  {r['scenario']}:")
        for obj, v in r["objectives"].items():
            print(f"    opt={obj:>7s} (a={v['alpha']:.2f}, b={v['beta']:.2f})"
                  f" uxcost={v['uxcost']:8.4f} dlv={v['dlv']:.3f} "
                  f"energy={v['energy']:.3f}")
        print(f"    dlv-only optimization raises energy by "
              f"{r['dlv_opt_energy_increase']*100:+.1f}%; energy-only "
              f"raises DLV by {r['energy_opt_dlv_increase']*100:+.1f}%")


if __name__ == "__main__":
    main()
