"""Shared helpers for the Level-1 (paper-figure) benchmark modules."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Iterable

import numpy as np

from repro.core import (SimResult, build_scenario, dream_full,
                        run_planaria, run_sim)
from repro.core.baselines import FCFSScheduler, VeltairLikeScheduler

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
DURATION_S = 6.0
ALL_SCENARIOS = ("VR_Gaming", "AR_Call", "Drone_Outdoor", "Drone_Indoor",
                 "AR_Social")


def geomean(xs: Iterable[float]) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    xs = np.maximum(xs, 1e-9)
    return float(np.exp(np.mean(np.log(xs))))


def run_cell(scenario: str, system: str, scheduler: str,
             cascade_prob: float = 0.5, duration_s: float = DURATION_S,
             seed: int = 0, **sched_kw) -> SimResult:
    """One (scenario, system, scheduler) simulation."""
    scn = build_scenario(scenario, cascade_prob)
    if scheduler == "Planaria":
        return run_planaria(scn, system, duration_s=duration_s, seed=seed)
    factories: dict[str, Callable] = {
        "FCFS": lambda: FCFSScheduler(),
        "Veltair": lambda: VeltairLikeScheduler(),
        "DREAM": lambda: dream_full(seed=seed, **sched_kw),
    }
    if scheduler in factories:
        return run_sim(scn, system, factories[scheduler],
                       duration_s=duration_s, seed=seed)
    raise KeyError(scheduler)


def save_artifact(name: str, payload) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed = time.time() - self.t0
        return False
