"""Benchmark aggregator: one module per paper figure + sweeps + kernels.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig7 roofline
    PYTHONPATH=src python -m benchmarks.run --only scenario_sweep \
        --seed 3 --duration 2.0 --json out.json

``--json`` aggregates every module's ``run()`` payload into one
machine-readable file (the BENCH_*.json perf-trajectory input); ``--seed``
and ``--duration`` thread through to every simulator-backed figure that
accepts them.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time

MODULES = (
    ("fig2", "benchmarks.fig2_static_vs_dynamic"),
    ("fig7", "benchmarks.fig7_heterogeneous"),
    ("fig8", "benchmarks.fig8_homogeneous"),
    ("fig9", "benchmarks.fig9_breakdown"),
    ("fig10", "benchmarks.fig10_param_search"),
    ("fig12", "benchmarks.fig12_cascade_prob"),
    ("fig13", "benchmarks.fig13_metric_ablation"),
    ("fig14", "benchmarks.fig14_supernet"),
    ("scenario_sweep", "benchmarks.scenario_sweep"),
    ("fleet_sweep", "benchmarks.fleet_sweep"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
)


def _filter_kwargs(fn, **kw) -> dict:
    params = inspect.signature(fn).parameters
    return {k: v for k, v in kw.items() if k in params and v is not None}


def git_provenance() -> dict:
    """Git identity of the tree that produced an artifact: {"sha": ...,
    "dirty": ...} — CI uploads these files as a trend series, so every
    point must be traceable to the exact commit (and flag uncommitted
    local edits).  The BENCH trajectory (appended by every
    ``scripts/check_bench.py`` run, which CI executes *before* this) is
    a runtime log, not a source edit, so it is excluded from the dirty
    computation — otherwise every nightly point would read dirty on a
    clean checkout.  Degrades to nulls outside a git checkout (e.g. a
    source tarball).  Shared with ``scripts/check_bench.py``."""
    import os
    import subprocess
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain", "--", ".",
             ":(exclude)benchmarks/baselines/trajectory.json"],
            cwd=root, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip() != ""
        return {"sha": sha, "dirty": dirty}
    except Exception:  # noqa: BLE001 — provenance must never fail a run
        return {"sha": None, "dirty": None}


def _describe(modname: str) -> str:
    """One-line benchmark description: the first line of the module's
    docstring, read via ``ast`` so --list stays instant (no benchmark
    imports, no jax) and docs/tooling share one source of truth."""
    import ast
    import importlib.util
    try:
        spec = importlib.util.find_spec(modname)
        with open(spec.origin) as f:
            doc = ast.get_docstring(ast.parse(f.read()))
        return doc.strip().splitlines()[0] if doc else "(no description)"
    except Exception:  # noqa: BLE001 — --list must never crash
        return "(no description)"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of benchmark tags to run")
    ap.add_argument("--list", action="store_true",
                    help="print available benchmark tags and exit")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write aggregated run() payloads to this JSON file")
    ap.add_argument("--seed", type=int, default=None,
                    help="seed threaded to simulator-backed figures")
    ap.add_argument("--duration", type=float, default=None,
                    help="per-cell simulation duration (seconds)")
    ap.add_argument("--obs", default=None, metavar="DIR",
                    help="export observability artifacts (spans/metrics/"
                         "profile) from obs-capable benchmarks to this dir")
    args = ap.parse_args()
    if args.list:
        for tag, modname in MODULES:
            print(f"{tag:>16s}  {modname}")
            print(f"{'':>16s}  {_describe(modname)}")
        return
    tags = {t for t, _ in MODULES}
    unknown = set(args.only or ()) - tags
    if unknown:
        ap.error(f"unknown benchmark tags: {sorted(unknown)}; "
                 f"choose from {sorted(tags)}")
    if args.json is not None:
        try:  # fail on an unwritable path now, not after the full run
            open(args.json, "a").close()
        except OSError as e:
            ap.error(f"--json path not writable: {e}")
    import importlib
    failures = []
    payloads: dict[str, object] = {}
    wall_s: dict[str, float] = {}
    for tag, modname in MODULES:
        if args.only and tag not in args.only:
            continue
        print(f"\n===== {tag} ({modname}) =====", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            kw = _filter_kwargs(mod.run, seed=args.seed,
                                duration_s=args.duration,
                                obs_dir=args.obs)
            if args.json is not None:
                payloads[tag] = mod.run(**kw)
                print(f"  [{tag}] collected "
                      f"{len(json.dumps(payloads[tag]))} bytes of results")
            elif kw and len(_filter_kwargs(mod.main, **kw)) < len(kw):
                # main() can't honor the requested flags (fig mains take no
                # args) — run parametrized; results land in the artifact dir
                mod.run(**kw)
                print(f"  [{tag}] ran with {kw}; "
                      "results in benchmarks/artifacts/")
            elif kw:
                mod.main(**kw)
            else:
                mod.main()
        except Exception as e:  # noqa: BLE001
            failures.append((tag, repr(e)))
            print(f"  FAILED: {e!r}")
        wall_s[tag] = round(time.time() - t0, 3)
        print(f"  [{tag}] {wall_s[tag]:.1f}s", flush=True)
    if args.json is not None:
        out = {"seed": args.seed, "duration_s": args.duration,
               "git": git_provenance(),
               "failures": failures, "wall_s": wall_s,
               "results": payloads}
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        print(f"\nwrote {args.json}")
    if failures:
        print("\nFAILED benchmarks:", failures)
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
