"""Benchmark aggregator: one module per paper figure + roofline + kernels.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig7 roofline
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = (
    ("fig2", "benchmarks.fig2_static_vs_dynamic"),
    ("fig7", "benchmarks.fig7_heterogeneous"),
    ("fig8", "benchmarks.fig8_homogeneous"),
    ("fig9", "benchmarks.fig9_breakdown"),
    ("fig10", "benchmarks.fig10_param_search"),
    ("fig12", "benchmarks.fig12_cascade_prob"),
    ("fig13", "benchmarks.fig13_metric_ablation"),
    ("fig14", "benchmarks.fig14_supernet"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of benchmark tags to run")
    args = ap.parse_args()
    import importlib
    failures = []
    for tag, modname in MODULES:
        if args.only and tag not in args.only:
            continue
        print(f"\n===== {tag} ({modname}) =====", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            mod.main()
        except Exception as e:  # noqa: BLE001
            failures.append((tag, repr(e)))
            print(f"  FAILED: {e!r}")
        print(f"  [{tag}] {time.time() - t0:.1f}s", flush=True)
    if failures:
        print("\nFAILED benchmarks:", failures)
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
