"""Figure 12: UXCost vs ML-cascade trigger probability (load sweep).

VR_Gaming / AR_Social on 4K heterogeneous systems with cascade probability
50% -> 99%. Paper: DREAM's advantage grows with system load; smart frame
drop and Supernet switching contribute most under the heaviest load.
"""
from __future__ import annotations

from repro.core import build_scenario, dream_mapscore, run_sim

from .common import DURATION_S, run_cell, save_artifact

SCENARIOS = ("VR_Gaming", "AR_Social")
SYSTEMS_FIG12 = ("4K_1WS2OS", "4K_1OS2WS")
PROBS = (0.5, 0.7, 0.9, 0.99)


def run(duration_s: float = DURATION_S, seed: int = 0) -> dict:
    cells = []
    for scenario in SCENARIOS:
        for system in SYSTEMS_FIG12:
            for p in PROBS:
                row = {"scenario": scenario, "system": system, "prob": p}
                for sched in ("Veltair", "Planaria", "DREAM"):
                    r = run_cell(scenario, system, sched, cascade_prob=p,
                                 duration_s=duration_s, seed=seed)
                    row[sched] = r.uxcost
                scn = build_scenario(scenario, p)
                r_map = run_sim(scn, system, lambda: dream_mapscore(seed),
                                duration_s=duration_s, seed=seed)
                row["DREAM-MapScore"] = r_map.uxcost
                cells.append(row)
    out = {"cells": cells}
    save_artifact("fig12_cascade_prob", out)
    return out


def main() -> None:
    out = run()
    print("fig12: UXCost vs cascade probability")
    for c in out["cells"]:
        print(f"  {c['scenario']:>10s} {c['system']:>10s} p={c['prob']:.2f} "
              f"Veltair={c['Veltair']:8.3f} Planaria={c['Planaria']:8.3f} "
              f"DREAM-Map={c['DREAM-MapScore']:8.3f} "
              f"DREAM-Full={c['DREAM']:8.3f}")


if __name__ == "__main__":
    main()
