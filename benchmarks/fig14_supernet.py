"""Figure 14: Supernet subnet selection vs system load.

Breakdown of which OFA subnet the Supernet-switching engine dispatched for
the context-understanding model, under 50% vs 99% cascade probability on
the 4K heterogeneous systems. Paper: under light load the original subnet
dominates (>80%); under heavy load 40-60%+ shift to lighter variants.
"""
from __future__ import annotations

from repro.core import build_scenario, dream_full, run_sim

from .common import DURATION_S, save_artifact

SCENARIOS = ("VR_Gaming", "AR_Social")
SYSTEMS_FIG14 = ("4K_1WS2OS", "4K_1OS2WS")
PROBS = (0.5, 0.99)


def run(duration_s: float = DURATION_S, seed: int = 0) -> dict:
    rows = []
    for scenario in SCENARIOS:
        for system in SYSTEMS_FIG14:
            for p in PROBS:
                scn = build_scenario(scenario, p)
                r = run_sim(scn, system, lambda: dream_full(seed),
                            duration_s=duration_s, seed=seed)
                counts = {k: v for k, v in r.variant_counts.items()
                          if k.startswith("ctx_ofa")}
                total = sum(counts.values())
                orig = counts.get("ctx_ofa", 0)
                rows.append({
                    "scenario": scenario, "system": system, "prob": p,
                    "counts": counts,
                    "original_frac": orig / total if total else 1.0,
                    "lighter_frac": 1 - (orig / total if total else 1.0),
                })
    out = {"rows": rows}
    save_artifact("fig14_supernet", out)
    return out


def main() -> None:
    out = run()
    print("fig14: Supernet subnet selection vs load")
    for r in out["rows"]:
        print(f"  {r['scenario']:>10s} {r['system']:>10s} p={r['prob']:.2f} "
              f"original={r['original_frac']*100:5.1f}% "
              f"lighter={r['lighter_frac']*100:5.1f}%  {r['counts']}")


if __name__ == "__main__":
    main()
