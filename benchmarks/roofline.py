"""Roofline table from the dry-run artifacts (EXPERIMENTS.md section source).

Reads benchmarks/artifacts/dryrun/single__*.json (the single-pod mesh; the
multi-pod pass only proves the pod axis shards) and prints, per
(arch x shape): the three roofline terms in seconds, the dominant term,
MODEL_FLOPS / HLO_FLOPs (useful-compute ratio), and bytes/device.
"""
from __future__ import annotations

import glob
import json
import os

from .common import ARTIFACT_DIR, save_artifact

DRYRUN_DIR = os.path.join(ARTIFACT_DIR, "dryrun")


def load(mesh: str = "single") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"{mesh}__*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run() -> dict:
    rows = load("single")
    table = []
    for r in rows:
        if r.get("status") != "ok":
            table.append({"arch": r["arch"], "shape": r["shape"],
                          "status": r.get("status", "?"),
                          "error": r.get("error", "")[:100]})
            continue
        t = r["terms_s"]
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        table.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "dominant": r["dominant"],
            "roofline_fraction": t["compute_s"] / total if total else 0.0,
            "useful_flops_ratio": r["useful_flops_ratio"],
            "bytes_per_dev_gb": r["memory"]["temp_bytes"] / 1e9,
        })
    multi = load("multipod")
    out = {
        "single_pod": table,
        "multipod_ok": sum(1 for r in multi if r.get("status") == "ok"),
        "multipod_total": len(multi),
    }
    save_artifact("roofline", out)
    return out


def main() -> None:
    out = run()
    print("roofline (single-pod 16x16 mesh; terms in ms/step):")
    hdr = (f"  {'arch':>24s} {'shape':<12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dom':<10s} {'RL-frac':>7s} {'useful':>7s}")
    print(hdr)
    for r in out["single_pod"]:
        if r["status"] != "ok":
            print(f"  {r['arch']:>24s} {r['shape']:<12s} {r['status']}: "
                  f"{r.get('error', '')}")
            continue
        print(f"  {r['arch']:>24s} {r['shape']:<12s} "
              f"{r['compute_s']*1e3:9.2f} {r['memory_s']*1e3:9.2f} "
              f"{r['collective_s']*1e3:9.2f} {r['dominant']:<10s} "
              f"{r['roofline_fraction']*100:6.1f}% "
              f"{r['useful_flops_ratio']*100:6.1f}%")
    print(f"  multipod: {out['multipod_ok']}/{out['multipod_total']} "
          f"cells compiled OK")


if __name__ == "__main__":
    main()
