"""Figure 9: per-optimization UXCost improvement breakdown.

VR_Gaming + AR_Social (the Supernet scenarios) on 4K and 8K heterogeneous
systems. Variants: fixed (alpha=beta=1, no adaptivity) -> DREAM-MapScore
(online param optimization) -> DREAM-SmartDrop (+frame drop) -> DREAM-Full
(+Supernet switching). Paper: param opt alone -49.2% (4K) / -21.0% (8K);
smart drop ~-16.5%/-13.8%; Supernet switch a further 6-9%.
"""
from __future__ import annotations

from repro.core import (DreamScheduler, build_scenario, dream_full,
                        dream_mapscore, dream_smartdrop, run_sim)

from .common import DURATION_S, geomean, save_artifact

SCENARIOS = ("VR_Gaming", "AR_Social")
SYSTEMS_FIG9 = ("4K_1WS2OS", "4K_1OS2WS", "8K_1WS2OS", "8K_1OS2WS")

VARIANTS = {
    "fixed": lambda seed: DreamScheduler(adaptivity=False, frame_drop=False,
                                         supernet=False, seed=seed),
    "DREAM-MapScore": lambda seed: dream_mapscore(seed=seed),
    "DREAM-SmartDrop": lambda seed: dream_smartdrop(seed=seed),
    "DREAM-Full": lambda seed: dream_full(seed=seed),
}


def run(duration_s: float = DURATION_S, seed: int = 0) -> dict:
    cells = []
    for scenario in SCENARIOS:
        for system in SYSTEMS_FIG9:
            scn = build_scenario(scenario, 0.5)
            row = {"scenario": scenario, "system": system}
            for name, mk in VARIANTS.items():
                r = run_sim(scn, system, lambda mk=mk: mk(seed),
                            duration_s=duration_s, seed=seed)
                row[name] = {"uxcost": r.uxcost, "dlv": r.dlv_rate,
                             "drops": r.drops,
                             "variants": sum(
                                 v for k, v in r.variant_counts.items()
                                 if "@" in k)}
            cells.append(row)
    gm = {name: geomean(c[name]["uxcost"] for c in cells)
          for name in VARIANTS}
    out = {
        "cells": cells,
        "geomean_uxcost": gm,
        "improvement_vs_fixed": {
            name: 1 - gm[name] / gm["fixed"] for name in VARIANTS},
    }
    save_artifact("fig9_breakdown", out)
    return out


def main() -> None:
    out = run()
    print("fig9: optimization breakdown (geomean UXCost)")
    for name, v in out["geomean_uxcost"].items():
        imp = out["improvement_vs_fixed"][name]
        print(f"  {name:>16s} uxcost={v:8.4f} vs-fixed={imp*100:+6.1f}%")


if __name__ == "__main__":
    main()
