"""Figure 7: UXCost / DLV / energy on heterogeneous hardware, all scenarios.

Paper claims (geomean over scenarios and hardware): DREAM cuts UXCost by
32.2% vs Planaria and 50.0% vs Veltair (up to 80.8% / 97.6%).
"""
from __future__ import annotations

from repro.core import HETERO_SYSTEMS

from .common import ALL_SCENARIOS, DURATION_S, geomean, run_cell, save_artifact

SCHEDULERS = ("FCFS", "Veltair", "Planaria", "DREAM")


def run(systems=HETERO_SYSTEMS, duration_s: float = DURATION_S,
        seed: int = 0, tag: str = "fig7_heterogeneous") -> dict:
    cells = []
    for scenario in ALL_SCENARIOS:
        for system in systems:
            row = {"scenario": scenario, "system": system}
            for sched in SCHEDULERS:
                r = run_cell(scenario, system, sched, duration_s=duration_s,
                             seed=seed)
                row[sched] = {"uxcost": r.uxcost, "dlv": r.dlv_rate,
                              "energy": r.norm_energy, "frames": r.frames}
            cells.append(row)
    summary = {}
    for sched in SCHEDULERS:
        summary[sched] = geomean(c[sched]["uxcost"] for c in cells)
    vs = {
        "vs_planaria": 1 - summary["DREAM"] / summary["Planaria"],
        "vs_veltair": 1 - summary["DREAM"] / summary["Veltair"],
        "vs_fcfs": 1 - summary["DREAM"] / summary["FCFS"],
    }
    out = {"cells": cells, "geomean_uxcost": summary, "dream_reduction": vs,
           "paper_claims": {"vs_planaria": 0.322, "vs_veltair": 0.500}}
    save_artifact(tag, out)
    return out


def main() -> None:
    out = run()
    print("fig7: UXCost on heterogeneous hardware")
    for c in out["cells"]:
        vals = " ".join(f"{s}={c[s]['uxcost']:8.3f}" for s in SCHEDULERS)
        print(f"  {c['scenario']:>14s} {c['system']:>10s} {vals}")
    gm = out["geomean_uxcost"]
    print("  geomean:", {k: round(v, 4) for k, v in gm.items()})
    red = out["dream_reduction"]
    print(f"  DREAM vs Planaria: {red['vs_planaria']*100:.1f}% "
          f"(paper 32.2%) | vs Veltair: {red['vs_veltair']*100:.1f}% "
          f"(paper 50.0%)")


if __name__ == "__main__":
    main()
