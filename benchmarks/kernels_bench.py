"""Kernel microbench: analytic roofline terms + CPU-oracle agreement.

No TPU is attached, so wall-clock numbers here are the XLA-oracle CPU times
(reported for relative comparison only). The meaningful kernel outputs are
the analytic per-call FLOPs / HBM bytes / VMEM working set that the
BlockSpec tiling commits to — these feed the §Perf napkin math.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

# note: `from repro.kernels import flash_attention` would resolve to the
# ops wrapper *function* re-exported by the package, not the module
import repro.kernels.flash_attention as fa
import repro.kernels.decode_attention as da
import repro.kernels.ssd as ssd_mod
from repro.kernels import ref

from .common import save_artifact


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> dict:
    key = jax.random.PRNGKey(0)
    rows = []

    # flash attention: gemma2-class local layer tile
    b, s, n, kv, h = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (b, s, n, h), jnp.float32)
    k = jax.random.normal(key, (b, s, kv, h), jnp.float32)
    v = jax.random.normal(key, (b, s, kv, h), jnp.float32)
    t_ref = _time(lambda *a: ref.attention(*a, window=128), q, k, v)
    rows.append({
        "kernel": "flash_attention",
        "shape": f"b{b} s{s} n{n} kv{kv} h{h} w128",
        "analytic_flops": fa.flops(b, s, s, n, h, causal=True),
        "vmem_bytes_per_step": fa.vmem_bytes(128, 128, h),
        "cpu_oracle_ms": t_ref * 1e3,
    })

    # decode attention: 32k cache read
    s_kv = 4096
    kc = jax.random.normal(key, (b, s_kv, kv, h), jnp.float32)
    vc = jax.random.normal(key, (b, s_kv, kv, h), jnp.float32)
    q1 = jax.random.normal(key, (b, n, h), jnp.float32)
    pos = jnp.full((b,), s_kv - 1, jnp.int32)
    t_ref = _time(lambda *a: ref.decode_attention(*a), q1, kc, vc, pos)
    rows.append({
        "kernel": "decode_attention",
        "shape": f"b{b} skv{s_kv} n{n} kv{kv} h{h}",
        "analytic_hbm_bytes": da.hbm_bytes(b, s_kv, kv, h),
        "cpu_oracle_ms": t_ref * 1e3,
    })

    # ssd: mamba2-130m-class block
    hh, p, nn, ch = 8, 64, 64, 64
    x = jax.random.normal(key, (b, 1024, hh, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (b, 1024, hh), jnp.float32))
    A = -jnp.exp(jax.random.normal(key, (hh,), jnp.float32) * 0.3)
    B = jax.random.normal(key, (b, 1024, nn), jnp.float32)
    C = jax.random.normal(key, (b, 1024, nn), jnp.float32)
    D = jnp.ones((hh,), jnp.float32)
    t_seq = _time(lambda *a: ref.ssd(*a)[0], x, dt, A, B, C, D)
    t_chunk = _time(
        lambda *a: ref.ssd_chunked(*a, chunk=ch)[0], x, dt, A, B, C, D)
    rows.append({
        "kernel": "ssd",
        "shape": f"b{b} s1024 h{hh} p{p} n{nn} chunk{ch}",
        "analytic_flops": ssd_mod.flops(b, 1024, hh, p, nn, ch),
        "cpu_sequential_ms": t_seq * 1e3,
        "cpu_chunked_ms": t_chunk * 1e3,
        "chunked_speedup": t_seq / t_chunk,
    })

    out = {"rows": rows}
    save_artifact("kernels_bench", out)
    return out


def main() -> None:
    out = run()
    print("kernel microbench (CPU oracle timings; analytic TPU terms):")
    for r in out["rows"]:
        print("  " + ", ".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
