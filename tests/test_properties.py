"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.mapscore import (CSWITCH_MAX, MapScoreParams, STARV_MAX,
                                 URGENCY_MAX, mapscore)
from repro.core.uxcost import (ModelWindowStats, WindowStats, norm_energy,
                               rate_dlv, uxcost)
from repro.core.costmodel import build_cost_table
from repro.core.types import Layer, ModelGraph, OpType, SYSTEMS
from repro.distributed.elastic import best_mesh_shape
from repro.training.optim import lr_at, OptimConfig

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# UXCost (Algorithm 2)
# ---------------------------------------------------------------------------

stats_st = st.builds(
    ModelWindowStats,
    frames=st.integers(0, 1000),
    violated=st.integers(0, 1000),
    energy_j=st.floats(0, 1e3, allow_nan=False),
    worst_energy_j=st.floats(0, 1e3, allow_nan=False),
).filter(lambda s: s.violated <= s.frames and s.energy_j <= s.worst_energy_j)


@given(st.lists(stats_st, min_size=1, max_size=6))
def test_uxcost_nonnegative_and_bounded(models):
    ws = WindowStats()
    for i, m in enumerate(models):
        ws.per_model[f"m{i}"] = m
    u = uxcost(ws)
    assert u >= 0.0
    assert u <= len(models) ** 2 + 1e-9     # both factors <= n_models


@given(stats_st)
def test_rate_dlv_floor_when_zero_violations(s):
    r = rate_dlv(s)
    if s.frames == 0:
        assert r == 0.0
    elif s.violated == 0:
        assert r == 1.0 / (2 * s.frames)    # Alg. 2 lines 7-8
    else:
        assert abs(r - s.violated / s.frames) < 1e-12


@given(stats_st)
def test_norm_energy_in_unit_interval(s):
    assert 0.0 <= norm_energy(s) <= 1.0 + 1e-9


@given(st.lists(stats_st, min_size=1, max_size=4),
       st.integers(0, 3))
def test_uxcost_monotone_in_violations(models, idx):
    """Adding a violated frame (same energy) never decreases UXCost."""
    ws1, ws2 = WindowStats(), WindowStats()
    for i, m in enumerate(models):
        ws1.per_model[f"m{i}"] = ModelWindowStats(
            m.frames, m.violated, m.energy_j, m.worst_energy_j)
        ws2.per_model[f"m{i}"] = ModelWindowStats(
            m.frames, m.violated, m.energy_j, m.worst_energy_j)
    k = f"m{idx % len(models)}"
    m = ws2.per_model[k]
    if m.frames == 0 or m.violated == 0:
        return  # the 1/(2n) floor makes 0 -> 1 violations non-monotone by design
    m.frames += 1
    m.violated += 1
    assert uxcost(ws2) >= uxcost(ws1) - 1e-9


# ---------------------------------------------------------------------------
# MapScore (Algorithm 1)
# ---------------------------------------------------------------------------

def _mk_table():
    g = ModelGraph("m", layers=(
        Layer("a", OpType.FC, K=128, C=128),
        Layer("b", OpType.CONV2D, K=32, C=32, R=3, S=3, Y=16, X=16),
    ))
    return build_cost_table(g, SYSTEMS["4K_1WS2OS"])


TABLE = _mk_table()


@given(
    t_curr=st.floats(0, 10, allow_nan=False),
    deadline=st.floats(0, 10, allow_nan=False),
    t_cmpl=st.floats(0, 10, allow_nan=False),
    alpha=st.floats(0, 2), beta=st.floats(0, 2),
    nxt=st.integers(0, 1),
    prev=st.floats(0, 1e7),
    same=st.booleans(),
)
@settings(max_examples=200)
def test_mapscore_finite_and_bounded(t_curr, deadline, t_cmpl, alpha, beta,
                                     nxt, prev, same):
    """MapScore never produces NaN/inf and every term honors its clamp."""
    n = TABLE.n_accs
    s = mapscore(TABLE, nxt, np.array([nxt]), t_curr, t_cmpl, deadline,
                 np.full(n, prev), np.full(n, same),
                 MapScoreParams(alpha, beta))
    assert s.shape == (n,)
    assert np.all(np.isfinite(s))
    upper = URGENCY_MAX * n + alpha * STARV_MAX + beta * n
    lower = -beta * CSWITCH_MAX
    assert np.all(s <= upper + 1e-6) and np.all(s >= lower - 1e-6)


# ---------------------------------------------------------------------------
# elastic mesh factorization
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 4096), mp=st.sampled_from([1, 2, 4, 8, 16]))
def test_best_mesh_shape_valid(n, mp):
    dp, m = best_mesh_shape(n, mp)
    assert dp * m <= n
    assert dp >= 1 and m >= 1
    assert m <= mp


# ---------------------------------------------------------------------------
# optimizer schedule
# ---------------------------------------------------------------------------

@given(step=st.integers(0, 10_000))
def test_lr_schedule_bounded(step):
    cfg = OptimConfig(learning_rate=1e-3, warmup_steps=100, total_steps=1000)
    lr = float(lr_at(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.learning_rate * (1 + 1e-6)  # f32 rounding
    if step >= cfg.total_steps:
        assert lr >= cfg.min_lr_frac * cfg.learning_rate - 1e-9


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 100), step=st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_data_host_count_invariance(seed, step):
    """Global batch content is identical for 1 host vs 2 hosts."""
    from repro.data import SyntheticLMData
    one = SyntheticLMData(vocab_size=64, seq_len=16, global_batch=4,
                          seed=seed)
    h0 = SyntheticLMData(vocab_size=64, seq_len=16, global_batch=4,
                         seed=seed, num_hosts=2, host_id=0)
    h1 = SyntheticLMData(vocab_size=64, seq_len=16, global_batch=4,
                         seed=seed, num_hosts=2, host_id=1)
    full = one.batch(step)["tokens"]
    top = h0.batch(step)["tokens"]
    bot = h1.batch(step)["tokens"]
    np.testing.assert_array_equal(full, np.concatenate([top, bot], 0))
