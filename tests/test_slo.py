"""SLO subsystem: tier declarations, admission state machine, degradation
ladder (hysteresis + node-aware ordering), swap/reject trace replay, legacy
byte-stability, and rejection accounting as a first-class UXCost outcome."""
import pytest

from repro.cluster import (AdmissionController, DEFAULT_SLO,
                           FleetScenarioBuilder, FleetSimulator, FuzzSpec,
                           LifecycleFuzz, LoadEstimator, SLOClass, SLOError,
                           SLOFuzz, StreamState, TelemetryWindow,
                           TIER_BEST_EFFORT, TIER_GUARANTEED, TIER_STANDARD,
                           TIER_DEFAULTS, slo_from_config)
from repro.cluster import trace as ftrace
from repro.core import build_scenario, dream_full
from repro.core.simulator import Simulator
from repro.scenarios import ScenarioError

SMALL_SYSTEMS = ("4K_1WS2OS", "8K_2WS", "4K_2OS", "8K_1OS2WS")

#: Aggressive controller for the end-to-end tests: thresholds low enough
#: that a small 4-node fleet reliably crosses them, so the ladder and the
#: reject gate both fire within a 1-second run.
SLO_CFG = {"t_degrade": 0.30, "t_promote": 0.20, "t_reject": 0.36,
           "max_actions": 4, "admit_level": 2}


def tiered_fleet(seed=3, n_nodes=4, n_streams=24, dur=1.0, tiers=True,
                 supernet_frac=0.5, burst=True):
    """A small overloaded fleet: a base wave plus (optionally) a second
    burst wave that fully departs — the end-to-end shape the SLO
    controller is built for, sized for test wall-time."""
    b = FleetScenarioBuilder("slo_fleet")
    for i in range(n_nodes):
        b.node(SMALL_SYSTEMS[i % len(SMALL_SYSTEMS)])
    slo_fuzz = SLOFuzz(tier_mix=(1.0, 2.0, 2.0) if tiers else None,
                       supernet_frac=supernet_frac)
    b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0, t1=round(0.35 * dur, 6),
        fps_scale=0.55, deterministic_arrivals=True, slo=slo_fuzz))
    if burst:
        b.fuzz_streams(FuzzSpec(
            n_streams=n_streams // 2, seed=seed + 50_021,
            t0=round(0.45 * dur, 6), t1=round(0.7 * dur, 6),
            fps_scale=0.55, deterministic_arrivals=True, slo=slo_fuzz,
            lifecycle=LifecycleFuzz(depart_frac=1.0,
                                    t0=round(0.72 * dur, 6),
                                    t1=round(0.9 * dur, 6))))
    return b.build()


def one_node_reject_fleet(depart_at=None, fps=40.0, dur=1.0):
    """One node, one heavy admitted stream, then a best-effort arrival the
    (hair-trigger) controller must reject; optionally the rejected stream
    departs mid-run, closing its rejection span early."""
    b = FleetScenarioBuilder("reject_fleet")
    b.node("4K_1WS2OS")
    b.add_stream([{"model": {"builder": "kws_res8", "name": "kws",
                             "kwargs": {}}, "fps": fps,
                   "arrival": {"kind": "periodic", "phase_frac": 0.0}}],
                 at=0.0, slo=TIER_STANDARD)
    sid = b.add_stream([{"model": {"builder": "kws_res8", "name": "kws2",
                                   "kwargs": {}}, "fps": fps,
                         "arrival": {"kind": "periodic", "phase_frac": 0.0}}],
                       at=0.2, slo=TIER_BEST_EFFORT)
    if depart_at is not None:
        b.depart(sid, at=depart_at)
    return b.build()


@pytest.fixture(scope="module")
def slo_run():
    """One live SLO-gated overload run + its trace-replay — shared across
    the end-to-end assertions below (the run is the expensive part)."""
    scn = tiered_fleet()
    live = FleetSimulator(scn, "score", duration_s=1.0, seed=3,
                          slo=SLO_CFG, slo_every_s=0.1, record=True).run()
    text = ftrace.dumps(live.trace)
    rep = FleetSimulator(replay=ftrace.loads(text)).run()
    return live, rep, text


# ---------------------------------------------------------------------------
# SLO classes and config forms
# ---------------------------------------------------------------------------

def test_slo_class_validation():
    with pytest.raises(SLOError):
        SLOClass(tier=7, budget_factor=1.0, priority=1.0)
    with pytest.raises(SLOError):
        SLOClass(tier=TIER_STANDARD, budget_factor=0.0, priority=1.0)
    with pytest.raises(SLOError):
        SLOClass(tier=TIER_STANDARD, budget_factor=1.0, priority=-1.0)


def test_slo_from_config_forms():
    assert slo_from_config(None) is DEFAULT_SLO
    assert DEFAULT_SLO.tier == TIER_STANDARD
    for tier in (TIER_GUARANTEED, TIER_STANDARD, TIER_BEST_EFFORT):
        assert slo_from_config(tier) == TIER_DEFAULTS[tier]
    custom = slo_from_config({"tier": 2, "budget_factor": 8.0})
    assert custom.tier == TIER_BEST_EFFORT and custom.budget_factor == 8.0
    assert custom.priority == TIER_DEFAULTS[TIER_BEST_EFFORT].priority
    # round-trip: defaults compress to a bare tier, customs stay explicit
    assert TIER_DEFAULTS[0].to_config() == {"tier": 0}
    assert slo_from_config(custom.to_config()) == custom
    for bad in (True, 9, {"tier": "x"}, {"budget_factor": 1.0}, "gold"):
        with pytest.raises(SLOError):
            slo_from_config(bad)


def test_controller_make_and_config_roundtrip():
    assert AdmissionController.make(None) is None
    assert AdmissionController.make(False) is None
    ac = AdmissionController.make(True)
    assert isinstance(ac, AdmissionController)
    assert AdmissionController.make(ac) is ac
    cfg = AdmissionController.make(SLO_CFG).to_config()
    assert AdmissionController.make(cfg).to_config() == cfg
    with pytest.raises(SLOError):
        AdmissionController.make("always")
    with pytest.raises(SLOError):
        # thresholds must order t_promote < t_degrade <= t_reject
        AdmissionController(t_promote=0.9, t_degrade=0.5)


# ---------------------------------------------------------------------------
# admission state machine
# ---------------------------------------------------------------------------

def test_admission_state_machine():
    ac = AdmissionController()          # t_degrade=0.85, t_reject=1.05
    t0, t1, t2 = (TIER_DEFAULTS[t] for t in range(3))
    assert ac.admit(t2, 3, [0.2]) == ("admit", 0)        # calm: everyone in
    assert ac.admit(t0, 3, [2.0]) == ("admit", 0)        # guaranteed: always
    assert ac.admit(t1, 3, [0.9]) == ("degrade", 1)      # pressured: one down
    assert ac.admit(t1, 0, [0.9]) == ("admit", 0)        # no ladder to use
    assert ac.admit(t2, 3, [1.2]) == ("reject", 0)       # best-effort out
    assert ac.admit(t1, 3, [1.2]) == ("degrade", 1)      # standard never out
    # admit_level clamps to the stream's actual ladder depth
    deep = AdmissionController(admit_level=2)
    assert deep.admit(t2, 1, [0.9]) == ("degrade", 1)
    assert deep.admit(t2, 3, [0.9]) == ("degrade", 2)


def test_admission_acts_on_forecast_before_saturation():
    """A rising-load trend degrades arrivals while live utilization is
    still low — the estimator's whole point is acting ahead of
    saturation."""
    ac = AdmissionController()
    for u in (0.2, 0.6, 0.9):
        ac.estimator.observe(u)
    assert ac.estimator.predict() > 0.9
    assert ac.admit(TIER_DEFAULTS[2], 2, [0.3])[0] == "degrade"


def test_pressure_folds_in_window_signals():
    """DLV, backlog, and latency-over-budget all raise the pressure
    scalar beyond bare utilization."""
    def window(**kw):
        base = dict(t0=0.0, t1=0.5, frames=10, violated=0, dlv_rate=0.0,
                    uxcost=0.0, node_dlv={}, node_frames={},
                    backlog_p50=0.0, backlog_p90=0.0, backlog_max=0.0,
                    migrations=0, xfer_j=0.0, stream_uxcost={})
        base.update(kw)
        return TelemetryWindow(**base)

    calm = AdmissionController()
    p0 = calm.on_window(window(), [0.4])
    hot = AdmissionController()
    p1 = hot.on_window(window(node_dlv={0: 0.4, 1: 0.1},
                              backlog_p90=1.0), [0.4])
    assert p1 == pytest.approx(p0 + 0.5 * 0.4 + 0.25 * 1.0)
    # latency term needs a registered budget to normalize against
    late = AdmissionController()
    late.register(0, TIER_DEFAULTS[0], head_period_s=0.1)   # budget 0.1s
    p2 = late.on_window(window(pipe_frames=2, pipe_latency_s=0.6), [0.4])
    assert p2 > p0
    late.forget(0)
    assert late.pressure([0.4]) == pytest.approx(p0)  # budget gone: term off


def test_load_estimator_tracks_level_and_trend():
    est = LoadEstimator()
    assert est.predict() == 0.0
    for _ in range(8):
        est.observe(0.5)
    assert est.predict() == pytest.approx(0.5, abs=1e-3)
    rising = LoadEstimator()
    for u in (0.1, 0.3, 0.5, 0.7):
        rising.observe(u)
    assert rising.predict() > rising.level


# ---------------------------------------------------------------------------
# degradation ladder: hysteresis + node-aware ordering
# ---------------------------------------------------------------------------

def test_ladder_orders_and_hysteresis_band():
    ac = AdmissionController(t_degrade=0.8, t_promote=0.6, t_reject=1.0,
                             max_actions=2)
    states = [
        StreamState(sid=0, tier=0, priority=4.0, level=0, max_level=3,
                    load=9.0),                       # tier-0: untouchable
        StreamState(sid=1, tier=2, priority=1.0, level=0, max_level=3,
                    load=0.1),
        StreamState(sid=2, tier=2, priority=1.0, level=0, max_level=3,
                    load=0.9),
        StreamState(sid=3, tier=1, priority=2.0, level=3, max_level=3,
                    load=0.9),                       # already at the bottom
        StreamState(sid=4, tier=1, priority=2.0, level=1, max_level=3,
                    load=0.5),
    ]
    ac.last_pressure = 0.9
    # hottest node first (sid 2 before sid 1 despite equal tier/priority),
    # never tier-0, never past max_level, at most max_actions moves
    assert ac.plan(states) == [(2, 1), (4, 2)]
    ac.last_pressure = 0.7                           # inside the band
    assert ac.plan(states) == []                     # hysteresis: no flap
    ac.last_pressure = 0.5
    # promote coolest-node streams first, one level per tick
    assert ac.plan(states) == [(4, 0), (3, 2)]


def test_ladder_noop_without_degraded_or_eligible_streams():
    ac = AdmissionController()
    ac.last_pressure = 2.0
    only_t0 = [StreamState(sid=0, tier=0, priority=4.0, level=0,
                           max_level=3, load=1.0)]
    assert ac.plan(only_t0) == []
    ac.last_pressure = 0.0
    assert ac.plan(only_t0) == []                    # nothing to promote


# ---------------------------------------------------------------------------
# the actuator: Simulator.swap_variant
# ---------------------------------------------------------------------------

def test_swap_variant_pins_and_restores():
    scn = build_scenario("VR_Gaming", 0.5)
    sim = Simulator(scn, "4K_1WS2OS", dream_full(), duration_s=1.0)
    idx = scn.model_index("ctx_ofa")
    base = sim.specs[idx].model
    v1 = sim.swap_variant("ctx_ofa", 1, 0.0)
    assert v1 is base.variants[0]
    job = sim._create_job(idx, t=0.0)
    # pinned jobs start on the variant, locked against per-job switching
    assert job.graph_name == v1.name and job.variant_locked
    assert job.base_name == base.name               # stats stay on the base
    # level clamps to the ladder depth; level 0 restores the original
    assert sim.swap_variant("ctx_ofa", 99, 0.1) is base.variants[-1]
    assert sim.swap_variant("ctx_ofa", 0, 0.2) is base
    job2 = sim._create_job(idx, t=0.3)
    assert job2.graph_name == base.name and not job2.variant_locked
    # a model without variants is untouched at any level
    kws_idx = scn.model_index("kws_res8")
    kws = sim.specs[kws_idx].model
    assert sim.swap_variant("kws_res8", 2, 0.4) is kws


# ---------------------------------------------------------------------------
# builder: tier declarations and RNG isolation
# ---------------------------------------------------------------------------

def _entries(fps=5.0):
    return [{"model": {"builder": "kws_res8", "name": "kws", "kwargs": {}},
             "fps": fps}]


def test_builder_rejects_bad_slo_declarations():
    b = FleetScenarioBuilder("bad")
    b.node("4K_1WS2OS")
    with pytest.raises(SLOError):
        b.add_stream(_entries(), slo=7)
    with pytest.raises(SLOError):
        b.add_stream(_entries(), slo=True)
    with pytest.raises(ScenarioError):
        b.fuzz_streams(FuzzSpec(n_streams=4, seed=0,
                                slo=SLOFuzz(tier_mix=(1.0, 2.0))))
    with pytest.raises(ScenarioError):
        b.fuzz_streams(FuzzSpec(n_streams=4, seed=0,
                                slo=SLOFuzz(tier_mix=(-1.0, 1.0, 1.0))))
    with pytest.raises(ScenarioError):
        b.fuzz_streams(FuzzSpec(n_streams=4, seed=0,
                                slo=SLOFuzz(tier_mix=(0.0, 0.0, 0.0))))
    with pytest.raises(ScenarioError):
        b.fuzz_streams(FuzzSpec(n_streams=4, seed=0,
                                slo=SLOFuzz(supernet_frac=1.5)))


def _stream_events(scn):
    return [e for e in scn.events if e.kind == "stream"]


def test_tier_draws_do_not_perturb_population():
    """``tier_mix`` draws come from a dedicated RNG stream: the tiered
    population has bit-identical arrivals and pipelines to the tierless
    one — the ``slo`` field is the only difference."""
    def build(tiers):
        b = FleetScenarioBuilder("iso")
        b.node("4K_1WS2OS")
        b.fuzz_streams(FuzzSpec(
            n_streams=12, seed=5, t0=0.0, t1=0.5,
            slo=SLOFuzz(tier_mix=(1.0, 2.0, 2.0) if tiers else None)))
        return b.build()

    plain, tiered = build(False), build(True)
    ev0, ev1 = _stream_events(plain), _stream_events(tiered)
    assert len(ev0) == len(ev1) == 12
    for a, b_ in zip(ev0, ev1):
        assert a.t == b_.t
        assert a.payload["entries"] == b_.payload["entries"]
        assert "slo" not in a.payload
        assert b_.payload["slo"]["tier"] in (0, 1, 2)
    # all three tiers show up in a 12-stream draw with (1, 2, 2) weights
    assert {e.payload["slo"]["tier"] for e in ev1} == {0, 1, 2}


def test_supernet_frac_reheads_strided_streams():
    b = FleetScenarioBuilder("heads")
    b.node("4K_1WS2OS")
    b.fuzz_streams(FuzzSpec(n_streams=8, seed=5, t0=0.0, t1=0.5,
                            slo=SLOFuzz(supernet_frac=0.5)))
    by_sid = sorted(_stream_events(b.build()), key=lambda e: e.payload["sid"])
    heads = [e.payload["entries"][0]["model"]["builder"] for e in by_sid]
    assert heads[::2] == ["ofa"] * 4                # every 2nd stream
    assert all(h != "ofa" for h in heads[1::2])


# ---------------------------------------------------------------------------
# end-to-end: live SLO run, replay bit-exactness, rejection accounting
# ---------------------------------------------------------------------------

def test_slo_run_controller_acted(slo_run):
    live, _, _ = slo_run
    assert live.slo_enabled
    assert live.swaps > 0                            # ladder fired
    assert live.rejections > 0                       # reject gate fired
    assert live.promotions <= live.swaps
    # all three tiers completed frames under the burst
    assert set(live.tier_frames) == {0, 1, 2}


def test_slo_trace_replay_bitexact(slo_run):
    """Replay applies the recorded swap/reject decisions as inputs (the
    controller never runs) and must land on the identical result."""
    live, rep, _ = slo_run
    assert rep.uxcost == live.uxcost
    assert rep.dlv_rate == live.dlv_rate
    assert rep.frames == live.frames
    assert rep.drops == live.drops
    assert rep.migrations == live.migrations
    assert rep.swaps == live.swaps
    assert rep.promotions == live.promotions
    assert rep.rejections == live.rejections
    assert rep.reject_frames == live.reject_frames
    assert rep.tier_frames == live.tier_frames
    assert rep.tier_dlv == live.tier_dlv
    assert rep.slo_enabled == live.slo_enabled       # flagged via trace meta


def test_slo_trace_roundtrip_bytestable(slo_run):
    _, _, text = slo_run
    assert ftrace.dumps(ftrace.loads(text)) == text
    kinds = {e["type"] for e in ftrace.loads(text).events}
    assert "swap" in kinds and "reject" in kinds


def test_rejection_is_charged_not_silently_dropped(slo_run):
    """Every head frame a refused stream would have offered counts as a
    violated pseudo-frame in the tier accounting — rejections are paid
    for in UXCost, never free."""
    live, _, _ = slo_run
    assert live.reject_frames > 0
    # tier accounting covers completed + rejected pseudo frames exactly
    assert sum(live.tier_frames.values()) == live.frames + live.reject_frames


def test_reject_depart_closes_span():
    """A rejected stream accrues pseudo-violations only while it is
    present: its departure closes the rejection span."""
    slo = {"t_degrade": 2e-4, "t_promote": 1e-4, "t_reject": 2e-4}
    kw = dict(policy="score", duration_s=1.0, seed=0, slo=slo,
              slo_every_s=0.25)
    full = FleetSimulator(one_node_reject_fleet(), **kw).run()
    cut = FleetSimulator(one_node_reject_fleet(depart_at=0.5), **kw).run()
    assert full.rejections == cut.rejections == 1
    # span [0.2, 1.0) vs [0.2, 0.5) at 40 fps
    assert full.reject_frames == round(0.8 * 40)
    assert cut.reject_frames == round(0.3 * 40)
    # the lone best-effort stream never ran: its tier is pure violations
    assert full.tier_dlv[TIER_BEST_EFFORT] == 1.0


def test_slo_disabled_is_inert():
    """With no controller, tier declarations only label the accounting:
    the run itself is bit-identical to the tierless scenario."""
    kw = dict(policy="score", duration_s=1.0, seed=3)
    plain = FleetSimulator(tiered_fleet(tiers=False), **kw).run()
    tiered = FleetSimulator(tiered_fleet(tiers=True), **kw).run()
    assert not tiered.slo_enabled
    assert tiered.swaps == tiered.rejections == tiered.reject_frames == 0
    assert tiered.uxcost == plain.uxcost
    assert tiered.frames == plain.frames
    assert tiered.drops == plain.drops
    assert tiered.migrations == plain.migrations
    # same frames, different labels: tierless lumps all into tier-1
    assert sum(tiered.tier_frames.values()) == sum(plain.tier_frames.values())
    assert set(plain.tier_frames) == {TIER_STANDARD}


def test_legacy_trace_has_no_slo_records():
    """A tierless, controller-free recorded run stays byte-stable against
    the SLO subsystem: no slo/swap/reject strings anywhere in its trace,
    and the trace still replays bit-exactly."""
    scn = tiered_fleet(tiers=False, supernet_frac=0.0, burst=False,
                       n_streams=12)
    live = FleetSimulator(scn, "score", duration_s=0.8, seed=3,
                          record=True).run()
    text = ftrace.dumps(live.trace)
    assert '"slo"' not in text
    assert '"swap"' not in text
    assert '"reject"' not in text
    assert ftrace.dumps(ftrace.loads(text)) == text
    rep = FleetSimulator(replay=ftrace.loads(text)).run()
    assert (rep.uxcost, rep.frames) == (live.uxcost, live.frames)
