"""Differential harness: vectorized fleet fast paths vs scalar oracles.

PR 8 reimplemented the fleet's inner loops as batched numpy column ops
(router placement scoring), a scalar arithmetic fast path (the per-node
DREAM scheduler), and a persistent lazy event heap (the fleet clock).
Each fast path's original implementation stays alive behind a flag:

  * ``ScoreDrivenRouter.vectorized = False``  -> per-node scalar scoring
  * ``DreamScheduler.fast_path = False``      -> numpy-per-job mapscore
  * ``FleetSimulator.lazy_peek = False``      -> full node-list rescans

Those scalar paths exist solely as the test oracle: this module drives
fuzzed fleet scenarios through both implementations and asserts the
results are *identical* — placements, UXCost, pipeline latencies, and
the recorded trace byte-for-byte.  The vectorization is a pure
reimplementation, not a new policy; any diff is a bug.

When ``hypothesis`` is installed (optional test dependency), a
property-based layer fuzzes scenario shapes too; without it the fixed
parametrized grid still covers every placement granularity (whole,
stage-split, SLO-overload, lifecycle churn, contended links, tuned
weights).
"""
from __future__ import annotations

import pytest

from repro.cluster import (FleetScenarioBuilder, FleetSimulator,
                           TransferModel)
from repro.cluster import trace as ftrace
from repro.cluster.router import ScoreDrivenRouter
from repro.core.scheduler import DreamScheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SYSTEMS_MIX = ("4K_2WS", "8K_2OS", "4K_1WS2OS", "8K_1OS2WS")

#: SLO config mirroring the overload sweep's deployment-tuned thresholds
SLO = {"t_degrade": 0.50, "t_promote": 0.35, "t_reject": 0.62,
       "max_actions": 6, "admit_level": 2}


def build_scenario(kind: str, seed: int, duration_s: float = 1.0):
    """One small fuzzed fleet scenario per coverage dimension.  Returns
    (scenario, FleetSimulator kwargs)."""
    b = FleetScenarioBuilder(f"equiv_{kind}_{seed}")
    n_nodes = 4
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
            for i in range(n_nodes)]
    kw: dict = {"duration_s": duration_s, "seed": seed, "record": True}
    if kind == "whole":
        b.node_drain(nids[0], at=round(0.5 * duration_s, 6))
        b.fuzz_streams(20, seed=seed, t0=0.0,
                       t1=round(0.5 * duration_s, 6), fps_scale=0.25)
        kw["policy"] = "score"
    elif kind == "split":
        b.fuzz_streams(8, seed=seed, t0=0.0,
                       t1=round(0.5 * duration_s, 6), fps_scale=1.0,
                       cascade_prob=1.0, max_depth=3, cascades_only=True,
                       deterministic_arrivals=True)
        kw.update(policy="score", split_stages=True,
                  transfer=TransferModel())
    elif kind == "slo":
        b.fuzz_streams(24, seed=seed, t0=0.0,
                       t1=round(0.35 * duration_s, 6), fps_scale=0.55,
                       tier_mix=(1.0, 2.0, 2.0), supernet_frac=0.5,
                       deterministic_arrivals=True)
        b.fuzz_streams(24, seed=seed + 50_021,
                       t0=round(0.45 * duration_s, 6),
                       t1=round(0.7 * duration_s, 6), fps_scale=0.55,
                       tier_mix=(1.0, 2.0, 2.0), supernet_frac=0.5,
                       deterministic_arrivals=True, depart_frac=1.0,
                       t_depart0=round(0.72 * duration_s, 6),
                       t_depart1=round(0.9 * duration_s, 6))
        kw.update(policy="score", slo=SLO, slo_every_s=0.1)
    elif kind == "lifecycle":
        b.node_drain(nids[0], at=round(0.55 * duration_s, 6))
        b.fuzz_streams(20, seed=seed, t0=0.0,
                       t1=round(0.5 * duration_s, 6), fps_scale=0.25,
                       depart_frac=0.5, rejoin_frac=0.4,
                       t_depart0=round(0.35 * duration_s, 6),
                       t_depart1=round(0.9 * duration_s, 6))
        kw.update(policy="score",
                  transfer=TransferModel(link_bandwidth_bytes_s=1.25e9),
                  rebalance_every_s=0.3)
    elif kind == "tuned":
        b.fuzz_streams(20, seed=seed, t0=0.0,
                       t1=round(0.6 * duration_s, 6), fps_scale=0.4,
                       deterministic_arrivals=True)
        kw.update(policy="tuned_score", tune_every_s=0.15,
                  rebalance_every_s=0.3)
    else:
        raise ValueError(kind)
    return b.build(), kw


def run_fingerprint(kind: str, seed: int) -> dict:
    """Run one scenario and reduce it to the exact-comparison fields."""
    fscn, kw = build_scenario(kind, seed)
    policy = kw.pop("policy")
    fs = FleetSimulator(fscn, policy, **kw)
    r = fs.run()
    return {
        "uxcost": r.uxcost,
        "frames": r.frames,
        "dlv_rate": r.dlv_rate,
        "norm_energy": r.norm_energy,
        "stream_seconds": r.stream_seconds,
        "pipeline_latency_s": r.pipeline_latency_s,
        "pipe_frames": r.pipe_frames,
        "migrations": r.migrations,
        "departures": r.departures,
        "jobs_purged": r.jobs_purged,
        "swaps": r.swaps,
        "rejections": r.rejections,
        "weights": tuple(r.weights) if r.weights is not None else None,
        # final placement maps (departed streams excluded by design —
        # the trace bytes below cover every intermediate placement)
        "stream_node": dict(fs.stream_node),
        "stage_node": dict(fs.stage_node),
        "trace_bytes": ftrace.dumps(r.trace),
    }


def force_scalar(monkeypatch) -> None:
    """Flip every fast path to its scalar reference implementation."""
    monkeypatch.setattr(ScoreDrivenRouter, "vectorized", False)
    monkeypatch.setattr(DreamScheduler, "fast_path", False)
    monkeypatch.setattr(FleetSimulator, "lazy_peek", False)


KINDS = ("whole", "split", "slo", "lifecycle", "tuned")


@pytest.mark.parametrize("kind", KINDS)
def test_vectorized_matches_scalar_oracle(kind, monkeypatch):
    vec = run_fingerprint(kind, seed=3)
    with monkeypatch.context() as m:
        force_scalar(m)
        ref = run_fingerprint(kind, seed=3)
    assert vec == ref


@pytest.mark.parametrize("seed", (0, 7))
def test_vectorized_matches_scalar_across_seeds(seed, monkeypatch):
    vec = run_fingerprint("lifecycle", seed=seed)
    with monkeypatch.context() as m:
        force_scalar(m)
        ref = run_fingerprint("lifecycle", seed=seed)
    assert vec == ref


class _SelfCheckingRouter(ScoreDrivenRouter):
    """Asserts, at every live placement decision, that the batched path
    and the scalar oracle agree — on the chosen node AND on every
    candidate's score bit-for-bit."""

    name = "score"

    def place(self, stream, nodes):
        got = ScoreDrivenRouter.place(self, stream, nodes)
        assert got == self._place_scalar(stream, nodes)
        best_iso = min(stream.cost_on(n).iso_s for n in nodes)
        svec = self.score_all(stream, nodes)
        for n, sv in zip(nodes, svec):
            assert float(sv) == self.score(stream, n, best_iso)
        return got

    def place_stages(self, stream, nodes, transfer):
        got = ScoreDrivenRouter.place_stages(self, stream, nodes, transfer)
        assert got == self._place_stages_scalar(stream, nodes, transfer)
        return got


@pytest.mark.parametrize("kind", ("whole", "split"))
def test_every_live_decision_agrees(kind):
    """In-situ check: the self-checking router re-derives each decision
    through the scalar oracle as the run unfolds (telemetry, backlogs
    and churn state exactly as the real router sees them)."""
    fscn, kw = build_scenario(kind, seed=5)
    kw.pop("policy")
    kw.pop("record")
    FleetSimulator(fscn, _SelfCheckingRouter(), **kw).run()


def _dual_run(kind: str, seed: int, monkeypatch) -> None:
    vec = run_fingerprint(kind, seed)
    with monkeypatch.context() as m:
        force_scalar(m)
        ref = run_fingerprint(kind, seed)
    assert vec == ref


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(kind=st.sampled_from(KINDS),
           seed=st.integers(min_value=0, max_value=2**16))
    def test_fuzzed_scenarios_equivalent(kind, seed):
        """Property layer: ANY fuzzer-generated fleet scenario must
        reproduce identically under the scalar oracles.  (Applies the
        flag flips inline — hypothesis reuses one test invocation.)"""
        vec = run_fingerprint(kind, seed)
        orig = (ScoreDrivenRouter.vectorized, DreamScheduler.fast_path,
                FleetSimulator.lazy_peek)
        ScoreDrivenRouter.vectorized = False
        DreamScheduler.fast_path = False
        FleetSimulator.lazy_peek = False
        try:
            ref = run_fingerprint(kind, seed)
        finally:
            (ScoreDrivenRouter.vectorized, DreamScheduler.fast_path,
             FleetSimulator.lazy_peek) = orig
        assert vec == ref
else:                                                  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed (optional dep)")
    def test_fuzzed_scenarios_equivalent():
        pass
