"""Differential harness: vectorized fleet fast paths vs scalar oracles.

PR 8 reimplemented the fleet's inner loops as batched numpy column ops
(router placement scoring), a scalar arithmetic fast path (the per-node
DREAM scheduler), and a persistent lazy event heap (the fleet clock).
Each fast path's original implementation stays alive behind a flag:

  * ``ScoreDrivenRouter.vectorized = False``  -> per-node scalar scoring
  * ``DreamScheduler.fast_path = False``      -> numpy-per-job mapscore
  * ``FleetSimulator.lazy_peek = False``      -> full node-list rescans

Those scalar paths exist solely as the test oracle: this module drives
fuzzed fleet scenarios through both implementations and asserts the
results are *identical* — placements, UXCost, pipeline latencies, and
the recorded trace byte-for-byte.  The vectorization is a pure
reimplementation, not a new policy; any diff is a bug.

When ``hypothesis`` is installed (optional test dependency), a
property-based layer fuzzes scenario shapes too; without it the fixed
parametrized grid still covers every placement granularity (whole,
stage-split, SLO-overload, lifecycle churn, contended links, tuned
weights).
"""
from __future__ import annotations

import pytest

from repro.cluster import (CascadeFuzz, FleetScenarioBuilder,
                           FleetSimulator, FuzzSpec, GenAIFuzz,
                           LifecycleFuzz, SLOFuzz, TransferModel)
from repro.cluster import trace as ftrace
from repro.cluster.router import ScoreDrivenRouter
from repro.core.scheduler import DreamScheduler
from repro.core.simulator import Simulator

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SYSTEMS_MIX = ("4K_2WS", "8K_2OS", "4K_1WS2OS", "8K_1OS2WS")

#: SLO config mirroring the overload sweep's deployment-tuned thresholds
SLO = {"t_degrade": 0.50, "t_promote": 0.35, "t_reject": 0.62,
       "max_actions": 6, "admit_level": 2}


def build_scenario(kind: str, seed: int, duration_s: float = 1.0):
    """One small fuzzed fleet scenario per coverage dimension.  Returns
    (scenario, FleetSimulator kwargs)."""
    b = FleetScenarioBuilder(f"equiv_{kind}_{seed}")
    n_nodes = 4
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)])
            for i in range(n_nodes)]
    kw: dict = {"duration_s": duration_s, "seed": seed, "record": True}
    if kind == "whole":
        b.node_drain(nids[0], at=round(0.5 * duration_s, 6))
        b.fuzz_streams(FuzzSpec(
            n_streams=20, seed=seed, t0=0.0,
            t1=round(0.5 * duration_s, 6), fps_scale=0.25))
        kw["policy"] = "score"
    elif kind == "split":
        b.fuzz_streams(FuzzSpec(
            n_streams=8, seed=seed, t0=0.0,
            t1=round(0.5 * duration_s, 6), fps_scale=1.0,
            deterministic_arrivals=True,
            cascade=CascadeFuzz(prob=1.0, max_depth=3, only=True)))
        kw.update(policy="score", split_stages=True,
                  transfer=TransferModel())
    elif kind == "slo":
        tiered = SLOFuzz(tier_mix=(1.0, 2.0, 2.0), supernet_frac=0.5)
        b.fuzz_streams(FuzzSpec(
            n_streams=24, seed=seed, t0=0.0,
            t1=round(0.35 * duration_s, 6), fps_scale=0.55,
            deterministic_arrivals=True, slo=tiered))
        b.fuzz_streams(FuzzSpec(
            n_streams=24, seed=seed + 50_021,
            t0=round(0.45 * duration_s, 6),
            t1=round(0.7 * duration_s, 6), fps_scale=0.55,
            deterministic_arrivals=True, slo=tiered,
            lifecycle=LifecycleFuzz(depart_frac=1.0,
                                    t0=round(0.72 * duration_s, 6),
                                    t1=round(0.9 * duration_s, 6))))
        kw.update(policy="score", slo=SLO, slo_every_s=0.1)
    elif kind == "lifecycle":
        b.node_drain(nids[0], at=round(0.55 * duration_s, 6))
        b.fuzz_streams(FuzzSpec(
            n_streams=20, seed=seed, t0=0.0,
            t1=round(0.5 * duration_s, 6), fps_scale=0.25,
            lifecycle=LifecycleFuzz(depart_frac=0.5, rejoin_frac=0.4,
                                    t0=round(0.35 * duration_s, 6),
                                    t1=round(0.9 * duration_s, 6))))
        kw.update(policy="score",
                  transfer=TransferModel(link_bandwidth_bytes_s=1.25e9),
                  rebalance_every_s=0.3)
    elif kind == "tuned":
        b.fuzz_streams(FuzzSpec(
            n_streams=20, seed=seed, t0=0.0,
            t1=round(0.6 * duration_s, 6), fps_scale=0.4,
            deterministic_arrivals=True))
        kw.update(policy="tuned_score", tune_every_s=0.15,
                  rebalance_every_s=0.3)
    elif kind == "genai":
        # mixed chat+vision population: stochastic token counts, decode
        # yield points, EWMA length prediction — the autoregressive
        # machinery must survive both engines bit-identically
        b.fuzz_streams(FuzzSpec(
            n_streams=18, seed=seed, t0=0.0,
            t1=round(0.5 * duration_s, 6), fps_scale=0.5,
            deterministic_arrivals=True, genai=GenAIFuzz(frac=0.34)))
        kw["policy"] = "score"
    else:
        raise ValueError(kind)
    return b.build(), kw


def run_fingerprint(kind: str, seed: int) -> dict:
    """Run one scenario and reduce it to the exact-comparison fields."""
    fscn, kw = build_scenario(kind, seed)
    policy = kw.pop("policy")
    fs = FleetSimulator(fscn, policy, **kw)
    r = fs.run()
    return {
        "uxcost": r.uxcost,
        "frames": r.frames,
        "dlv_rate": r.dlv_rate,
        "norm_energy": r.norm_energy,
        "stream_seconds": r.stream_seconds,
        "pipeline_latency_s": r.pipeline_latency_s,
        "pipe_frames": r.pipe_frames,
        "migrations": r.migrations,
        "departures": r.departures,
        "jobs_purged": r.jobs_purged,
        "swaps": r.swaps,
        "rejections": r.rejections,
        "weights": tuple(r.weights) if r.weights is not None else None,
        # final placement maps (departed streams excluded by design —
        # the trace bytes below cover every intermediate placement)
        "stream_node": dict(fs.stream_node),
        "stage_node": dict(fs.stage_node),
        "trace_bytes": ftrace.dumps(r.trace),
    }


def force_scalar(monkeypatch) -> None:
    """Flip every fast path to its scalar reference implementation."""
    monkeypatch.setattr(ScoreDrivenRouter, "vectorized", False)
    monkeypatch.setattr(DreamScheduler, "fast_path", False)
    monkeypatch.setattr(FleetSimulator, "lazy_peek", False)
    monkeypatch.setattr(Simulator, "soa_slab", False)


KINDS = ("whole", "split", "slo", "lifecycle", "tuned", "genai")


@pytest.mark.parametrize("kind", KINDS)
def test_vectorized_matches_scalar_oracle(kind, monkeypatch):
    vec = run_fingerprint(kind, seed=3)
    with monkeypatch.context() as m:
        force_scalar(m)
        ref = run_fingerprint(kind, seed=3)
    assert vec == ref


@pytest.mark.parametrize("seed", (0, 7))
def test_vectorized_matches_scalar_across_seeds(seed, monkeypatch):
    vec = run_fingerprint("lifecycle", seed=seed)
    with monkeypatch.context() as m:
        force_scalar(m)
        ref = run_fingerprint("lifecycle", seed=seed)
    assert vec == ref


def test_budget_aware_routing_matches_scalar_oracle(monkeypatch):
    """SLO-budget-aware routing (urgency divided by the stream's tier
    budget) must hold the same batched-vs-scalar bit-identity as the
    budget-blind score — and must actually route differently on a tiered
    population, or the flag is dead code."""
    flat = run_fingerprint("slo", seed=5)
    with monkeypatch.context() as m:
        m.setattr(ScoreDrivenRouter, "budget_aware", True)
        vec = run_fingerprint("slo", seed=5)
        with monkeypatch.context() as m2:
            force_scalar(m2)
            ref = run_fingerprint("slo", seed=5)
    assert vec == ref
    assert vec["trace_bytes"] != flat["trace_bytes"]


# --------------------------------------------------------------- SoA slab
# PR 9's structure-of-arrays simulation core: the per-node inner loop
# advances in time slabs over a flat JobTable instead of per-frame Python
# events.  The scalar per-event engine stays alive as the oracle behind
# ``Simulator.soa_slab``; these arms isolate that one flag (the other fast
# paths stay on) so a slab-core diff cannot hide behind the router/clock
# oracles.

@pytest.mark.parametrize("kind", KINDS)
def test_soa_slab_matches_scalar_isolated(kind, monkeypatch):
    vec = run_fingerprint(kind, seed=11)
    with monkeypatch.context() as m:
        m.setattr(Simulator, "soa_slab", False)
        ref = run_fingerprint(kind, seed=11)
    assert vec == ref


@pytest.mark.parametrize("kind", KINDS)
def test_soa_batch_arm_forced(kind, monkeypatch):
    """Small scenarios rarely reach the batch arm's ready-set threshold;
    pinning it to 1 forces every scheduling decision and frame-drop scan
    through the SoA matrix path, which must be bit-identical too."""
    base = run_fingerprint(kind, seed=3)
    with monkeypatch.context() as m:
        m.setattr(DreamScheduler, "soa_batch_min", 1)
        forced = run_fingerprint(kind, seed=3)
    assert base == forced


def _node_fingerprint(r) -> tuple:
    return (r.uxcost, r.frames, r.drops, r.aborts, r.dlv_rate,
            r.norm_energy, tuple(r.acc_utilization), tuple(r.windows),
            tuple(sorted(r.variant_counts.items())), r.pipeline_latency_s)


def _drive_slabs(monkeypatch, soa: bool, scenario_name: str,
                 actions) -> tuple:
    """Drive one single-node Simulator through explicit step_until slabs,
    applying ``actions`` (t, fn) at slab boundaries, and fingerprint it."""
    from repro.core import build_scenario, dream_full
    with monkeypatch.context() as m:
        m.setattr(Simulator, "soa_slab", soa)
        sim = Simulator(build_scenario(scenario_name, 0.8), "4K_1WS2OS",
                        dream_full(), duration_s=1.0, seed=2)
        sim.start()
        for t, fn in actions:
            sim.step_until(t)
            fn(sim, t)
        sim.step_until(sim.duration_s)
        return _node_fingerprint(sim.finalize())


@pytest.mark.parametrize("soa", (True, False))
def test_slab_boundary_depart(monkeypatch, soa):
    """A stream departure (leave + purge) lands between two slabs cut at
    an arbitrary non-event time — the slab core must flush its done lane
    and observe the purge exactly as the per-event oracle does."""
    def depart(sim, t):
        name = sim.specs[0].model.name
        sim.leave_model(name, t)
        sim.purge_model(name)
    fps = [_drive_slabs(monkeypatch, s, "AR_Social", [(0.347, depart)])
           for s in (soa, False)]
    assert fps[0] == fps[1]


@pytest.mark.parametrize("soa", (True, False))
def test_slab_boundary_swap_variant(monkeypatch, soa):
    """An SLO degradation pin (swap_variant) applied mid-run: every job
    created in later slabs starts on the pinned variant, identically on
    both engines."""
    def swap(sim, t):
        sim.swap_variant("ctx_ofa", 1, t)
    fps = [_drive_slabs(monkeypatch, s, "VR_Gaming",
                        [(0.283, swap), (0.75, lambda sim, t:
                          sim.swap_variant("ctx_ofa", 0, t))])
           for s in (soa, False)]
    assert fps[0] == fps[1]


def test_zero_length_slab(monkeypatch):
    """Repeated zero-length slabs (advancing to the current time) process
    nothing, leave the external event surface (peek_t) unchanged, and
    leave no residue in the slab done lane."""
    from repro.core import build_scenario, dream_full
    with monkeypatch.context() as m:
        m.setattr(Simulator, "soa_slab", True)
        sim = Simulator(build_scenario("AR_Social", 0.8), "4K_1WS2OS",
                        dream_full(), duration_s=1.0, seed=2)
        sim.start()
        assert sim.step_until(0.4) > 0
        peek = sim.peek_t()
        for _ in range(3):
            assert sim.step_until(0.4) == 0         # zero-length slab
            assert sim.peek_t() == peek
            assert sim._slab_dones == []            # lane fully flushed
        # every in-flight completion is visible on the heap between slabs
        busy = sum(a.busy for a in sim.accs)
        dones = sum(1 for e in sim.events if e[2] == 1)  # DONE kind
        assert dones == busy
        sim.step_until(sim.duration_s)
        assert _node_fingerprint(sim.finalize())


class _SelfCheckingRouter(ScoreDrivenRouter):
    """Asserts, at every live placement decision, that the batched path
    and the scalar oracle agree — on the chosen node AND on every
    candidate's score bit-for-bit."""

    name = "score"

    def place(self, stream, nodes):
        got = ScoreDrivenRouter.place(self, stream, nodes)
        assert got == self._place_scalar(stream, nodes)
        best_iso = min(stream.cost_on(n).iso_s for n in nodes)
        svec = self.score_all(stream, nodes)
        for n, sv in zip(nodes, svec):
            assert float(sv) == self.score(stream, n, best_iso)
        return got

    def place_stages(self, stream, nodes, transfer):
        got = ScoreDrivenRouter.place_stages(self, stream, nodes, transfer)
        assert got == self._place_stages_scalar(stream, nodes, transfer)
        return got


@pytest.mark.parametrize("kind", ("whole", "split"))
def test_every_live_decision_agrees(kind):
    """In-situ check: the self-checking router re-derives each decision
    through the scalar oracle as the run unfolds (telemetry, backlogs
    and churn state exactly as the real router sees them)."""
    fscn, kw = build_scenario(kind, seed=5)
    kw.pop("policy")
    kw.pop("record")
    FleetSimulator(fscn, _SelfCheckingRouter(), **kw).run()


def _dual_run(kind: str, seed: int, monkeypatch) -> None:
    vec = run_fingerprint(kind, seed)
    with monkeypatch.context() as m:
        force_scalar(m)
        ref = run_fingerprint(kind, seed)
    assert vec == ref


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(kind=st.sampled_from(KINDS),
           seed=st.integers(min_value=0, max_value=2**16))
    def test_fuzzed_scenarios_equivalent(kind, seed):
        """Property layer: ANY fuzzer-generated fleet scenario must
        reproduce identically under the scalar oracles.  (Applies the
        flag flips inline — hypothesis reuses one test invocation.)"""
        vec = run_fingerprint(kind, seed)
        orig = (ScoreDrivenRouter.vectorized, DreamScheduler.fast_path,
                FleetSimulator.lazy_peek, Simulator.soa_slab)
        ScoreDrivenRouter.vectorized = False
        DreamScheduler.fast_path = False
        FleetSimulator.lazy_peek = False
        Simulator.soa_slab = False
        try:
            ref = run_fingerprint(kind, seed)
        finally:
            (ScoreDrivenRouter.vectorized, DreamScheduler.fast_path,
             FleetSimulator.lazy_peek, Simulator.soa_slab) = orig
        assert vec == ref
else:                                                  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed (optional dep)")
    def test_fuzzed_scenarios_equivalent():
        pass
