"""Sharding-rule resolution + small-mesh SPMD lowering of real steps.

The production 512-device lowering is exercised by launch/dryrun.py (which
must set XLA_FLAGS before jax init); here we verify the same code paths on
the single real CPU device (mesh (1,1)) and the rule-adaptation logic.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import SHAPES, get_config, smoke_config
from repro.distributed.sharding import (adapt_rules_for, spec_for,
                                        tree_specs)
from repro.launch.mesh import rules_for, rules_for_mesh
from repro.models import model as M


def _mesh11():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_rules_for_mesh_drops_missing_axes():
    rules = rules_for_mesh(_mesh11())
    assert rules["batch"] == "data"        # ('pod','data') -> 'data'
    assert rules["fsdp"] == "data"


def test_adapt_rules_degrades_indivisible_dims():
    mesh = _mesh11()
    rules = {"heads": "model", "kv_heads": "model"}
    out = adapt_rules_for(rules, mesh, {"heads": 8, "kv_heads": 1})
    # every axis size is 1 on this mesh, so nothing degrades
    assert out["heads"] == "model"
    # simulate a 16-wide model axis via a fake mesh shape
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((2, 16))
    out = adapt_rules_for(rules, FakeMesh(), {"heads": 8, "kv_heads": 1})
    assert out["heads"] is None and out["kv_heads"] is None


def test_rules_for_arch_kv_seq_fallback():
    """MQA archs on a model-parallel mesh shard the KV cache on kv_seq."""
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))
    cfg = get_config("gemma-2b")           # kv=1
    rules = rules_for(cfg, FakeMesh(), SHAPES["decode_32k"])
    assert rules["act_kv_heads"] is None
    assert rules["kv_seq"] == "model"


def test_param_axes_cover_params():
    """Every param leaf has a logical-axes tuple of matching rank."""
    for arch in ("gemma2-2b", "qwen3-moe-235b-a22b", "zamba2-2.7b",
                 "mamba2-130m"):
        cfg = smoke_config(arch)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        axes = M.param_axes(cfg)
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_a = dict(jax.tree_util.tree_flatten_with_path(
            axes, is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))[0])
        for path, leaf in flat_p:
            assert path in flat_a, f"{arch}: missing axes for {path}"
            assert len(flat_a[path]) == leaf.ndim, \
                f"{arch}: rank mismatch at {path}"


def test_spec_for_and_tree_specs():
    s = spec_for(("batch", None, "heads"),
                 {"batch": "data", "heads": "model"})
    assert s == jax.sharding.PartitionSpec("data", None, "model")
    tree = {"w": ("fsdp", "ffn"), "b": (None,)}
    specs = tree_specs(tree, {"fsdp": "data", "ffn": "model"})
    assert specs["w"] == jax.sharding.PartitionSpec("data", "model")


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-130m"])
def test_lower_train_step_on_real_device_mesh(arch):
    """End-to-end jit lowering with shardings on the (1,1) CPU mesh."""
    import repro.launch.dryrun as dryrun_side_effect
    # importing dryrun sets XLA_FLAGS; devices are already initialized by
    # conftest, so the flag is inert here — import kept for parity with
    # the real launch path
    assert dryrun_side_effect is not None
    cfg = dataclasses.replace(smoke_config(arch), scan_layers=True)
    mesh = _mesh11()
    from repro.training import TrainConfig, build_train_step, \
        init_train_state
    rules = rules_for(cfg, mesh, SHAPES["train_4k"])
    tcfg = TrainConfig()
    step = build_train_step(cfg, tcfg, rules)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    tokens = jnp.zeros((2, 8), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend:
        batch["frontend"] = jnp.zeros(
            (2, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    with mesh:
        new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))


# ---------------------------------------------------------------------------
# elastic re-mesh factorization on degraded device counts
# ---------------------------------------------------------------------------

from repro.distributed.elastic import best_mesh_shape


@pytest.mark.parametrize("n,mp,expect", [
    (8, 4, (2, 4)),      # healthy pod: model axis kept intact
    (6, 4, (3, 2)),      # degraded: 4 does not divide 6, halve to 2
    (7, 4, (7, 1)),      # prime survivor count: model axis collapses
    (12, 8, (3, 4)),     # 8 -> 4 is the largest halving that divides
    (5, 2, (5, 1)),      # odd survivor count under mp=2
    (1, 8, (1, 1)),      # single device left
    (96, 16, (6, 16)),   # non-power-of-two total, mp intact
    (9, 3, (3, 3)),      # non-power-of-two axis that still divides
    (10, 3, (10, 1)),    # halving from 3 jumps straight to 1 (3//2 == 1)
])
def test_best_mesh_shape_degraded_counts(n, mp, expect):
    """Join/leave leaves arbitrary device counts; the re-mesh must keep the
    model axis when it divides and shrink it minimally when it does not."""
    dp, m = best_mesh_shape(n, mp)
    assert (dp, m) == expect
    assert dp * m <= n                       # never oversubscribes
    assert mp % m == 0                       # weights re-tile by halvings
