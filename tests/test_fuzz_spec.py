"""API-consolidation shims: FuzzSpec and EngineConfig.

PR 10 collapsed the historical 16-kwarg ``fuzz_streams`` call form into
a structured ``FuzzSpec`` (cascade / lifecycle / SLO / genai sub-specs)
and the five engine toggles into ``EngineConfig`` presets.  The legacy
call forms keep working through DeprecationWarning shims; this module
pins both directions of the contract:

  * byte-stability — the legacy shim AND the FuzzSpec form both
    reproduce the fingerprints recorded before the redesign
    (tests/golden/fuzz_fingerprint.json), so nobody's seeded
    populations moved;
  * warning discipline — exactly one DeprecationWarning per legacy
    call, zero warnings through the new forms (CI runs the repro test
    lanes with DeprecationWarning promoted to error, so an internal
    caller regressing onto the old form fails loudly).
"""
from __future__ import annotations

import hashlib
import json
import os
import warnings

import pytest

from repro.core import EngineConfig, ENGINE_PRESETS, dream_full
from repro.core import build_scenario as build_core_scenario
from repro.core.simulator import Simulator
from repro.cluster import (CascadeFuzz, FleetScenarioBuilder,
                           FleetSimulator, FuzzSpec, GenAIFuzz,
                           LifecycleFuzz, SLOFuzz)
from repro.cluster import trace as ftrace
from repro.scenarios.builder import ScenarioError

FINGERPRINT_PATH = os.path.join(os.path.dirname(__file__), "golden",
                                "fuzz_fingerprint.json")
with open(FINGERPRINT_PATH) as _f:
    FINGERPRINTS = json.load(_f)

#: the recorded legacy call forms, verbatim from the fingerprint script
LEGACY = {
    "plain": dict(n_streams=12, seed=3),
    "scaled_window": dict(n_streams=10, seed=7, t0=0.1, t1=0.8,
                          fps_scale=0.4),
    "cascades": dict(n_streams=8, seed=11, cascade_prob=1.0, max_depth=3,
                     cascades_only=True, max_pipelines=2,
                     deterministic_arrivals=True),
    "lifecycle": dict(n_streams=14, seed=5, depart_frac=0.5,
                      rejoin_frac=0.4, t_depart0=0.4, t_depart1=0.9),
    "tiered_supernet": dict(n_streams=16, seed=9, fps_scale=0.55,
                            tier_mix=(1.0, 2.0, 2.0), supernet_frac=0.5,
                            deterministic_arrivals=True),
}

#: hand-written FuzzSpec equivalents — deliberately NOT derived through
#: the shim's own mapping code, so a mapping bug cannot hide
SPECS = {
    "plain": FuzzSpec(n_streams=12, seed=3),
    "scaled_window": FuzzSpec(n_streams=10, seed=7, t0=0.1, t1=0.8,
                              fps_scale=0.4),
    "cascades": FuzzSpec(n_streams=8, seed=11, deterministic_arrivals=True,
                         cascade=CascadeFuzz(prob=1.0, max_depth=3,
                                             only=True, max_pipelines=2)),
    "lifecycle": FuzzSpec(n_streams=14, seed=5,
                          lifecycle=LifecycleFuzz(depart_frac=0.5,
                                                  rejoin_frac=0.4,
                                                  t0=0.4, t1=0.9)),
    "tiered_supernet": FuzzSpec(n_streams=16, seed=9, fps_scale=0.55,
                                deterministic_arrivals=True,
                                slo=SLOFuzz(tier_mix=(1.0, 2.0, 2.0),
                                            supernet_frac=0.5)),
}


def _population_sha(call) -> str:
    """sha256 of the serialized fuzzed events, exactly as recorded by
    tests/golden/gen_fuzz_fingerprint.py."""
    b = FleetScenarioBuilder("fuzz_fingerprint")
    b.node("4K_1WS2OS")
    call(b)
    scn = b.build()
    events = [(e.t, e.kind, e.payload) for e in scn.events]
    blob = json.dumps(events, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()


@pytest.mark.parametrize("name", sorted(LEGACY))
def test_legacy_form_matches_recorded_fingerprint(name):
    kw = dict(LEGACY[name])
    with pytest.warns(DeprecationWarning):
        sha = _population_sha(
            lambda b: b.fuzz_streams(kw.pop("n_streams"), kw.pop("seed"),
                                     **kw))
    assert sha == FINGERPRINTS[name]["sha256"]


@pytest.mark.parametrize("name", sorted(SPECS))
def test_spec_form_matches_recorded_fingerprint(name):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sha = _population_sha(lambda b: b.fuzz_streams(SPECS[name]))
    assert sha == FINGERPRINTS[name]["sha256"]


def test_legacy_form_emits_exactly_one_deprecation_warning():
    b = FleetScenarioBuilder("warn_count")
    b.node("4K_1WS2OS")
    with pytest.warns(DeprecationWarning) as rec:
        b.fuzz_streams(6, 3)
    assert len([w for w in rec
                if w.category is DeprecationWarning]) == 1


def test_spec_form_rejects_legacy_leftovers():
    b = FleetScenarioBuilder("mixed_call")
    b.node("4K_1WS2OS")
    with pytest.raises(ScenarioError):
        b.fuzz_streams(FuzzSpec(n_streams=6, seed=3), seed=3)
    with pytest.raises(ScenarioError):
        b.fuzz_streams(FuzzSpec(n_streams=6, seed=3), fps_scale=0.5)


def test_legacy_form_requires_seed():
    b = FleetScenarioBuilder("no_seed")
    b.node("4K_1WS2OS")
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ScenarioError):
            b.fuzz_streams(6)


# ---------------------------------------------------------------------------
# EngineConfig: presets resolve the five toggles; deprecated per-toggle
# constructor kwargs still work (once-warned) and stay bit-identical.
# ---------------------------------------------------------------------------

def _small_fleet():
    b = FleetScenarioBuilder("engine_shim")
    b.node("4K_2WS")
    b.node("8K_2OS")
    # genai share included so the autoregressive decode loop is part of
    # what the presets must reproduce bit-identically
    b.fuzz_streams(FuzzSpec(n_streams=8, seed=3, fps_scale=0.5,
                            deterministic_arrivals=True,
                            genai=GenAIFuzz(frac=0.34)))
    return b.build()


def _fleet_trace(**kw) -> str:
    fs = FleetSimulator(_small_fleet(), "score", duration_s=1.0, seed=3,
                        record=True, **kw)
    return ftrace.dumps(fs.run().trace)


def test_engine_presets_are_bit_identical():
    default = _fleet_trace()
    assert _fleet_trace(engine="soa") == default
    assert _fleet_trace(engine="scalar") == default
    assert _fleet_trace(engine=EngineConfig(engine="scalar")) == default


def test_engine_preset_names_are_validated():
    with pytest.raises(ValueError):
        EngineConfig(engine="turbo")
    assert set(ENGINE_PRESETS) == {"soa", "scalar"}


def test_engine_resolve_applies_overrides():
    cfg = EngineConfig(engine="scalar", soa_slab=True)
    resolved = cfg.resolve()
    assert resolved["soa_slab"] is True          # override wins
    assert resolved["fast_path"] is False        # preset fills the rest


def test_fleet_lazy_peek_shim_warns_once_and_matches():
    with pytest.warns(DeprecationWarning) as rec:
        legacy = _fleet_trace(lazy_peek=False)
    assert len([w for w in rec
                if w.category is DeprecationWarning]) == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        new = _fleet_trace(engine=EngineConfig(lazy_peek=False))
    assert legacy == new


def _sim_result(**kw):
    scn = build_core_scenario("AR_Social", 0.9)
    sim = Simulator(scn, "4K_1WS2OS", dream_full(), duration_s=1.0, **kw)
    r = sim.run()
    return (r.uxcost, r.frames, r.drops, r.dlv_rate)


def test_simulator_soa_slab_shim_warns_once_and_matches():
    with pytest.warns(DeprecationWarning) as rec:
        legacy = _sim_result(soa_slab=False)
    assert len([w for w in rec
                if w.category is DeprecationWarning]) == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        new = _sim_result(engine=EngineConfig(soa_slab=False))
    assert legacy == new


def test_simulator_engine_presets_identical():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert _sim_result(engine="soa") == _sim_result()
        assert _sim_result(engine="scalar") == _sim_result()
