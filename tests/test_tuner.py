"""Online fleet-weight tuner: probe-core convergence, telemetry windows,
fleet phase events, re-arming, hindsight scoring, replay bypass."""
import numpy as np
import pytest

from repro.cluster import (CascadeFuzz, FleetScenarioBuilder,
                          FleetSimulator, FleetTelemetry, FuzzSpec,
                          STATIC_WEIGHTS, TelemetryWindow, TunedScoreRouter)
from repro.cluster import trace as ftrace
from repro.core.adaptivity import CoordinateProbe, ProbeSearch
from repro.scenarios import ScenarioError
from repro.scenarios.phases import scale_fps, set_fps

SYSTEMS = ("4K_1WS2OS", "8K_2WS", "4K_2OS", "8K_1OS2WS")


def drift_fleet(seed=2, n_nodes=4, n_streams=24, dur=1.5, churn=False,
                phase=True):
    b = FleetScenarioBuilder("tuner_fleet")
    nids = [b.node(SYSTEMS[i % len(SYSTEMS)]) for i in range(n_nodes)]
    if churn:
        b.node("8K_1WS2OS", at=0.4 * dur)
        b.node_drain(nids[1], at=0.5 * dur)
    sids = b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0, t1=0.7 * dur,
        fps_scale=0.4, deterministic_arrivals=True))
    if phase:
        # half the population surges: the nodes hosting it degrade mid-run
        b.phase(scale_fps(3.0), at=round(0.45 * dur, 6),
                sids=sids[:len(sids) // 2])
    return b.build()


# ---------------------------------------------------------------------------
# probe core (host-agnostic, repro.core.adaptivity)
# ---------------------------------------------------------------------------

def test_coordinate_probe_converges_on_synthetic_cost():
    """Batch-driven coordinate search finds the optimum of a convex cost
    — the 'probe converges' contract, checked where it is deterministic."""
    target = np.array([0.4, 1.6, 1.0])
    probe = CoordinateProbe(center=np.ones(3), lo=np.zeros(3),
                            hi=np.full(3, 2.0), radius=0.5, r_min=0.05,
                            shrink=0.6, margin=0.02)
    rng = np.random.default_rng(0)
    cost = lambda p: float(np.sum((p - target) ** 2))
    for _ in range(200):
        if not probe.probing:
            break
        probe.step_batch(cost, rng)
    assert not probe.probing                 # parked below r_min
    assert probe.commits > 0
    assert np.all(np.abs(probe.center - target) < 0.25)


def test_coordinate_probe_sequential_driver():
    """The deploy-and-measure driver: one candidate per window, commit at
    the end of each mini-cycle, never returns out-of-bounds points."""
    target = np.array([0.5, 1.5])
    probe = CoordinateProbe(center=np.ones(2), lo=np.zeros(2),
                            hi=np.full(2, 2.0), radius=0.5, margin=0.0)
    rng = np.random.default_rng(1)
    live = probe.current()
    for _ in range(300):
        if not probe.probing:
            break
        cost = float(np.sum((live - target) ** 2))
        live = probe.step(cost, rng)
        assert np.all(live >= 0.0) and np.all(live <= 2.0)
    assert not probe.probing
    assert float(np.sum((probe.center - target) ** 2)) < 0.5


def test_coordinate_probe_margin_blocks_marginal_commits():
    probe = CoordinateProbe(center=np.ones(1), lo=np.zeros(1),
                            hi=np.full(1, 2.0), radius=0.5, margin=0.5)
    rng = np.random.default_rng(0)
    # candidate is 10% better than center: inside the 50% margin -> hold
    probe.step_batch(lambda p: 1.0 - 0.1 * abs(float(p[0]) - 1.0), rng)
    assert probe.commits == 0
    assert probe.center[0] == 1.0


def test_coordinate_probe_retrigger_restarts_pass():
    probe = CoordinateProbe(center=np.ones(2), lo=np.zeros(2),
                            hi=np.full(2, 2.0), radius=0.5, r_min=0.4,
                            axis_order=(1, 0))
    rng = np.random.default_rng(0)
    probe.step_batch(lambda p: float(p[1]), rng)
    assert probe.pass_pos == 1
    probe.probing = False
    probe.retrigger()
    assert probe.probing
    assert probe.pass_pos == 0 and probe.axis == 1
    assert probe.radius >= 0.4
    assert probe.retriggers == 1


def test_probe_search_star_shape_matches_legacy_2d():
    """ProbeSearch candidates in 2-D are the four axis neighbors + center
    + one distant draw — the exact (alpha, beta) star of Section 3.6."""
    ps = ProbeSearch(center=np.array([1.0, 1.0]), radius=0.5)
    rng = np.random.default_rng(0)
    first = ps.step(0.0, rng)                # makes candidates, returns c0
    assert np.array_equal(first, np.array([1.0, 1.0]))
    cands = np.asarray(ps.candidates)
    assert cands.shape == (6, 2)
    assert np.array_equal(cands[1], [1.5, 1.0])
    assert np.array_equal(cands[2], [0.5, 1.0])
    assert np.array_equal(cands[3], [1.0, 1.5])
    assert np.array_equal(cands[4], [1.0, 0.5])


# ---------------------------------------------------------------------------
# fleet telemetry windows
# ---------------------------------------------------------------------------

def test_telemetry_windows_are_exact_deltas():
    fscn = drift_fleet(phase=False)
    fs = FleetSimulator(fscn, "score", duration_s=1.0, seed=2,
                        tune_every_s=0.25)
    r = fs.run()
    wins = list(fs.telemetry.windows)        # snapshot: observe() appends
    assert len(wins) == 3                    # ticks at 0.25/0.5/0.75
    assert all(w.t1 - w.t0 == pytest.approx(0.25) for w in wins)
    # a final snapshot accounts for everything since the last tick
    # (windows count stats frames: completions AND drops, like UXCost)
    final = fs.telemetry.observe(1.0, fs.nodes, fs.migrations,
                                 sum(fs.xfer_energy.values()))
    stat_frames = sum(st.frames for st in r.stats.per_model.values())
    assert sum(w.frames for w in wins) + final.frames == stat_frames
    for w in wins:
        assert w.violated <= w.frames
        assert set(w.node_dlv) == set(fs.nodes)
        assert w.backlog_p50 <= w.backlog_p90 <= w.backlog_max
        if w.frames:
            assert w.n_models > 0 and w.norm_uxcost > 0.0
            assert w.stream_uxcost            # per-stream deltas present


def test_zero_length_window_is_empty_and_holds_static_weights():
    tel = FleetTelemetry()
    fscn = drift_fleet(phase=False)
    fs = FleetSimulator(fscn, "tuned_score", duration_s=0.5, seed=2)
    fs.run()
    w1 = tel.observe(0.5, fs.nodes, 0, 0.0)
    w2 = tel.observe(0.5, fs.nodes, 0, 0.0)  # zero-length: no progress
    assert not w1.empty
    assert w2.empty and w2.frames == 0 and w2.norm_uxcost == 0.0
    pol = TunedScoreRouter()
    rng = np.random.default_rng(0)
    assert pol.on_window(w2, rng) is None    # held: no probe step
    assert pol.probe.steps == 0
    assert pol.weights == tuple(STATIC_WEIGHTS)


def test_signal_free_window_holds_weights():
    """A violation-free window cannot rank candidates: weights hold even
    though decisions were recorded."""
    pol = TunedScoreRouter()
    pol._decisions.append(([0, 1], np.zeros((2, 5)), np.zeros(2)))
    win = TelemetryWindow(
        t0=0.0, t1=0.5, frames=10, violated=0, dlv_rate=0.0, uxcost=0.1,
        node_dlv={0: 0.0, 1: 0.0}, node_frames={0: 5, 1: 5},
        backlog_p50=0.0, backlog_p90=0.0, backlog_max=0.0,
        migrations=0, xfer_j=0.0, stream_uxcost={}, n_models=2)
    assert pol.on_window(win, np.random.default_rng(0)) is None
    assert pol.held_windows == 1 and pol.probe.steps == 0
    assert not pol._decisions                # consumed, not accumulated


# ---------------------------------------------------------------------------
# fleet phase events
# ---------------------------------------------------------------------------

def test_fleet_phase_validation():
    b = FleetScenarioBuilder("bad_phase")
    b.node("4K_2WS")
    sid = b.fuzz_streams(FuzzSpec(n_streams=1, seed=0))[0]
    with pytest.raises(ScenarioError):       # model-addressed kinds stay
        b.phase(set_fps("det", 30.0), at=0.5)       # node-local
    with pytest.raises(ScenarioError):
        b.phase(scale_fps(2.0, models=["det"]), at=0.5)
    with pytest.raises(ScenarioError):
        b.phase(scale_fps(2.0), at=0.5, sids=[sid + 7])
    b.phase(scale_fps(2.0), at=0.5, sids=[sid])     # valid
    assert b.build().events[-1].kind == "phase"


def test_fleet_phase_shifts_load_and_retriggers():
    """The phase event actually changes the hosted streams' FPS (frames go
    up vs the unphased run) and re-arms node probes + the fleet tuner."""
    base = FleetSimulator(drift_fleet(phase=False), "score",
                          duration_s=1.5, seed=2).run()
    fs = FleetSimulator(drift_fleet(phase=True), "score",
                        duration_s=1.5, seed=2)
    r = fs.run()
    assert r.frames > base.frames * 1.2      # the surge really happened
    # phase events are workload changes: the touched nodes' (alpha, beta)
    # probes re-armed beyond the placement-churn retriggers
    assert r.probe_retriggers > base.probe_retriggers


def test_phase_event_scales_migrated_stream_at_drifted_rate():
    """A stream migrated after a phase event keeps its drifted FPS: the
    StreamView owns rescaled configs, not the scenario's originals."""
    fscn = drift_fleet(phase=True)
    fs = FleetSimulator(fscn, "score", duration_s=1.5, seed=2)
    fs.run()
    phased = {e.payload["sids"][0]
              for e in fscn.events if e.kind == "phase"}
    sid = next(iter(phased))
    # the scenario's own entries are untouched...
    orig = next(e.payload["entries"] for e in fscn.events
                if e.kind == "stream" and e.payload["sid"] == sid)
    sv = fs.streams[sid]
    assert sv.entry_cfgs[0]["fps"] == pytest.approx(
        float(orig[0]["fps"]) * 3.0)         # ...the view carries the x3
    assert float(orig[0]["fps"]) != sv.entry_cfgs[0]["fps"]


# ---------------------------------------------------------------------------
# the tuner in the fleet loop
# ---------------------------------------------------------------------------

def cascade_split_fleet(seed=3, n_streams=8, dur=0.8):
    b = FleetScenarioBuilder("tuner_cascade")
    for i in range(4):
        b.node(SYSTEMS[i % len(SYSTEMS)])
    b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0, t1=0.5 * dur,
        fps_scale=0.25, deterministic_arrivals=True,
        cascade=CascadeFuzz(prob=1.0, max_depth=3, only=True)))
    return b.build()


def test_stage_decisions_record_five_wide_terms():
    """Under stage-split placement the tuned router records one decision
    context per *stage* with the full 5-wide WEIGHT_NAMES terms — the
    transfer column finite, and nonzero for off-parent candidates — while
    still landing on the static router's exact placements."""
    from repro.cluster import TransferModel
    tm = TransferModel(link_bandwidth_bytes_s=1.25e9)
    kw = dict(duration_s=0.8, seed=3, transfer=tm, split_stages=True)
    fs = FleetSimulator(cascade_split_fleet(), "tuned_score", **kw)
    fs.run()                      # no tune ticks: contexts are retained
    decisions = list(fs.policy._decisions)
    assert decisions
    # cascades_only: every stream has >= 2 stages, so there are strictly
    # more decisions (head + each stage) than streams
    assert len(decisions) > len(fs.streams)
    xfer_cols = np.concatenate([rows[:, 4] for _, rows, _ in decisions])
    assert all(rows.shape[1] == 5 for _, rows, _ in decisions)
    assert np.isfinite(xfer_cols).all()        # inf is clamped, never stored
    assert (xfer_cols > 0).any()               # off-parent candidates priced
    # head (whole-stream) decisions keep a zero transfer column
    assert (xfer_cols == 0).any()
    # recording must not perturb the argmin: placement parity with the
    # static score router on the identical scenario
    ctrl = FleetSimulator(cascade_split_fleet(), "score", **kw)
    ctrl.run()
    assert fs.stage_node == ctrl.stage_node


def test_tuner_consumes_windows_and_stays_in_bounds():
    fscn = drift_fleet(phase=True)
    fs = FleetSimulator(fscn, "tuned_score", duration_s=1.5, seed=2,
                        tune_every_s=0.25, rebalance_every_s=0.5)
    r = fs.run()
    pol = fs.policy
    assert pol.windows_seen == 5
    assert pol.probe.steps > 0               # signal windows reached it
    mult = pol.multipliers
    assert np.all(mult >= pol.probe.lo) and np.all(mult <= pol.probe.hi)
    assert r.weights == pol.weights
    assert r.tuner_windows == pol.windows_seen


def test_tuner_rearms_on_join_drain_and_phase():
    fscn = drift_fleet(churn=True, phase=True)
    fs = FleetSimulator(fscn, "tuned_score", duration_s=1.5, seed=2,
                        tune_every_s=0.25)
    r = fs.run()
    # 4 initial joins + mid-run join + drain + phase event
    assert fs.tuner_retriggers == 7
    assert fs.policy.probe.retriggers == 7
    assert r.tuner_retriggers == 7


def test_tuner_without_commits_is_bit_identical_to_static():
    """Hindsight scoring deploys no candidates: until the probe commits,
    the tuned fleet must make exactly the static router's decisions."""
    fscn = drift_fleet(phase=True)
    static = FleetSimulator(fscn, "score", duration_s=1.5, seed=2,
                            rebalance_every_s=0.5).run()
    pol = TunedScoreRouter(margin=10.0)      # commit-proof margin
    tuned = FleetSimulator(fscn, pol, duration_s=1.5, seed=2,
                           rebalance_every_s=0.5, tune_every_s=0.25).run()
    assert pol.probe.commits == 0
    assert tuned.uxcost == static.uxcost
    assert tuned.frames == static.frames
    assert tuned.weights == tuple(STATIC_WEIGHTS)


def test_tuner_commits_on_degrading_fleet():
    """On a drifting fleet where some nodes degrade mid-run, the hindsight
    probe finds and commits a weight vector away from the static center
    (the seeded config is verified to produce a commit)."""
    fscn = drift_fleet(seed=1, n_nodes=4, n_streams=24, phase=True)
    fs = FleetSimulator(fscn, "tuned_score", duration_s=1.5, seed=1,
                        tune_every_s=0.2, rebalance_every_s=0.4)
    r = fs.run()
    assert r.tuner_commits > 0
    assert tuple(r.weights) != tuple(STATIC_WEIGHTS)


def test_tuned_trace_replay_bitexact_with_tuner_bypassed():
    fscn = drift_fleet(churn=True, phase=True)
    live_fs = FleetSimulator(fscn, "tuned_score", duration_s=1.5, seed=2,
                             tune_every_s=0.2, rebalance_every_s=0.5,
                             record=True)
    live = live_fs.run()
    text = ftrace.dumps(live.trace)
    assert text == ftrace.dumps(ftrace.loads(text))   # bytes-stable JSONL
    rep_fs = FleetSimulator(replay=ftrace.loads(text))
    rep = rep_fs.run()
    assert rep.uxcost == live.uxcost
    assert rep.frames == live.frames
    assert rep.drops == live.drops
    assert rep.migrations == live.migrations
    assert rep.weights == live.weights       # recorded tune decisions land
    # the tuner really was bypassed: no telemetry windows, no probe steps
    assert rep_fs.telemetry.windows == []
    assert rep_fs.policy.probe.steps == 0
    assert rep.tuner_windows == 0


def test_tune_records_only_on_signal_windows():
    """Held windows (empty / signal-free) record no tune event — live and
    replay agree on exactly which ticks committed weights."""
    fscn = drift_fleet(phase=True)
    live_fs = FleetSimulator(fscn, "tuned_score", duration_s=1.5, seed=2,
                             tune_every_s=0.25, record=True)
    live_fs.run()
    pol = live_fs.policy
    tunes = live_fs.trace.events_of("tune")
    assert len(tunes) == (pol.windows_seen - pol.empty_windows
                          - pol.held_windows)
    for ev in tunes:
        assert len(ev["weights"]) == len(STATIC_WEIGHTS)
        assert "window_uxcost" in ev and "probing" in ev


def test_set_weights_validation():
    pol = TunedScoreRouter()
    with pytest.raises(ValueError):
        pol.set_weights([1.0, 2.0])          # wrong arity
    with pytest.raises(ValueError):
        pol.set_weights([1.0, -0.1, 0.2, 0.15, 8.0])
